"""ISSUE 8: mesh-native compressed execution — sharded vs replicated
restore wall clock and per-link transfer accounting.

Runs on whatever local devices exist (the CI mesh-smoke job forces
``--xla_force_host_platform_device_count=8``); on a single device every
mesh row degrades to one explicit ``mesh/skipped`` row instead of lying
with replicated numbers.

  mesh/restore_replicated   load_for_serving() single-device layout
  mesh/restore_sharded      load_for_serving(mesh=...): each stream shard
                            uploads to its owning devices only
  mesh/serve_sharded        one prefill under the ambient serving mesh —
                            the derived column carries the d2d_allgather
                            ledger: compressed bytes moved, the
                            (A-1) x device-stream-bytes upper bound, and
                            the dense bytes (which must be ZERO: weight
                            gathering moves only compressed bytes — the CI
                            gate asserts this from BENCH_mesh.json)
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import Codec
from repro.core.codec_api import use_codec
from repro.launch.mesh import largest_model_axis, make_host_mesh
from repro.models import build_model
from repro.runtime.collectives import stream_nbytes, use_serving_mesh
from repro.runtime.weights import StreamedWeight, is_handle


def _once(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)) if out is not None else None
    return time.perf_counter() - t0, out


def run():
    rows = []
    n = len(jax.devices())
    model_ax = largest_model_axis(n, cap=4)
    if model_ax < 2:
        # single device: there is no mesh to measure — say so explicitly
        rows.append(("mesh/skipped", 0.0,
                     f"devices={n};no >=2-way model axis"))
        return rows
    mesh = make_host_mesh(model=model_ax)
    rows.append(("mesh/axes", 0.0,
                 f"data={mesh.shape['data']};model={mesh.shape['model']}"))

    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    codec = Codec()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, serving_layout="stream",
                                serving_min_bytes=1024,
                                serving_shards=model_ax, codec=codec)
        mgr.save(1, {"params": params}, blocking=True)
        like = jax.eval_shape(model.init, jax.random.key(0))

        codec.reset_transfer_stats()
        dt, _ = _once(lambda: mgr.load_for_serving(
            like, mode="stream", prefix="params", min_bytes=1024,
            shards=model_ax))
        ts = codec.transfer_stats()
        rows.append(("mesh/restore_replicated", dt * 1e6,
                     f"s={dt:.3f};h2d_mb={ts['h2d_bytes'] / 1e6:.2f}"))

        codec.reset_transfer_stats()
        dt, (tree, _) = _once(lambda: mgr.load_for_serving(
            like, mode="stream", prefix="params", min_bytes=1024,
            shards=model_ax, mesh=mesh))
        links = codec.link_stats()
        rows.append(("mesh/restore_sharded", dt * 1e6,
                     f"s={dt:.3f};"
                     f"h2d_mb={links['h2d']['compressed_bytes'] / 1e6:.2f};"
                     f"disk_mb={links['disk']['compressed_bytes'] / 1e6:.2f}"))

    # one prefill under the ambient serving mesh: every sharded stream
    # bundle is gathered as wire payloads; the ledger proves no dense
    # weight ever rode the interconnect
    sharded = [h for h in jax.tree.leaves(tree, is_leaf=is_handle)
               if isinstance(h, StreamedWeight)
               and h.ct.mode == "enec" and h.ct.shards == model_ax]
    bound = (model_ax - 1) * sum(stream_nbytes(h.ct) for h in sharded)
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0,
                                       cfg.vocab_size)}
    codec.reset_transfer_stats()
    with use_codec(codec), use_serving_mesh(mesh):
        dt, _ = _once(lambda: model.prefill_fn(tree, pb, 32))
    ag = codec.link_stats()["d2d_allgather"]
    assert ag["dense_bytes"] == 0, ag
    assert 0 < ag["compressed_bytes"] <= bound, (ag, bound)
    rows.append(("mesh/serve_sharded_prefill", dt * 1e6,
                 f"allgather_mb={ag['compressed_bytes'] / 1e6:.3f};"
                 f"bound_mb={bound / 1e6:.3f};"
                 f"dense_allgather_mb={ag['dense_bytes'] / 1e6:.3f};"
                 f"sharded_leaves={len(sharded)}"))
    return rows
