"""Whole-tree compression AND decompression pipeline: seed per-layer loop
vs device-resident stacked path (ISSUE 1 + ISSUE 4 tentpoles).

The legacy compress path below is a faithful copy of the seed pipeline: per
tensor it moved the FULL stack to the host for the parameter search, then
compressed each layer with its own jit dispatch (host round-trip for the
widening check, blocking ``device_get`` for the wire-size escape), and
finally ``jnp.stack``-copied the L stream pytrees.  The new path is
``compress_params_for_streaming`` on top of ``compress_stacked_many``:
device-side stats, one tiny host transfer per tree, one encode dispatch per
layer-stack bucket.

The decode side mirrors it (ISSUE 4): the legacy path decoded one layer
per jit dispatch (O(#layers) dispatches, one compile per distinct shape);
the new path is ``materialize_weight_tree`` on ``decompress_stacked_many``
— every leaf sharing a decoder bucket decodes in one concatenated dispatch
(O(#buckets) for the whole tree, ``decode_cache_stats`` asserts it).

Both a cold run (caches cleared — the production compress-once-per-model
scenario, where compile count dominates) and a warm steady state are timed,
on synthetic llama3_2_1b / qwen3_32b layer stacks (real layer counts,
CPU-scaled widths).  ``BENCH_SMOKE=1`` restricts to the smallest config.
"""
from __future__ import annotations

import functools
import os
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Codec
from repro.core import codec, params as params_mod
from repro.core.api import CompressedTensor
from repro.core.dtypes import FORMATS, format_for
from repro.runtime.streaming import (StreamedWeight,
                                     compress_params_for_streaming,
                                     materialize_weight_tree,
                                     streaming_encode_plan)

# the bench's own codec instance: every dispatch/compile counter below is
# scoped to it, so other suites in the same process cannot perturb the
# numbers (the v1 API property this PR makes assertable)
CODEC = Codec()

# real layer counts, widths scaled for a CPU bench.  Layer slices of 1-2
# blocks put the run in the dispatch/round-trip-bound regime that the NPU
# deployment actually lives in (there the codec kernel runs at memory speed
# and per-tensor host synchronization is what serializes the pipeline);
# Table VI shows compression ratios are size-independent.
MODELS = {
    "llama3_2_1b": dict(n_layers=16, d=128, d_kv=128, d_ff=256),
    "qwen3_32b": dict(n_layers=64, d=128, d_kv=128, d_ff=256),
}


def _active_models() -> dict:
    if os.environ.get("BENCH_SMOKE"):
        return {"llama3_2_1b": MODELS["llama3_2_1b"]}
    return MODELS
SHARDS = 1
COLD_ITERS = 2
WARM_ITERS = 5


def synthetic_stacked_params(arch: str) -> dict:
    """A trained-LLM-like stacked weight tree (paper §III statistics)."""
    spec = MODELS[arch]
    L, d, d_kv, d_ff = spec["n_layers"], spec["d"], spec["d_kv"], spec["d_ff"]
    # stable digest, NOT hash(): PYTHONHASHSEED would reroll the weights
    # (and thus ratios/timings) every process
    rng = np.random.default_rng(zlib.crc32(arch.encode()))

    def gen(*shape):
        n = int(np.prod(shape))
        # per-tensor scale variation: distinct leaves get distinct searched
        # params, exactly as trained checkpoints do
        w = rng.standard_normal(n) * rng.uniform(0.008, 0.03)
        w[rng.random(n) < 2e-3] *= 64.0
        return jnp.asarray(w.astype(np.float32)).astype(jnp.bfloat16
                                                        ).reshape(shape)

    return {"period": [{
        "attn": {"wq": gen(L, d, d), "wk": gen(L, d, d_kv),
                 "wv": gen(L, d, d_kv), "wo": gen(L, d, d)},
        "mlp": {"w_gate": gen(L, d, d_ff), "w_up": gen(L, d, d_ff),
                "w_down": gen(L, d_ff, d)},
    }]}


# ---------------------------------------------------------------------------
# legacy (seed) per-layer pipeline, kept verbatim for the comparison
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _legacy_jit_encode(fmt_name: str, p):
    fmt = FORMATS[fmt_name]
    return jax.jit(lambda bits: codec.encode_blocks(bits, fmt, p))


def _legacy_compress_array(x, p, shards: int) -> CompressedTensor:
    """Seed ``compress_array``: full host round-trip + per-tensor sync."""
    fmt = format_for(x.dtype)
    host = np.asarray(jax.device_get(x))                  # FULL tensor -> host
    bits_h = np.ascontiguousarray(host).view(fmt.np_uint_dtype)
    exp = (bits_h >> fmt.mant_bits) & fmt.exp_mask
    p = params_mod.widen_for_range(p, int(exp.min()), int(exp.max()))
    bits = codec.to_blocks(x, fmt)
    nblocks = bits.shape[0]
    if shards > 1:
        extra = (-nblocks) % shards
        if extra:
            bits = jnp.concatenate(
                [bits, jnp.zeros((extra, bits.shape[1]), bits.dtype)])
    streams = _legacy_jit_encode(fmt.name, p)(bits)       # dispatch per layer
    if shards > 1:
        streams = jax.tree.map(
            lambda a: a.reshape((shards, a.shape[0] // shards) + a.shape[1:]),
            streams)
    ct = CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=tuple(x.shape), dtype_str=str(x.dtype),
        block_elems=params_mod.DEFAULT_BLOCK_ELEMS, shards=shards, mode="enec")
    ct.nbytes_wire()                                      # blocking sync/tensor
    return ct


def legacy_compress_tree(params, shards: int = SHARDS):
    """Seed ``compress_params_for_streaming``: O(#layers) dispatches."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for leaf in flat:
        n_layers = leaf.shape[0]
        p = params_mod.search_for_array(                  # FULL stack -> host
            np.asarray(jax.device_get(leaf)), format_for(leaf.dtype))
        cts = [_legacy_compress_array(leaf[i], p, shards)
               for i in range(n_layers)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *cts)
        out.append(stacked)
    return jax.tree_util.tree_unflatten(treedef, out)


def stacked_compress_tree(params, shards: int = SHARDS):
    return compress_params_for_streaming(params, min_bytes=1024,
                                         shards=shards, codec=CODEC)


# ---------------------------------------------------------------------------
# legacy (seed) per-layer decode path, kept verbatim for the comparison
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _legacy_jit_decode(fmt_name: str, p, n_elems: int):
    fmt = FORMATS[fmt_name]
    return jax.jit(lambda streams: codec.decode_blocks(streams, n_elems,
                                                       fmt, p))


def legacy_decompress_tree(streamed):
    """Seed decode path: one jit'd decode dispatch per LAYER per leaf (the
    exact shape of the retired ``decompress_on_device``-per-slice restore),
    plus the per-leaf un-permute."""
    flat, treedef = jax.tree_util.tree_flatten(
        streamed, is_leaf=lambda x: isinstance(x, StreamedWeight))
    out = []
    for sw in flat:
        ct = sw.ct
        n_layers = ct.streams.mask.shape[0]
        layers = []
        for i in range(n_layers):
            s = jax.tree.map(lambda a: a[i], ct.streams)   # one layer slice
            flat_s = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[-1:])
                if a.ndim > 1 else a.reshape(-1), s)
            bits = _legacy_jit_decode(ct.fmt_name, ct.params,
                                      ct.block_elems)(flat_s)
            layers.append(codec.from_blocks(bits, ct.shape, ct.fmt))
        w = jnp.stack(layers).astype(jnp.dtype(ct.dtype_str))
        out.append(jnp.moveaxis(w, 1, 1 + sw.tp_axis))
    return jax.tree_util.tree_unflatten(treedef, out)


def stacked_decompress_tree(streamed):
    return materialize_weight_tree(streamed, codec=CODEC)


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def _clear_all_caches():
    jax.clear_caches()
    _legacy_jit_encode.cache_clear()
    _legacy_jit_decode.cache_clear()
    CODEC.reset_encode_cache_stats(clear_cache=True)
    CODEC.reset_decode_cache_stats(clear_cache=True)


def _time_once(fn, params) -> float:
    t0 = time.perf_counter()
    out = fn(params)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _time_cold(fn, params) -> float:
    ts = []
    for _ in range(COLD_ITERS):
        _clear_all_caches()
        ts.append(_time_once(fn, params))
    return float(np.min(ts))


def _time_warm(fn, params) -> float:
    _time_once(fn, params)
    # min over iters: robust to scheduler noise on a shared bench box
    return float(np.min([_time_once(fn, params)
                         for _ in range(WARM_ITERS)]))


def _verify_lossless(params, streamed) -> None:
    flat_in = jax.tree_util.tree_leaves(params)
    flat_out = jax.tree_util.tree_leaves(
        streamed, is_leaf=lambda x: isinstance(x, StreamedWeight))
    for x, sw in zip(flat_in, flat_out):
        assert isinstance(sw, StreamedWeight), "leaf unexpectedly dense"
        dec = jnp.moveaxis(CODEC.decompress_stacked(sw.ct), 1,
                           1 + sw.tp_axis)
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(x)).view(np.uint16),
            np.asarray(jax.device_get(dec)).view(np.uint16))


def _verify_decode_parity(params, a, b):
    for x, y, z in zip(jax.tree_util.tree_leaves(params),
                       jax.tree_util.tree_leaves(a),
                       jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(x)).view(np.uint16),
            np.asarray(jax.device_get(y)).view(np.uint16))
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(y)).view(np.uint16),
            np.asarray(jax.device_get(z)).view(np.uint16))


def run():
    rows = []
    for arch, spec in _active_models().items():
        params = synthetic_stacked_params(arch)
        streamed = stacked_compress_tree(params)
        _verify_lossless(params, streamed)

        legacy_cold = _time_cold(legacy_compress_tree, params)
        _clear_all_caches()
        stacked_cold = _time_cold(stacked_compress_tree, params)
        legacy_warm = _time_warm(legacy_compress_tree, params)
        _clear_all_caches()
        stacked_warm = _time_warm(stacked_compress_tree, params)
        # dispatch/compile accounting for ONE whole-tree compression —
        # and the plan/execute cross-check: the EncodePlan's bucket count
        # must equal the dispatches the cache counters measured
        _clear_all_caches()
        jax.block_until_ready(stacked_compress_tree(params))
        st = CODEC.encode_cache_stats()
        plan = streaming_encode_plan(params, min_bytes=1024, shards=SHARDS,
                                     codec=CODEC)
        assert st["dispatches"] == len(plan.buckets), (
            f"encode dispatches {st['dispatches']} != plan buckets "
            f"{len(plan.buckets)}")

        n_leaves = len(jax.tree_util.tree_leaves(params))
        n_layers = spec["n_layers"]
        rows += [
            (f"pipeline_tree/{arch}/legacy_cold", legacy_cold * 1e6,
             f"{n_leaves * n_layers}_encode_dispatches"),
            (f"pipeline_tree/{arch}/stacked_cold", stacked_cold * 1e6,
             f"{st['dispatches']}_encode_dispatches_{st['compiles']}_compiles"
             f"_{len(plan.buckets)}_plan_buckets"),
            (f"pipeline_tree/{arch}/legacy_warm", legacy_warm * 1e6, ""),
            (f"pipeline_tree/{arch}/stacked_warm", stacked_warm * 1e6, ""),
            (f"pipeline_tree/{arch}/speedup_cold", 0.0,
             f"{legacy_cold / stacked_cold:.2f}x"),
            (f"pipeline_tree/{arch}/speedup_warm", 0.0,
             f"{legacy_warm / stacked_warm:.2f}x"),
        ]

        # -- whole-tree DECOMPRESS: per-layer loop vs batched decode -------
        _verify_decode_parity(params, legacy_decompress_tree(streamed),
                              stacked_decompress_tree(streamed))
        d_legacy_cold = _time_cold(legacy_decompress_tree, streamed)
        _clear_all_caches()
        d_stacked_cold = _time_cold(stacked_decompress_tree, streamed)
        d_legacy_warm = _time_warm(legacy_decompress_tree, streamed)
        _clear_all_caches()
        d_stacked_warm = _time_warm(stacked_decompress_tree, streamed)
        # dispatch/compile accounting for ONE whole-tree decompression
        _clear_all_caches()
        jax.block_until_ready(
            jax.tree.leaves(stacked_decompress_tree(streamed)))
        dst = CODEC.decode_cache_stats()
        dplan = CODEC.plan_decode(
            [leaf.ct for leaf in jax.tree.leaves(
                streamed, is_leaf=lambda x: isinstance(x, StreamedWeight))
             if isinstance(leaf, StreamedWeight)])
        assert dst["dispatches"] == len(dplan.buckets), (
            f"decode dispatches {dst['dispatches']} != plan buckets "
            f"{len(dplan.buckets)}")
        rows += [
            (f"pipeline_tree/{arch}/decode_legacy_cold",
             d_legacy_cold * 1e6, f"{n_leaves * n_layers}_decode_dispatches"),
            (f"pipeline_tree/{arch}/decode_stacked_cold",
             d_stacked_cold * 1e6,
             f"{dst['dispatches']}_decode_dispatches_"
             f"{dst['compiles']}_compiles_{len(dplan.buckets)}_plan_buckets"),
            (f"pipeline_tree/{arch}/decode_legacy_warm",
             d_legacy_warm * 1e6, ""),
            (f"pipeline_tree/{arch}/decode_stacked_warm",
             d_stacked_warm * 1e6, ""),
            (f"pipeline_tree/{arch}/decode_speedup_cold", 0.0,
             f"{d_legacy_cold / d_stacked_cold:.2f}x"),
            (f"pipeline_tree/{arch}/decode_speedup_warm", 0.0,
             f"{d_legacy_warm / d_stacked_warm:.2f}x"),
        ]
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
