"""Shared benchmark helpers: timing + the Deflate/ZipNN-style baselines the
paper compares against (Table II rows NV_Deflate / ZipNN)."""
from __future__ import annotations

import time
import zlib

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (fn must block or return jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def to_bytes(x) -> bytes:
    return np.ascontiguousarray(np.asarray(jax.device_get(x))).tobytes()


def deflate_ratio(x) -> float:
    """General-purpose Deflate on the raw buffer (NV_Deflate analogue)."""
    raw = to_bytes(x)
    return len(raw) / len(zlib.compress(raw, 6))


def zipnn_like_ratio(x) -> float:
    """ZipNN-style: split exponent / sign+mantissa byte planes, Deflate the
    exponent plane, store the rest raw (tail-separation baseline)."""
    from repro.core.dtypes import format_for, split_fields, to_bits
    import jax.numpy as jnp

    fmt = format_for(x.dtype)
    bits = to_bits(x)
    exp, rawf = split_fields(bits, fmt)
    exp_b = np.asarray(jax.device_get(exp)).astype(np.uint8).tobytes()
    comp_exp = zlib.compress(exp_b, 6)
    raw_bits = fmt.raw_bits
    raw_bytes = (np.asarray(x).size * raw_bits + 7) // 8
    total = len(comp_exp) + raw_bytes
    return (np.asarray(x).size * fmt.total_bits / 8) / total
