"""ISSUE 2: TTFT / TPOT / tok/s across the weight-execution modes.

Serves a reduced llama config through the full policy path (dense | stream |
fused) and times prefill + single-token decode.  On CPU the compressed modes
pay pure decode overhead (no CPU->NPU link to win back) and the fused kernel
runs under Pallas interpret — the numbers locate the overhead side of the
trade; the win side is the derived roofline in bench_e2e.  Logits across the
three modes are bit-identical (tests/test_serving_modes.py), so the modes
are directly comparable.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.streaming import assign_weight_modes, stream_stats

from .common import time_fn


def run():
    rows = []
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)
    batch, prompt_len, max_len = 2, 16, 24
    pb = {"tokens": jax.random.randint(jax.random.key(1),
                                       (batch, prompt_len), 0,
                                       cfg.vocab_size)}
    for mode in ("dense", "stream", "fused"):
        tree = assign_weight_modes(params, mode=mode, min_bytes=1024,
                                   shards=2)
        st = stream_stats(tree)
        prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, max_len))

        @jax.jit
        def decode_step(p, cache, tok):
            logits, cache = model.decode_fn(p, cache, tok)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        ttft = time_fn(prefill, tree, pb, iters=3)
        _, cache = prefill(tree, pb)
        tok = jnp.zeros((batch,), jnp.int32)
        tpot = time_fn(lambda p, c, t: decode_step(p, c, t)[0],
                       tree, cache, tok, iters=5)
        rows.append((f"serve/{mode}/bs{batch}", tpot * 1e6,
                     f"ttft_s={ttft:.4f};tpot_s={tpot:.4f};"
                     f"tok_s={batch / tpot:.1f};"
                     f"hbm_ratio={st['hbm_ratio']:.3f}"))
    return rows
