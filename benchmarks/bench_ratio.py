"""Paper Table II: compression ratio across the 10 model datasets, ENEC vs
general-purpose (Deflate) and tail-separation (ZipNN-style) baselines.
Every ENEC row is verified bit-identical on decompression."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import default_codec
from repro.data.synthetic_weights import PAPER_MODELS, generate

from .common import deflate_ratio, time_fn, zipnn_like_ratio


def run():
    rows = []
    for spec in PAPER_MODELS:
        x = generate(spec)
        t0 = time_fn(lambda v: default_codec().compress_array(v), x,
                     iters=1, warmup=0)
        ct = default_codec().compress_array(x)
        y = default_codec().decompress_array(ct)
        dt = np.uint16 if spec.dtype != "fp32" else np.uint32
        lossless = bool((np.asarray(jax.device_get(x)).view(dt)
                         == np.asarray(jax.device_get(y)).view(dt)).all())
        assert lossless, spec.name
        rows.append((f"table2/enec/{spec.name}/{spec.dtype}",
                     t0 * 1e6, f"ratio={ct.ratio():.3f};lossless={lossless};"
                     f"params={ct.params.astuple() if ct.params else None}"))
        rows.append((f"table2/deflate/{spec.name}", 0.0,
                     f"ratio={deflate_ratio(x):.3f}"))
        rows.append((f"table2/zipnn_like/{spec.name}", 0.0,
                     f"ratio={zipnn_like_ratio(x):.3f}"))
    return rows
