"""Paper Fig. 13: ablation V0 -> V3.

V0  basic design (§IV-B): frequency-table gather mapping + per-group
    variable bit-width with 4-bit metadata (reduction-max).
V1  + bit-width quantization & hierarchical halving packing (1-bit mask,
    two-level m/n) — mapping still a table gather.
V2  + vectorized branch-free integer transform (= full ENEC encode).
V3  + IDD-Scan decode (prefix sum via MXU scan instead of serial cumsum —
    structural on CPU; we report the decode op mix and interpret-validated
    equality, plus CPU time of the gather-free decode).

Ratios are exact; CPU timings indicate the gather vs branch-free gap on
this host (the paper's Fig. 13 throughput story lives on the NPU/TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BF16, codec, search_for_array
from repro.core.dtypes import split_fields
from repro.data.synthetic_weights import WeightSetSpec, generate

from .common import time_fn

BLOCK = 16384


def _rank_table(exp_host):
    hist = np.bincount(exp_host.reshape(-1), minlength=256)
    table = np.empty(256, np.uint16)
    table[np.argsort(-hist)] = np.arange(256)
    return table


def v0_encode(bits, table, L=16):
    """Gather mapping + per-group variable width (4-bit metadata)."""
    exp, raw = split_fields(bits, BF16)
    y = jnp.take(jnp.asarray(table), exp.astype(jnp.int32))   # [B1] gather
    yg = y.reshape(y.shape[0], -1, L)
    gmax = jnp.max(yg, axis=-1)                                # [B2] red-max
    width = jnp.ceil(jnp.log2(gmax.astype(jnp.float32) + 1)).astype(jnp.int32)
    return y, width, raw


def v0_ratio(bits, table, L=16) -> float:
    y, width, _ = v0_encode(bits, table, L)
    total_bits = float(jnp.sum(width) * L + width.size * 4)
    raw_bits = bits.size * 8.0  # sign+mantissa stored raw (8 of 16)
    return bits.size * 16.0 / (total_bits + raw_bits)


def v1_ratio(bits, table, p) -> float:
    """Two-level m/n quantization of the TABLE-mapped values."""
    exp, _ = split_fields(bits, BF16)
    y = np.asarray(jnp.take(jnp.asarray(table), exp.astype(jnp.int32)))
    yg = y.reshape(-1, p.L)
    anom = (yg >= (1 << p.m)).any(axis=1)
    bits_exp = (1.0 + p.m * p.L) * yg.shape[0] \
        + float(anom.sum()) * p.L * (p.n - p.m)
    return bits.size * 16.0 / (bits_exp + bits.size * 8.0)


def run():
    rows = []
    spec = WeightSetSpec("deepseek-llm-7b-base", "bf16", 4 << 20, seed=3)
    x = generate(spec)
    host = np.asarray(jax.device_get(x))
    bits = codec.to_blocks(x, BF16, BLOCK)
    exp_host = (host.view(np.uint16) >> 7) & 0xFF
    table = _rank_table(exp_host)
    p = search_for_array(host, BF16)

    r0 = v0_ratio(bits, table)
    r1 = v1_ratio(bits, table, p)
    enc2 = jax.jit(functools.partial(codec.encode_blocks, fmt=BF16, p=p))
    streams = enc2(bits)
    comp_bytes = (streams.mask.size + streams.low.size + streams.raw.size
                  + int(np.ceil(np.asarray(streams.high_len).sum() / 8)))
    r2 = host.nbytes / comp_bytes

    t0 = time_fn(lambda b: v0_encode(b, table), bits, iters=3)
    t2 = time_fn(enc2, bits, iters=3)
    dec2 = jax.jit(functools.partial(codec.decode_blocks, n_elems=BLOCK,
                                     fmt=BF16, p=p))
    t2d = time_fn(dec2, streams)

    gb = host.nbytes / 1e9
    rows += [
        ("fig13/V0_table_gather_varwidth", t0 * 1e6,
         f"ratio={r0:.3f};enc_GBps={gb / t0:.3f}"),
        ("fig13/V1_quantized_halving_pack", t0 * 1e6,
         f"ratio={r1:.3f};enc_GBps={gb / t0:.3f}"),
        ("fig13/V2_branch_free_transform", t2 * 1e6,
         f"ratio={r2:.3f};enc_GBps={gb / t2:.3f};dec_GBps={gb / t2d:.3f}"),
        ("fig13/V2_vs_V0_encode_speedup", 0.0, f"x={t0 / t2:.2f}"),
        ("fig13/V3_idd_scan_decode", t2d * 1e6,
         "structural: prefix sum on MXU (see kernels/idd_scan.py); "
         "validated exact in tests/test_kernels.py"),
    ]
    return rows
