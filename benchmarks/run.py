"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Modules:
  bench_ratio       Table II   (compression ratio, 10 datasets, baselines)
  bench_throughput  Fig. 9     (CPU measured + TPU roofline projection)
  bench_blocksize   Fig. 11/12 + Table VI (block/input size sweeps)
  bench_ablation    Fig. 13    (V0 -> V3)
  bench_params      Table IV   (searched params + Eq. 4 formula check)
  bench_transfer    Table V    (parameter transferability)
  bench_pipeline    ISSUE 1    (whole-tree compression: per-layer vs stacked)
  bench_e2e         Fig. 10    (TTFT/TPOT dense vs ENEC-streamed + derived)
  bench_serve       ISSUE 2    (TTFT/TPOT/tok-s across weight-execution modes)
  bench_ckpt        ISSUE 3    (enec-v2 save/load + restore-to-serve wall clock)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_ablation, bench_blocksize, bench_ckpt, bench_e2e,
                   bench_params, bench_pipeline, bench_ratio, bench_serve,
                   bench_throughput, bench_transfer)
    modules = [bench_ratio, bench_throughput, bench_blocksize,
               bench_ablation, bench_params, bench_transfer, bench_pipeline,
               bench_e2e, bench_serve, bench_ckpt]
    print("name,us_per_call,derived")
    failed = 0
    for mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},ERROR,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
