"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows AND persists one machine-
readable ``BENCH_<suite>.json`` per suite at the repo root (timestamp,
backend/config, per-benchmark numbers) so the perf trajectory is tracked
across PRs instead of vanishing into stdout.

    python -m benchmarks.run                       # every suite
    python -m benchmarks.run pipeline ckpt         # a subset (by suite name)
    python -m benchmarks.run --smoke pipeline      # smallest configs only

Modules:
  bench_ratio       Table II   (compression ratio, 10 datasets, baselines)
  bench_throughput  Fig. 9     (CPU measured + TPU roofline projection)
  bench_blocksize   Fig. 11/12 + Table VI (block/input size sweeps)
  bench_ablation    Fig. 13    (V0 -> V3)
  bench_params      Table IV   (searched params + Eq. 4 formula check)
  bench_transfer    Table V    (parameter transferability)
  bench_pipeline    ISSUE 1/4  (whole-tree compress AND decompress:
                                per-layer vs stacked)
  bench_e2e         Fig. 10    (TTFT/TPOT dense vs ENEC-streamed + derived)
  bench_serve       ISSUE 2    (TTFT/TPOT/tok-s across weight-execution modes)
  bench_overlap     ISSUE 7    (decode-prefetch pipeline: decode_ms vs
                                matmul_ms, overlapped vs serial TPOT)
  bench_ckpt        ISSUE 3/4  (enec-v2 save/load + restore wall clock +
                                decode dispatch accounting)
  bench_faults      ISSUE 6    (restore latency under injected fault rates:
                                transient I/O, decode failure, corruption)
  bench_mesh        ISSUE 8    (sharded vs replicated restore, per-link
                                ledger: collective traffic = compressed
                                bytes only; needs a multi-device mesh)
  bench_traffic     ISSUE 9    (Poisson load against the continuous-batching
                                engine: served tok/s vs offered load,
                                p50/p99 TTFT/TPOT, shed/evicted/rejected
                                accounting, one-shot logit parity)
  bench_moe         ISSUE 10   (expert streaming: tok/s + p50/p99 TPOT vs
                                expert-cache budget 0/25/100%, hit-rate
                                curves skewed vs uniform routing, logit
                                parity + dispatch-bound gates)
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

SUITE_ORDER = ["ratio", "throughput", "blocksize", "ablation", "params",
               "transfer", "pipeline", "e2e", "serve", "overlap", "ckpt",
               "faults", "mesh", "traffic", "moe"]


def _env_flag(name: str) -> bool:
    """A truthy env flag: unset, "", "0", "false", "no", "off" are all
    False.  (``bool(os.environ.get(...))`` counted ``BENCH_SMOKE=0`` as
    smoke, so full-config runs got recorded as smoke artifacts.)"""
    return os.environ.get(name, "").strip().lower() \
        not in ("", "0", "false", "no", "off")


def _suite_name(mod_name: str) -> str:
    return mod_name.rsplit(".", 1)[-1].removeprefix("bench_")


def write_suite_json(suite: str, rows, error: str = None,
                     out_dir: Path = REPO_ROOT) -> Path:
    """Persist one suite's rows as ``BENCH_<suite>.json`` (the artifact CI
    uploads and the perf-trajectory record across PRs)."""
    import jax

    doc = {
        "suite": suite,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "config": {
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "python": sys.version.split()[0],
            "smoke": _env_flag("BENCH_SMOKE"),
        },
        "results": [{"name": name, "us_per_call": round(us, 1),
                     "derived": derived} for name, us, derived in rows],
    }
    if error is not None:
        doc["error"] = error
    path = Path(out_dir) / f"BENCH_{suite}.json"
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suites", nargs="*",
                    help="suite names to run (default: all); accepts "
                         "'pipeline' or 'bench_pipeline'")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest configs only (sets BENCH_SMOKE=1; the "
                         "CI bench-smoke job uses this)")
    ap.add_argument("--out-dir", default=str(REPO_ROOT),
                    help="where BENCH_<suite>.json files land "
                         "(default: repo root)")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["BENCH_SMOKE"] = "1"
        # every suite is expected to have a committed baseline at the repo
        # root for cross-PR comparison; flag the ones that don't so a new
        # suite can't silently ship without one
        missing = [s for s in SUITE_ORDER
                   if not (REPO_ROOT / f"BENCH_{s}.json").exists()]
        if missing:
            print(f"[benchmarks.run] suites missing a committed baseline "
                  f"at {REPO_ROOT}: {' '.join(missing)}", file=sys.stderr)

    from . import (bench_ablation, bench_blocksize, bench_ckpt, bench_e2e,
                   bench_faults, bench_mesh, bench_moe, bench_overlap,
                   bench_params, bench_pipeline, bench_ratio, bench_serve,
                   bench_throughput, bench_traffic, bench_transfer)
    by_suite = {_suite_name(m.__name__): m for m in
                [bench_ratio, bench_throughput, bench_blocksize,
                 bench_ablation, bench_params, bench_transfer,
                 bench_pipeline, bench_e2e, bench_serve, bench_overlap,
                 bench_ckpt, bench_faults, bench_mesh, bench_traffic,
                 bench_moe]}
    wanted = [s.removeprefix("bench_") for s in args.suites] or SUITE_ORDER
    unknown = [s for s in wanted if s not in by_suite]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; "
                         f"expected a subset of {SUITE_ORDER}")

    print("name,us_per_call,derived")
    failed = 0
    for suite in wanted:
        mod = by_suite[suite]
        rows = []   # accumulated incrementally so a mid-suite failure still
        try:        # records every completed benchmark, not an empty file
            for row in mod.run():
                rows.append(row)
                name, us, derived = row
                print(f"{name},{us:.1f},{derived}")
            write_suite_json(suite, rows, out_dir=Path(args.out_dir))
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},ERROR,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
            write_suite_json(suite, rows, error=f"{type(e).__name__}: {e}",
                             out_dir=Path(args.out_dir))
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
