"""ISSUE 10: MoE expert streaming — tok/s and TPOT vs expert-cache budget.

Serves the smallest MoE config (phi3_5_moe) with the expert stacks held as
per-expert compressed records behind the byte-budgeted LRU decode cache
(``runtime/experts.py``) and measures decode-step latency at cache budgets
of 0% / 25% / 100% of the fully-resident expert bytes, against the dense
baseline.  Derived keys carry the acceptance gates:

  parity_mismatches  bitwise logit mismatches vs dense (must be 0 at ANY
                     budget — the cache changes cost, never bits)
  dispatch_ok        every routing step's misses decoded in at most
                     #plan-buckets vectorized dispatches (the O(#buckets)
                     contract of ``host_decode.decode_many``)

A second section drives ``ExpertStore.fetch_step`` directly with synthetic
skewed (zipf) vs uniform routing to trace hit-rate curves against the
budget fraction — the cache-sizing signal ``docs/MOE.md`` documents.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import host_decode
from repro.models import build_model
from repro.runtime.experts import install_expert_store
from repro.runtime.streaming import assign_weight_modes


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "").strip().lower() \
        not in ("", "0", "false", "no", "off")


def _serve_timed(model, tree, pb, max_len, n_steps):
    """Prefill + n_steps greedy decode; returns (prefill_logits,
    first_decode_logits, per-step seconds)."""
    t0 = time.perf_counter()
    logits, cache = model.prefill_fn(tree, pb, max_len)
    jax.block_until_ready(logits)
    ttft = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    first = None
    steps = []
    for _ in range(n_steps):
        t0 = time.perf_counter()
        dec, cache = model.decode_fn(tree, cache, tok)
        jax.block_until_ready(dec)
        steps.append(time.perf_counter() - t0)
        if first is None:
            first = np.asarray(dec)
        tok = jnp.argmax(dec, -1).astype(jnp.int32)
    return np.asarray(logits), first, ttft, steps


def _mismatches(ref, got):
    return sum(int(np.sum(r.view(np.uint32) != g.view(np.uint32)))
               for r, g in zip(ref, got))


def _plan_buckets(store):
    """Distinct decode-bucket keys across the store's records — the bound
    a single fetch's dispatch count must stay under."""
    keys = set()
    for name in store.names():
        rec = host_decode.parse_record(store._records[(name, 0, 0)])
        p = rec.params
        keys.add((rec.fmt_name, (p.n, p.m, p.L), rec.block_elems))
    return len(keys)


def _routing_hit_rates(params, frac_budgets, *, skew, steps, seed):
    """Drive fetch_step directly with synthetic routing (k=2 of E per
    step, zipf-skewed or uniform) and return hit rates per budget."""
    rng = np.random.default_rng(seed)
    out = {}
    for frac in frac_budgets:
        _, store = install_expert_store(params)
        store.budget_bytes = int(frac * store.total_expert_bytes())
        names = store.names()
        m = store.meta(names[0])
        e, n_layers = m["n_experts"], m["n_layers"]
        if skew == "zipf":
            p = 1.0 / np.arange(1, e + 1) ** 1.5
        else:
            p = np.ones(e)
        p = p / p.sum()
        for i in range(steps):
            routed = rng.choice(e, size=min(2, e), replace=False, p=p)
            store.fetch_step(names, i % n_layers, routed)
        st = store.stats()
        out[frac] = st["hits"] / max(1, st["hits"] + st["misses"])
    return out


def run():
    rows = []
    smoke = _smoke()
    n_steps = 6 if smoke else 16
    sim_steps = 40 if smoke else 200
    cfg = dataclasses.replace(get_smoke_config("phi3_5_moe_42b_a6_6b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch, prompt_len = 2, 8
    max_len = prompt_len + n_steps + 2
    pb = {"tokens": jax.random.randint(jax.random.key(1),
                                       (batch, prompt_len), 0,
                                       cfg.vocab_size)}

    ref_pre, ref_dec, ttft, steps = _serve_timed(model, params, pb,
                                                 max_len, n_steps)
    tpot = float(np.median(steps))
    rows.append((f"moe/dense/bs{batch}", tpot * 1e6,
                 f"ttft_s={ttft:.4f};tpot_s={tpot:.4f};"
                 f"p50_tpot_s={np.percentile(steps, 50):.4f};"
                 f"p99_tpot_s={np.percentile(steps, 99):.4f};"
                 f"tok_s={batch / tpot:.1f}"))

    _, probe = install_expert_store(params)
    total = probe.total_expert_bytes()
    plan_buckets = _plan_buckets(probe)
    # 0.75 sits between one layer's working set and full residency: the
    # LRU both hits and evicts every step (the constrained-budget row)
    for frac in (0.0, 0.25, 0.75, 1.0):
        tree, store = install_expert_store(
            params, budget_bytes=int(frac * total))
        tree = assign_weight_modes(tree, mode="stream", min_bytes=1024)
        pre, dec, ttft, steps = _serve_timed(model, tree, pb, max_len,
                                             n_steps)
        tpot = float(np.median(steps))
        st = store.stats()
        bad = _mismatches((ref_pre, ref_dec), (pre, dec))
        hit_rate = st["hits"] / max(1, st["hits"] + st["misses"])
        # O(#buckets) dispatch contract: across the whole serve, the
        # batched fetches may not exceed plan_buckets dispatches each
        dispatch_ok = st["fetch_buckets"] <= st["fetches"] * plan_buckets
        rows.append((
            f"moe/cache{int(frac * 100)}/bs{batch}", tpot * 1e6,
            f"ttft_s={ttft:.4f};tpot_s={tpot:.4f};"
            f"p50_tpot_s={np.percentile(steps, 50):.4f};"
            f"p99_tpot_s={np.percentile(steps, 99):.4f};"
            f"tok_s={batch / tpot:.1f};"
            f"budget_bytes={store.budget_bytes};"
            f"hit_rate={hit_rate:.3f};hits={st['hits']};"
            f"misses={st['misses']};evictions={st['evictions']};"
            f"fetches={st['fetches']};buckets={st['fetch_buckets']};"
            f"plan_buckets={plan_buckets};"
            f"miss_decode_s={st['decode_s']:.4f};"
            f"parity_mismatches={bad};dispatch_ok={dispatch_ok}"))
        if bad:
            raise AssertionError(
                f"expert-cache serve at budget {frac:.0%} diverged from "
                f"dense: {bad} logit mismatches")
        if not dispatch_ok:
            raise AssertionError(
                f"fetch dispatches exceeded the bucket bound: "
                f"{st['fetch_buckets']} > {st['fetches']} * {plan_buckets}")

    for skew in ("uniform", "zipf"):
        rates = _routing_hit_rates(params, (0.25, 0.5, 1.0), skew=skew,
                                   steps=sim_steps, seed=7)
        derived = ";".join(f"hit_rate@{int(f * 100)}pct={r:.3f}"
                           for f, r in sorted(rates.items()))
        rows.append((f"moe/routing/{skew}", 0.0,
                     f"steps={sim_steps};{derived}"))
    return rows
