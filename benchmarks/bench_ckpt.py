"""ISSUE 3/4: checkpoint save/load throughput + restore wall clock +
decode dispatch accounting.

Measures the enec-v2 container against the v1-style dense-inflate restore:

  ckpt/save          blocking save() of a {"params", "opt"} training tree
                     (device-resident compression + threadpool pack writer)
  ckpt/load          dense training restore (bit-exact; ALL compressed
                     records decode in one batched pipeline pass —
                     O(#decoder buckets) decode dispatches, reported in
                     the derived column via decode_cache_stats)
  ckpt/restore_v1    the dense-inflate serving path the seed had: load()
                     the dense tree, then re-compress via
                     assign_weight_modes — the weight bytes cross the host
                     boundary dense and are encoded a second time
  ckpt/restore_v2    load_for_serving() on a serving-layout checkpoint:
                     framed records deserialize straight into weight
                     handles; only compressed bytes are staged to device
                     (zero decode dispatches when every layout matches)

The derived column carries the manifest ratio, the host->device bytes of
the v2 restore (wire.transfer_stats) — the quantity the paper says decides
fleet-scale restore time — and the decode dispatch/compile counters that
the bench-smoke CI job asserts never regress to per-record counts.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import Codec
from repro.models import build_model
from repro.runtime.streaming import assign_weight_modes


def _once(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)) if out is not None else None
    return time.perf_counter() - t0, out


def run():
    rows = []
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = jax.tree.map(lambda a: jnp.zeros_like(a), params)
    tree = {"params": params, "opt": {"m": opt}}
    raw_mb = sum(l.size * l.dtype.itemsize
                 for l in jax.tree.leaves(tree)) / 1e6

    # the bench's own codec: counters below are scoped to this instance
    codec = Codec()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, serving_layout="fused",
                                serving_min_bytes=1024, codec=codec)
        dt, _ = _once(lambda: mgr.save(1, tree, blocking=True))
        manifest = mgr.manifest()
        rows.append(("ckpt/save", dt * 1e6,
                     f"mb_s={raw_mb / dt:.1f};ratio={manifest['ratio']:.3f};"
                     f"packs={len(manifest['packs'])}"))

        n_records = len(manifest["leaves"])
        codec.reset_decode_cache_stats()
        dt, _ = _once(lambda: mgr.load(tree))
        st = codec.decode_cache_stats()
        # plan/execute cross-check: the loader's DecodePlan is the dispatch
        # count — the O(#buckets) restore guarantee as data, not folklore
        plan_buckets = len(mgr.last_decode_plan.buckets)
        assert st["dispatches"] == plan_buckets, (
            f"load dispatches {st['dispatches']} != plan buckets "
            f"{plan_buckets}")
        rows.append(("ckpt/load", dt * 1e6,
                     f"mb_s={raw_mb / dt:.1f};records={n_records};"
                     f"decode_dispatches={st['dispatches']};"
                     f"decode_compiles={st['compiles']};"
                     f"plan_buckets={plan_buckets}"))

        # v1-style dense-inflate restore-to-serve: dense load + re-compress
        dt, _ = _once(lambda: assign_weight_modes(
            mgr.load(tree)[0]["params"], mode="fused", min_bytes=1024,
            codec=codec))
        rows.append(("ckpt/restore_v1_dense_inflate", dt * 1e6,
                     f"s={dt:.3f}"))

        # v2 direct restore: records -> handles, compressed bytes only
        like = jax.eval_shape(model.init, jax.random.key(0))
        codec.reset_transfer_stats()
        codec.reset_decode_cache_stats()
        dt, _ = _once(lambda: mgr.load_for_serving(
            like, mode="fused", prefix="params", min_bytes=1024))
        ts = codec.transfer_stats()
        st = codec.decode_cache_stats()
        rows.append(("ckpt/restore_v2_to_handles", dt * 1e6,
                     f"s={dt:.3f};h2d_mb={ts['h2d_bytes'] / 1e6:.2f};"
                     f"dense_mb={raw_mb / 2:.2f};"
                     f"decode_dispatches={st['dispatches']}"))
    return rows
