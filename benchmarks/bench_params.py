"""Paper Table IV + §VI-D: searched parameters per model dataset, plus the
AE's 'Formula Avg CR' check (Eq. 4 prediction vs achieved)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (FORMATS, default_codec, expected_ratio,
                        search_for_array)
from repro.data.synthetic_weights import PAPER_MODELS, generate

from .common import time_fn


def run():
    rows = []
    for spec in PAPER_MODELS:
        x = generate(spec)
        fmt = FORMATS[spec.dtype]
        host = np.asarray(jax.device_get(x))
        t = time_fn(lambda: search_for_array(host, fmt), iters=1, warmup=0)
        p = search_for_array(host, fmt)
        ct = default_codec().compress_array(x, p)
        rows.append((f"table4/params/{spec.name}/{spec.dtype}", t * 1e6,
                     f"(b,n,m,L)={p.astuple()};formula_CR="
                     f"{expected_ratio(p, fmt):.3f};achieved_CR="
                     f"{ct.ratio():.3f}"))
        # beyond-paper: joint search (DESIGN.md §8)
        pj = search_for_array(host, fmt, mode="joint")
        ctj = default_codec().compress_array(x, pj)
        rows.append((f"table4/params_joint/{spec.name}", 0.0,
                     f"(b,n,m,L)={pj.astuple()};achieved_CR="
                     f"{ctj.ratio():.3f}"))
    return rows
