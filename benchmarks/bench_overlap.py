"""ISSUE 7: the decode-prefetch pipeline (runtime/overlap.py), measured.

Serves the reduced llama config in stream mode and times three things:

  decode_layer   one layer's batched prefetch decode (ONE exact-bucketed
                 dispatch set over every streamed leaf of the layer)
  matmul_layer   one layer's compute, proxied by dense-mode TPOT / n_layers
                 (the pipeline hides decode behind exactly this)
  tpot           steady-state decode-step TPOT with the pipeline off
                 (serial: every leaf decodes inside its layer) vs on

``efficiency`` is the fraction of the total per-step decode time the
pipeline actually recovered: ``(tpot_serial - tpot_overlap) / (P * decode)``.
On an async accelerator the ceiling is 1.0 (decode fully hidden behind
matmuls whenever decode <= matmul); on single-stream CPU the win comes from
the restructured dispatch itself — one exact-block batched decode per layer
instead of per-leaf bucket-padded decodes.  The measured config uses
``d_ff=640`` so each mlp leaf spans 5 codec blocks per layer: 5 sits
maximally off the pow2 bucket grid, so the serial path decodes 8 padded
blocks per leaf where the pipeline's exact plan decodes 5 — the padding
waste the prefetch provably avoids.  Logits with the pipeline on/off are
bit-identical (tests/test_overlap.py), so the two TPOT columns are
directly comparable, and CI gates on overlap <= serial.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.overlap import build_schedule, decode_layer
from repro.runtime.streaming import assign_weight_modes, stream_stats

from .common import time_fn


def run():
    rows = []
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4, d_ff=640)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch, prompt_len, max_len = 2, 16, 24
    pb = {"tokens": jax.random.randint(jax.random.key(1),
                                       (batch, prompt_len), 0,
                                       cfg.vocab_size)}
    P = cfg.n_layers

    tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                               shards=1)
    st = stream_stats(tree)
    dense = assign_weight_modes(params, mode="dense", min_bytes=1024,
                                shards=1)

    # one layer's batched prefetch decode, exactly as pipeline_scan issues it
    def dec(period):
        return decode_layer(build_schedule(period, P), 0)

    decode_s = time_fn(jax.jit(dec), tree["period"], iters=10)
    buckets = build_schedule(tree["period"], P).buckets_per_layer
    rows.append(("overlap/decode_layer", decode_s * 1e6,
                 f"decode_ms={decode_s * 1e3:.3f};"
                 f"buckets_per_layer={buckets};"
                 f"streamed={st['overlap_eligible_tensors']}"))

    def tpot_of(weights, overlap):
        m = build_model(dataclasses.replace(cfg, overlap=overlap))
        prefill = jax.jit(lambda p, b: m.prefill_fn(p, b, max_len))

        @jax.jit
        def decode_step(p, cache, tok):
            logits, cache = m.decode_fn(p, cache, tok)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        _, cache = prefill(weights, pb)
        tok = jnp.zeros((batch,), jnp.int32)
        return time_fn(lambda p, c, t: decode_step(p, c, t)[0],
                       weights, cache, tok, iters=20)

    tpot_dense = tpot_of(dense, "off")
    matmul_s = tpot_dense / P   # per-layer compute the pipeline hides behind
    rows.append(("overlap/matmul_layer", matmul_s * 1e6,
                 f"matmul_ms={matmul_s * 1e3:.3f};"
                 f"dense_tpot_s={tpot_dense:.4f};"
                 f"decode_over_matmul={decode_s / matmul_s:.3f}"))

    tpot_serial = tpot_of(tree, "off")
    tpot_overlap = tpot_of(tree, "on")
    hidden = tpot_serial - tpot_overlap
    efficiency = hidden / max(P * decode_s, 1e-12)
    rows.append(("overlap/tpot", tpot_overlap * 1e6,
                 f"tpot_serial_s={tpot_serial:.4f};"
                 f"tpot_overlap_s={tpot_overlap:.4f};"
                 f"decode_ms={decode_s * 1e3:.3f};"
                 f"matmul_ms={matmul_s * 1e3:.3f};"
                 f"efficiency={efficiency:.3f};"
                 f"speedup={tpot_serial / tpot_overlap:.3f}"))
    return rows
