"""Paper Fig. 9: compression/decompression throughput.

This container is CPU-only, so we report (a) measured CPU throughput of the
jit'd XLA codec, (b) the TPU-v5e roofline *projection* for the Pallas
kernels (bytes-moved / HBM bandwidth — the codec is elementwise/streamed,
so HBM bandwidth is the binding resource), and (c) baseline CPU codecs.
"""
from __future__ import annotations

import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BF16, FORMATS, codec, search_for_array
from repro.data.synthetic_weights import PAPER_MODELS, generate

from .common import time_fn, to_bytes

HBM_BW = 819e9


def _tpu_projection_gbps(fmt, p, n_elems=16384) -> tuple:
    """Roofline projection: bytes in + bytes out per block / HBM bw."""
    widths = codec.stream_shapes(n_elems, fmt, p)
    comp_bytes = sum(widths.values()) + 4
    raw_bytes = n_elems * fmt.total_bits // 8
    # encode: read raw, write streams; decode: read streams, write raw
    enc = raw_bytes + comp_bytes
    dec = comp_bytes + raw_bytes
    return (raw_bytes / enc * HBM_BW / 1e9, raw_bytes / dec * HBM_BW / 1e9)


def run():
    rows = []
    for spec in PAPER_MODELS[:5] + PAPER_MODELS[5:6] + PAPER_MODELS[7:8]:
        x = generate(spec)
        fmt = FORMATS[spec.dtype]
        host = np.asarray(jax.device_get(x))
        p = search_for_array(host, fmt)
        bits = codec.to_blocks(x, fmt)
        nbytes = host.nbytes

        enc = jax.jit(functools.partial(codec.encode_blocks, fmt=fmt, p=p))
        streams = enc(bits)
        t_enc = time_fn(enc, bits)
        dec = jax.jit(functools.partial(codec.decode_blocks,
                                        n_elems=bits.shape[1], fmt=fmt, p=p))
        t_dec = time_fn(dec, streams)
        proj_c, proj_d = _tpu_projection_gbps(fmt, p)
        rows.append((f"fig9/enec_cpu_comp/{spec.name}", t_enc * 1e6,
                     f"GBps={nbytes / t_enc / 1e9:.3f}"))
        rows.append((f"fig9/enec_cpu_decomp/{spec.name}", t_dec * 1e6,
                     f"GBps={nbytes / t_dec / 1e9:.3f}"))
        rows.append((f"fig9/enec_tpu_roofline_comp/{spec.name}", 0.0,
                     f"GBps={proj_c:.0f}"))
        rows.append((f"fig9/enec_tpu_roofline_decomp/{spec.name}", 0.0,
                     f"GBps={proj_d:.0f}"))
        # deflate CPU baseline
        raw = to_bytes(x)
        t_z = time_fn(lambda b: zlib.compress(b, 1), raw, iters=2, warmup=0)
        rows.append((f"fig9/deflate_cpu_comp/{spec.name}", t_z * 1e6,
                     f"GBps={len(raw) / t_z / 1e9:.4f}"))
    return rows
