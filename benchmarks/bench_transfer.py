"""Paper Table V (§VI-E): parameter transferability — apply the params
searched on the DeepSeek-like set to the other BF16 sets without re-tuning;
compression must stay lossless, ratio loss should be small."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import BF16, default_codec, search_for_array
from repro.data.synthetic_weights import PAPER_MODELS, generate


def run():
    rows = []
    source = next(s for s in PAPER_MODELS
                  if s.name == "deepseek-llm-7b-base")
    p_src = search_for_array(
        np.asarray(jax.device_get(generate(source))), BF16)
    for spec in PAPER_MODELS:
        if spec.dtype != "bf16" or spec.name == source.name:
            continue
        x = generate(spec)
        ct_t = default_codec().compress_array(x, p_src)  # transferred
        ct_o = default_codec().compress_array(x)   # optimal search
        y = default_codec().decompress_array(ct_t)
        lossless = bool((np.asarray(jax.device_get(x)).view(np.uint16)
                         == np.asarray(jax.device_get(y)).view(np.uint16)
                         ).all())
        assert lossless, spec.name
        drop = (ct_o.ratio() - ct_t.ratio()) / ct_o.ratio() * 100
        rows.append((f"table5/transfer/{spec.name}", 0.0,
                     f"transferred_CR={ct_t.ratio():.3f};optimal_CR="
                     f"{ct_o.ratio():.3f};drop_pct={drop:.1f};"
                     f"lossless={lossless}"))
    return rows
