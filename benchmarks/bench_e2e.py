"""Paper Fig. 10: end-to-end inference (TTFT / TPOT), dense vs
ENEC-streamed weights.

Two views:
 (a) measured, CPU smoke scale: serve a reduced llama config with batched
     requests, dense vs compressed-streamed weights (XLA decompresses
     layer-wise inside the step).  On CPU the decompression is pure
     overhead — there is no CPU->NPU link to win back — so this measures
     the overhead side of the trade.
 (b) derived, production scale: from the dry-run roofline of
     qwen3-32b x decode_32k, decode is HBM-bound on weight reads; ENEC
     residency divides the weight-read term by the measured ratio (Fig. 10's
     mechanism, one level down the hierarchy).  The paper's 4.1x/3.3x wins
     come from the much slower CPU<->NPU link; our derived win is the HBM
     figure for weights-fit-in-HBM serving.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.streaming import compress_params_for_streaming

from .common import time_fn

ROOFLINE = Path("results/roofline.json")
HBM_BW = 819e9


def run():
    rows = []
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)
    streamed = compress_params_for_streaming(params, min_bytes=1024, shards=2)

    for batch in (1, 4):
        pb = {"tokens": jax.random.randint(rng, (batch, 32), 0,
                                           cfg.vocab_size)}
        prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, 64))
        ttft_d = time_fn(prefill, params, pb, iters=3)
        ttft_s = time_fn(prefill, streamed, pb, iters=3)
        _, cache = prefill(params, pb)
        tok = jnp.zeros((batch,), jnp.int32)
        dec = jax.jit(lambda p, c, t: model.decode_fn(p, c, t))
        tpot_d = time_fn(dec, params, cache, tok, iters=5)
        tpot_s = time_fn(dec, streamed, cache, tok, iters=5)
        rows.append((f"fig10/smoke_ttft/bs{batch}", ttft_d * 1e6,
                     f"dense_s={ttft_d:.4f};streamed_s={ttft_s:.4f}"))
        rows.append((f"fig10/smoke_tpot/bs{batch}", tpot_d * 1e6,
                     f"dense_s={tpot_d:.4f};streamed_s={tpot_s:.4f}"))

    # (b) production-scale derived speedup from the dry-run roofline
    if ROOFLINE.exists():
        data = {(r.get("arch"), r.get("shape")): r
                for r in json.loads(ROOFLINE.read_text())}
        cell = data.get(("qwen3_32b", "decode_32k"))
        if cell and cell.get("status") == "ok":
            ratio = 1.35
            mem_s = cell["memory_s"]
            # weight bytes dominate decode HBM traffic; split via params
            wbytes = 2.0 * 32.8e9 / 256
            w_s = wbytes / HBM_BW
            mem_enec = mem_s - w_s + w_s / ratio
            rows.append(("fig10/derived_qwen3_32b_decode32k", 0.0,
                         f"memory_term_s={mem_s:.4e};"
                         f"with_enec_s={mem_enec:.4e};"
                         f"tpot_speedup={mem_s / mem_enec:.2f}x"))
    return rows
