"""Paper Fig. 11/12 + Table VI: block-size and input-size sweeps."""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.core import BF16, codec, default_codec, search_for_array
from repro.data.synthetic_weights import WeightSetSpec, generate

from .common import time_fn


def run():
    rows = []
    base = WeightSetSpec("deepseek-llm-7b-base", "bf16", 8 << 20, seed=3)
    x = generate(base)
    host = np.asarray(jax.device_get(x))

    # Fig 11: throughput of the codec vs data block size
    for block in (2048, 4096, 8192, 16384, 32768):
        p = search_for_array(host, BF16, block_elems=block)
        bits = codec.to_blocks(x, BF16, block)
        enc = jax.jit(functools.partial(codec.encode_blocks, fmt=BF16, p=p))
        t = time_fn(enc, bits, iters=3)
        ct = default_codec().compress_array(x, p, block_elems=block)
        rows.append((f"fig11/blocksize_{block}", t * 1e6,
                     f"GBps={host.nbytes / t / 1e9:.3f};"
                     f"ratio={ct.ratio():.3f}"))

    # Table VI: ratio vs input size (MB)
    for mb in (1, 2, 4, 8, 16):
        spec = dataclasses.replace(base, n_elems=mb << 19)  # bf16: 2 B/elem
        xi = generate(spec)
        ct = default_codec().compress_array(xi)
        rows.append((f"table6/input_{mb}MB", 0.0, f"ratio={ct.ratio():.3f}"))
    return rows
