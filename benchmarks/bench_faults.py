"""ISSUE 6: restore latency under injected fault rates.

Measures what reliability costs: the degraded-policy restore against the
same restore with transient I/O faults, an injected decode failure, and a
permanently corrupt on-disk record —

  faults/restore_clean       degraded-policy load_for_serving, no faults
                             (the policy's overhead when nothing is wrong:
                             quarantine list stays empty, dispatch counts
                             match the strict path)
  faults/restore_transient   every pack read fails twice then succeeds;
                             the retry/backoff policy absorbs it, the
                             derived column carries the attempt counters
  faults/restore_decode      one decode dispatch dies after the bytes
                             arrived intact; the record is quarantined and
                             restored from the previous step
  faults/restore_corrupt     one byte flipped inside a committed pack
                             record; CRC rejects it, the quarantine +
                             prior-step fallback restores through it

Two steps with identical params are saved so every fallback has an intact
source.  Each row asserts its expected quarantine count — the bench doubles
as a coarse fault-model regression check (the fine-grained one is
tests/test_faults.py; the CI job is fault-smoke).
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import Codec
from repro.models import build_model
from repro.runtime import faults as rt_faults
from repro.runtime.faults import FaultSpec


def _once(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)) if out is not None else None
    return time.perf_counter() - t0, out


def run():
    rows = []
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    like = jax.eval_shape(model.init, jax.random.key(0))

    codec = Codec()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, serving_layout="fused",
                                serving_min_bytes=1024, codec=codec)
        # identical params at both steps: any fallback is bit-identical
        mgr.save(1, {"params": params}, blocking=True)
        mgr.save(2, {"params": params}, blocking=True)

        def restore():
            return mgr.load_for_serving(like, mode="fused", prefix="params",
                                        min_bytes=1024, policy="degraded")

        mgr.retry.reset_stats()
        dt, _ = _once(restore)
        rep = mgr.last_restore_report
        assert not rep.degraded, rep.summary()
        rows.append(("faults/restore_clean", dt * 1e6,
                     f"s={dt:.3f};quarantined=0;"
                     f"io_attempts={rep.retry['attempts']}"))

        mgr.retry.reset_stats()
        with rt_faults.inject(FaultSpec(kind="read", match="pack-",
                                        times=2)):
            dt, _ = _once(restore)
        rep = mgr.last_restore_report
        assert not rep.degraded and rep.retry["retries"] == 2, rep.summary()
        rows.append(("faults/restore_transient_reads", dt * 1e6,
                     f"s={dt:.3f};quarantined=0;"
                     f"io_retries={rep.retry['retries']};"
                     f"io_attempts={rep.retry['attempts']}"))

        with rt_faults.inject(FaultSpec(kind="decode", times=1)):
            dt, _ = _once(restore)
        rep = mgr.last_restore_report
        assert len(rep.quarantined) == 1, rep.summary()
        rows.append(("faults/restore_decode_fault", dt * 1e6,
                     f"s={dt:.3f};quarantined=1;"
                     f"fallback={rep.quarantined[0].fallback!r}"))

        # permanent damage last: the byte flip outlives this row
        name, _, pos = rt_faults.flip_pack_byte(d, "", step=2)
        dt, _ = _once(restore)
        rep = mgr.last_restore_report
        assert [q.name for q in rep.quarantined] == [name], rep.summary()
        assert rep.quarantined[0].fallback, rep.summary()
        rows.append(("faults/restore_1_corrupt", dt * 1e6,
                     f"s={dt:.3f};quarantined=1;record={name!r};"
                     f"byte={pos};"
                     f"fallback={rep.quarantined[0].fallback!r}"))
    return rows
