"""ISSUE 9: serving under load — Poisson traffic against the engine.

Drives the continuous-batching engine (runtime/engine.py) with seeded
Poisson arrivals at two operating points per weight-execution mode:
*unloaded* (~0.4x the measured service capacity) and *overloaded* (~3x
capacity).  Reports served tok/s vs offered load, p50/p99 TTFT and TPOT
over completed requests, and the shed/evicted/timed-out/rejected counts
that show WHERE the excess load went.

The run self-asserts the robustness acceptance criteria:

* queue depth stays bounded at its cap (backpressure, not buffering);
* the overloaded point sheds a nonzero amount of work (load shedding is
  doing the protecting);
* p99 TPOT of ADMITTED requests under overload stays within 1.5x the
  unloaded baseline (the worse of the low-rate Poisson run and a
  saturated-ring run, since a full decode bucket inherently costs more
  per step than an idle ring on CPU) — admission degrades,
  admitted-request latency does not;
* every completed request's logits are bit-identical to the one-shot
  serve path (``parity_mismatch=0``);
* no admitted-and-completed request misses its total deadline
  (``deadline_miss=0`` — the engine accounts late finishes as
  ``timed_out``, so this holds by construction).

The CI ``traffic-smoke`` job gates on the last two fields.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.admission import AdmissionQueue, OverloadGovernor
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.streaming import assign_weight_modes

PROMPT_LEN = 8
N_NEW = 4
N_PROMPTS = 3          # distinct prompts cycled through the traffic


def _smoke() -> bool:
    return os.environ.get("BENCH_SMOKE", "").strip().lower() \
        not in ("", "0", "false", "no", "off")


def _one_shot(model, tree, prompt, max_len):
    logits, cache = model.prefill_fn(tree, {"tokens": prompt[None, :]},
                                     max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks, outs = [int(np.asarray(tok)[0])], [np.asarray(logits)[0]]
    for _ in range(N_NEW - 1):
        logits, cache = model.decode_fn(tree, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(np.asarray(tok)[0]))
        outs.append(np.asarray(logits)[0])
    return toks, outs


def _reset_run_state(engine):
    """Fresh per-run counters WITHOUT dropping the warm jit caches."""
    engine.queue = AdmissionQueue(engine.config.queue_depth)
    engine.governor = OverloadGovernor(
        watchdog_s=engine.config.watchdog_s,
        overload_factor=engine.config.overload_factor,
        warmup_steps=engine.config.warmup_steps,
        recovery_steps=engine.config.recovery_steps)


def _warmup(engine, prompts):
    """Compile every prefill/install/step-bucket variant before anything
    is timed (first-call compile spikes would otherwise dominate p99)."""
    engine.submit(prompts[0], N_NEW, name="warm0")
    engine.step()                      # bucket 1
    engine.submit(prompts[1], N_NEW, name="warm1")
    engine.step()                      # bucket 2
    for i in range(2, engine.config.max_slots):
        engine.submit(prompts[i % N_PROMPTS], N_NEW, name=f"warm{i}")
    engine.run_until_idle()            # bucket max_slots
    _reset_run_state(engine)


def _calibrate(engine, prompts):
    """Measured service capacity (requests/s) with the ring kept full.
    Also returns the saturated-ring p99 TPOT: under overload the decode
    bucket is always full, so THIS (not a mostly-idle ring, whose smaller
    buckets cost less per step on CPU) is the fair latency baseline for
    admitted requests."""
    t0 = time.perf_counter()
    n = 2 * engine.config.max_slots
    reqs = [engine.submit(prompts[i % N_PROMPTS], N_NEW, name=f"cal{i}")
            for i in range(n)]
    engine.run_until_idle()
    rate = n / (time.perf_counter() - t0)
    tpots = [r.tpot_s() for r in reqs if r.tpot_s() is not None]
    _reset_run_state(engine)
    return rate, _pct(tpots, 99) * 1e3


def _drive(engine, prompts, arrivals, *, ttft_deadline_s, deadline_s):
    """Submit at the scheduled (relative) arrival times; step whenever the
    engine has work, sleep to the next arrival otherwise."""
    reqs = []
    start = time.monotonic()
    i = 0
    while i < len(arrivals) or engine.has_work():
        now = time.monotonic() - start
        while i < len(arrivals) and arrivals[i] <= now:
            req = engine.submit(prompts[i % N_PROMPTS], N_NEW,
                                ttft_deadline_s=ttft_deadline_s,
                                deadline_s=deadline_s, name=f"traffic{i}")
            req.prompt_idx = i % N_PROMPTS
            reqs.append(req)
            i += 1
        if engine.has_work():
            engine.step()
        elif i < len(arrivals):
            time.sleep(max(0.0, min(arrivals[i] - now, 0.01)))
    return reqs


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals), q)) if vals else 0.0


def _run_load(engine, prompts, refs, *, rate_rps, n_requests, seed,
              ttft_deadline_s, deadline_s):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    t0 = time.perf_counter()
    reqs = _drive(engine, prompts, arrivals,
                  ttft_deadline_s=ttft_deadline_s, deadline_s=deadline_s)
    wall = time.perf_counter() - t0

    done = [r for r in reqs if r.state == "done"]
    by_state = {s: sum(1 for r in reqs if r.state == s)
                for s in ("done", "timed_out", "rejected", "shed",
                          "evicted")}
    ttfts = [r.ttft_s() for r in done if r.ttft_s() is not None]
    tpots = [r.tpot_s() for r in done if r.tpot_s() is not None]
    # honest-accounting gate: a "done" request finished within deadline by
    # construction (late finishes are accounted timed_out) — assert anyway
    deadline_miss = sum(
        1 for r in done
        if r.deadline_s is not None and r.finish_s > r.deadline_s)
    parity_mismatch = 0
    for r in done:
        ref_toks, ref_logits = refs[r.prompt_idx]
        if r.tokens != ref_toks or len(r.logits) != len(ref_logits) or any(
                not np.array_equal(np.asarray(g).view(np.uint32),
                                   np.asarray(e).view(np.uint32))
                for g, e in zip(r.logits, ref_logits)):
            parity_mismatch += 1
    return {
        "offered_rps": rate_rps,
        "wall_s": wall,
        "tok_s": sum(len(r.tokens) for r in done) / wall,
        "p50_ttft_ms": _pct(ttfts, 50) * 1e3,
        "p99_ttft_ms": _pct(ttfts, 99) * 1e3,
        "p50_tpot_ms": _pct(tpots, 50) * 1e3,
        "p99_tpot_ms": _pct(tpots, 99) * 1e3,
        "max_queue_depth": engine.queue.max_depth_seen,
        "queue_cap": engine.queue.depth,
        "deadline_miss": deadline_miss,
        "parity_mismatch": parity_mismatch,
        **by_state,
    }


def _derived(m, extra=""):
    s = (f"offered_rps={m['offered_rps']:.2f};tok_s={m['tok_s']:.1f};"
         f"p50_ttft_ms={m['p50_ttft_ms']:.1f};"
         f"p99_ttft_ms={m['p99_ttft_ms']:.1f};"
         f"p50_tpot_ms={m['p50_tpot_ms']:.1f};"
         f"p99_tpot_ms={m['p99_tpot_ms']:.1f};"
         f"done={m['done']};shed={m['shed']};evicted={m['evicted']};"
         f"timed_out={m['timed_out']};rejected={m['rejected']};"
         f"max_queue_depth={m['max_queue_depth']};"
         f"queue_cap={m['queue_cap']};"
         f"deadline_miss={m['deadline_miss']};"
         f"parity_mismatch={m['parity_mismatch']}")
    return s + extra


def run():
    smoke = _smoke()
    # the overload burst must decisively exceed what the queue + slot ring
    # can buffer (queue_depth + max_slots = 12), or a fast drain absorbs
    # it without shedding and the admission-control assert gets flaky
    n_unloaded = 6 if smoke else 16
    n_overload = 24 if smoke else 48
    rows = []
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    if not smoke:
        cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (N_PROMPTS, PROMPT_LEN), 0, cfg.vocab_size),
        np.int32)
    ecfg = EngineConfig(max_slots=4, queue_depth=8,
                        max_prompt_len=PROMPT_LEN, max_new_tokens=N_NEW,
                        collect_logits=True)
    for mode in ("dense", "stream", "fused"):
        tree = assign_weight_modes(params, mode=mode, min_bytes=1024,
                                   shards=2)
        refs = [_one_shot(model, tree, prompts[i], ecfg.max_len)
                for i in range(N_PROMPTS)]
        engine = Engine(model, tree, ecfg)
        _warmup(engine, prompts)
        capacity_rps, saturated_p99_tpot_ms = _calibrate(engine, prompts)
        service_s = 1.0 / capacity_rps

        unloaded = _run_load(
            engine, prompts, refs, rate_rps=0.4 * capacity_rps,
            n_requests=n_unloaded, seed=0,
            # generous deadlines: the unloaded point should shed nothing
            ttft_deadline_s=300.0, deadline_s=600.0)
        _reset_run_state(engine)
        overload = _run_load(
            engine, prompts, refs, rate_rps=3.0 * capacity_rps,
            n_requests=n_overload, seed=1,
            # TTFT deadline a few service times out: queued work that
            # cannot start soon is shed before it wastes a prefill; the
            # total deadline stays generous so admitted work completes
            ttft_deadline_s=6.0 * service_s, deadline_s=600.0)
        _reset_run_state(engine)

        # the latency baseline is the WORSE of the unloaded-Poisson and
        # saturated-ring p99: overload always decodes full buckets, and a
        # full bucket costs more per step than a near-empty one on CPU —
        # that's batching cost, not overload-induced degradation
        base_p99 = max(unloaded["p99_tpot_ms"], saturated_p99_tpot_ms)
        ratio = overload["p99_tpot_ms"] / base_p99 if base_p99 else 0.0
        for m in (unloaded, overload):
            assert m["max_queue_depth"] <= m["queue_cap"], \
                f"{mode}: queue depth {m['max_queue_depth']} exceeded cap"
            assert m["parity_mismatch"] == 0, \
                f"{mode}: {m['parity_mismatch']} completed request(s) " \
                f"diverged from the one-shot logits"
            assert m["deadline_miss"] == 0, \
                f"{mode}: {m['deadline_miss']} done request(s) past deadline"
        turned_away = overload["shed"] + overload["rejected"]
        assert turned_away > 0, \
            f"{mode}: 2.5x overload shed/rejected nothing — admission " \
            f"control is not engaging"
        assert ratio <= 1.5, \
            f"{mode}: overload p99 TPOT {overload['p99_tpot_ms']:.1f}ms is " \
            f"{ratio:.2f}x unloaded — admitted-request latency degraded"

        rows.append((f"traffic/{mode}/unloaded",
                     unloaded["p50_tpot_ms"] * 1e3, _derived(unloaded)))
        rows.append((f"traffic/{mode}/overload",
                     overload["p50_tpot_ms"] * 1e3,
                     _derived(overload,
                              f";tpot_p99_ratio={ratio:.3f};"
                              f"capacity_rps={capacity_rps:.2f}")))
        engine.shutdown()
    return rows
