"""Elastic mesh selection (runtime/elastic.py): grid factorization over
awkward survivor counts, model-axis divisibility against the arch's
TP-sharded dims, and the single-device floor."""
import dataclasses

import pytest

import repro.runtime.elastic as elastic
from repro.runtime.elastic import best_mesh_for, candidate_grids


def test_candidate_grids_power_of_two():
    assert candidate_grids(16) == [(1, 16), (2, 8), (4, 4), (8, 2), (16, 1)]


def test_candidate_grids_non_power_of_two_counts():
    # survivor counts after a node loss are rarely powers of two: only the
    # model widths that still divide the count may appear
    assert candidate_grids(12) == [(3, 4), (6, 2), (12, 1)]
    assert candidate_grids(6) == [(3, 2), (6, 1)]
    assert candidate_grids(7) == [(7, 1)]       # prime: data-parallel only
    assert candidate_grids(10) == [(5, 2), (10, 1)]


def test_candidate_grids_max_model_caps_width():
    assert candidate_grids(32, max_model=4) == [(8, 4), (16, 2), (32, 1)]
    assert candidate_grids(8, max_model=1) == [(8, 1)]


def test_candidate_grids_single_device_floor():
    assert candidate_grids(1) == [(1, 1)]


@dataclasses.dataclass
class _Cfg:
    """Just the fields best_mesh_for consults (duck-typed like configs)."""
    n_heads: int
    head_dim: int
    d_ff: int
    n_experts: int = 0

    def head_dim_(self):
        return self.head_dim


@pytest.fixture
def captured_mesh(monkeypatch):
    """best_mesh_for builds a real jax mesh; capture the (shape, axes)
    request instead so the selection logic is testable on any host."""
    monkeypatch.setattr(elastic, "make_mesh",
                        lambda shape, axes: (tuple(shape), tuple(axes)))


def test_best_mesh_takes_widest_divisible_model_axis(captured_mesh):
    cfg = _Cfg(n_heads=8, head_dim=8, d_ff=256)
    assert best_mesh_for(cfg, n_devices=8) == ((1, 8), ("data", "model"))


def test_best_mesh_ffn_indivisibility_narrows_model_axis(captured_mesh):
    # d_ff=4 rejects model=8; model=4 divides heads (64) and ffn (4)
    cfg = _Cfg(n_heads=8, head_dim=8, d_ff=4)
    assert best_mesh_for(cfg, n_devices=8) == ((2, 4), ("data", "model"))


def test_best_mesh_head_indivisibility_narrows_model_axis(captured_mesh):
    # hd_total=6 rejects model 8 and 4; model=2 divides 6 and d_ff
    cfg = _Cfg(n_heads=3, head_dim=2, d_ff=64)
    assert best_mesh_for(cfg, n_devices=8) == ((4, 2), ("data", "model"))


def test_best_mesh_degenerates_to_model_1(captured_mesh):
    # odd hd_total and d_ff: nothing >1 divides, model=1 always does
    cfg = _Cfg(n_heads=3, head_dim=1, d_ff=3)
    assert best_mesh_for(cfg, n_devices=8) == ((8, 1), ("data", "model"))


def test_best_mesh_expert_count_constrains_model_axis(captured_mesh):
    cfg = _Cfg(n_heads=8, head_dim=8, d_ff=256, n_experts=2)
    assert best_mesh_for(cfg, n_devices=8) == ((4, 2), ("data", "model"))


def test_best_mesh_non_power_of_two_devices(captured_mesh):
    cfg = _Cfg(n_heads=4, head_dim=4, d_ff=32)
    assert best_mesh_for(cfg, n_devices=6) == ((3, 2), ("data", "model"))
    assert best_mesh_for(cfg, n_devices=7) == ((7, 1), ("data", "model"))


def test_best_mesh_single_device_floor(captured_mesh):
    cfg = _Cfg(n_heads=8, head_dim=8, d_ff=256)
    assert best_mesh_for(cfg, n_devices=1) == ((1, 1), ("data", "model"))


def test_best_mesh_uses_real_devices_by_default():
    # no n_devices: the live jax device count (1 on the CPU test host)
    cfg = _Cfg(n_heads=8, head_dim=8, d_ff=256)
    mesh = best_mesh_for(cfg)
    assert mesh.devices.size == 1
