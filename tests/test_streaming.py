"""Weight-streaming serving: ENEC-compressed weights in the serve step must
be bit-identical to dense serving (lossless end to end)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.streaming import (compress_params_for_streaming,
                                     stream_stats)


@pytest.mark.parametrize("arch,scan", [("qwen3_32b", True),
                                       ("qwen3_32b", False),
                                       ("phi3_5_moe_42b_a6_6b", False)])
def test_streamed_serve_bit_identical(arch, scan):
    cfg = dataclasses.replace(get_smoke_config(arch), scan_layers=scan)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)
    streamed = compress_params_for_streaming(params, min_bytes=1024, shards=2)
    B, T = 2, 16
    pb = {"tokens": jax.random.randint(rng, (B, T), 0, cfg.vocab_size)}
    l_ref, c_ref = model.prefill_fn(params, pb, 32)
    l_str, c_str = model.prefill_fn(streamed, pb, 32)
    assert float(jnp.abs(l_ref - l_str).max()) == 0.0
    tok = jnp.argmax(l_ref, -1).astype(jnp.int32)
    d_ref, _ = model.decode_fn(params, c_ref, tok)
    d_str, _ = model.decode_fn(streamed, c_str, tok)
    assert float(jnp.abs(d_ref - d_str).max()) == 0.0


def test_stream_stats_accounting():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    streamed = compress_params_for_streaming(params, min_bytes=1024, shards=2)
    st = stream_stats(streamed)
    assert st["streamed_tensors"] >= 3
    assert st["device_bytes"] <= st["raw_bytes"]


def test_small_leaves_stay_raw():
    cfg = get_smoke_config("qwen3_32b")
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    streamed = compress_params_for_streaming(params)  # default 1MiB floor
    # smoke model is tiny: nothing should be streamed, tree unchanged
    assert stream_stats(streamed)["streamed_tensors"] == 0
