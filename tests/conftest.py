# NOTE: do NOT set --xla_force_host_platform_device_count here.
# Smoke tests and benches must see 1 device; only launch/dryrun.py forces
# 512. Multi-device tests spawn subprocesses with their own XLA_FLAGS.
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _restore_default_codec():
    """Backend/config changes must not leak across tests.

    The legacy ``set_encode_backend`` / ``set_decode_backend`` wrappers
    mutate the process-default Codec's config; before this fixture they
    mutated process globals with no reset, so one test switching to the
    Pallas backend silently changed every later test.  Snapshot the default
    codec and its config, and restore both afterwards (``configure`` only
    clears compile caches when the config actually changed, so the common
    no-op path keeps caches warm)."""
    from repro.core import codec_api
    codec = codec_api.default_codec()
    config = codec.config
    yield
    codec_api.set_default_codec(codec)
    codec.configure(config)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_realistic_bf16(n, seed=0, outlier_frac=2e-3):
    """Trained-LLM-like weights (paper §III statistics)."""
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    w = r.standard_normal(n) * 0.015
    w[r.random(n) < outlier_frac] *= 64.0
    return jnp.asarray(w.astype("float32")).astype(jnp.bfloat16)
