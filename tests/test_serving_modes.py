"""ISSUE 2: the weight-execution policy behind the unified decode path.

dense / stream / fused serving must produce BIT-IDENTICAL logits (every
mode's matmul realizes the canonical tiled contraction of
``kernels.ref.tiled_matmul_ref``); fused tile compression must ride the
batched pipeline (one encode dispatch per encoder bucket, verified via
``encode_cache_stats``); handles must materialize bit-exactly; and the
abstract (dry-run) streaming path must agree with the concrete one on
which leaves stream (the shared-eligibility dedupe).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import api as enec_api
from repro.core.params import EnecParams
from repro.models import build_model
from repro.runtime.streaming import (MATMUL_LEAF_NAMES, WEIGHT_MODES,
                                     abstract_streamed_params,
                                     assign_weight_modes,
                                     compress_params_for_streaming,
                                     decompress_sliced, stream_stats)
from repro.runtime.weights import (DenseWeight, FusedWeight, StreamedWeight,
                                   WeightHandle, is_handle, resolve)


def _u32(x):
    return np.asarray(jax.device_get(x)).view(np.uint32)


def _flat_named(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_handle)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "name",
                        getattr(k, "idx", k)))) for k in path)
        out.append((pstr, leaf))
    return out


def _serve(model, tree, pb, max_len):
    logits, cache = model.prefill_fn(tree, pb, max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec, _ = model.decode_fn(tree, cache, tok)
    return np.asarray(logits), np.asarray(dec)


@pytest.mark.parametrize("scan", [True, False])
def test_three_mode_logits_bit_parity(scan):
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=scan)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 12), 0,
                                       cfg.vocab_size)}
    outs = {m: _serve(model, assign_weight_modes(params, mode=m,
                                                 min_bytes=1024, shards=2),
                      pb, 24)
            for m in WEIGHT_MODES}
    for mode in ("stream", "fused"):
        for ref_l, got_l in zip(outs["dense"], outs[mode]):
            np.testing.assert_array_equal(ref_l.view(np.uint32),
                                          got_l.view(np.uint32),
                                          err_msg=mode)


def test_moe_fused_mode_parity_and_streamed_experts():
    cfg = get_smoke_config("phi3_5_moe_42b_a6_6b")
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    pb = {"tokens": jax.random.randint(jax.random.key(3), (2, 8), 0,
                                       cfg.vocab_size)}
    fused = assign_weight_modes(params, mode="fused", min_bytes=1024)
    # expert stacks are 3-D per layer: they stream (materialize), not fuse
    kinds = {pstr.rsplit("/", 1)[-1]: type(leaf)
             for pstr, leaf in _flat_named(fused) if is_handle(leaf)}
    assert kinds.get("e_gate", StreamedWeight) is StreamedWeight
    assert any(t is FusedWeight for t in kinds.values())
    ref_out = _serve(model, assign_weight_modes(params, mode="dense",
                                                min_bytes=1024), pb, 16)
    got_out = _serve(model, fused, pb, 16)
    for a, b in zip(ref_out, got_out):
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


def test_fused_assignment_and_fallback_types():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode="fused", min_bytes=1024)
    st = stream_stats(tree)
    assert st["fused_tensors"] >= 3
    for pstr, leaf in _flat_named(tree):
        name = pstr.rsplit("/", 1)[-1]
        if name in MATMUL_LEAF_NAMES:
            # matmul positions are ALWAYS handles (fused, or the dense
            # fallback when tiles don't beat raw bytes) so the executor —
            # and the logits — never depend on compressibility
            assert isinstance(leaf, (FusedWeight, DenseWeight)), pstr
        else:
            assert not isinstance(leaf, (FusedWeight, DenseWeight)), pstr


def test_fused_policy_batches_encode_dispatches():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    enec_api.reset_encode_cache_stats()
    tree = assign_weight_modes(params, mode="fused", min_bytes=1024)
    st = enec_api.encode_cache_stats()
    handles = [leaf for _, leaf in _flat_named(tree)
               if isinstance(leaf, (FusedWeight, StreamedWeight))]
    assert len(handles) >= 3
    # every eligible leaf went through compression (fallbacks included), yet
    # encodes batch into one dispatch per encoder bucket (fmt, params-key,
    # block_elems) — never one per tensor, never one per layer
    n_eligible = sum(1 for _, leaf in _flat_named(tree) if is_handle(leaf))
    buckets = {enec_api._encoder_key(h.ct.fmt_name, h.ct.params,
                                     h.ct.block_elems) for h in handles}
    assert len(buckets) <= st["dispatches"] <= n_eligible
    assert st["dispatches"] < n_eligible * cfg.n_layers


def test_handles_materialize_bit_exact():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    orig = dict(_flat_named(params))
    for mode in ("stream", "fused"):
        tree = assign_weight_modes(params, mode=mode, min_bytes=1024,
                                   shards=2)
        for pstr, leaf in _flat_named(tree):
            if not is_handle(leaf):
                continue
            ref_leaf = orig[pstr]
            if getattr(leaf, "flat", False):
                # 2-D leaf stored as an L=1 stack: never sliced by the
                # layer loop, materializes whole
                got = jax.tree.map(lambda a: a[0], leaf).materialize()
                np.testing.assert_array_equal(
                    np.asarray(got).view(np.uint8),
                    np.asarray(ref_leaf).view(np.uint8),
                    err_msg=f"{mode}:{pstr}")
                continue
            for i in range(ref_leaf.shape[0]):   # per layer slice
                sliced = jax.tree.map(lambda a: a[i], leaf)
                got = sliced.materialize()
                np.testing.assert_array_equal(
                    np.asarray(got).view(np.uint8),
                    np.asarray(ref_leaf[i]).view(np.uint8),
                    err_msg=f"{mode}:{pstr}[{i}]")


def test_resolve_materializes_storage_handles_only():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    streamed = compress_params_for_streaming(params, min_bytes=1024,
                                             shards=2)
    sliced = jax.tree.map(lambda a: a[0], streamed["period"])
    resolved = resolve(sliced)
    assert not any(is_handle(leaf) for _, leaf in _flat_named(resolved))
    # decompress_sliced is the legacy alias of resolve
    alias = decompress_sliced(sliced)
    for (_, a), (_, b) in zip(_flat_named(resolved), _flat_named(alias)):
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                      np.asarray(b).view(np.uint8))
    # matmul-capable handles pass through untouched
    fused = assign_weight_modes(params, mode="fused", min_bytes=1024)
    kept = resolve(jax.tree.map(lambda a: a[0], fused["period"]))
    assert any(isinstance(leaf, WeightHandle)
               for _, leaf in _flat_named(kept))


def test_abstract_streaming_agrees_with_concrete():
    """The shared eligibility predicate: every leaf the concrete policy
    streams must also stream in the abstract (dry-run) tree."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    concrete = compress_params_for_streaming(params, min_bytes=1024,
                                             shards=2)
    p = EnecParams(b=122, n=6, m=3, L=16, l=96)
    abstract = abstract_streamed_params(cfg, p, min_bytes=1024, shards=2)
    conc = {pstr for pstr, leaf in _flat_named(concrete)
            if isinstance(leaf, StreamedWeight)}
    abst = {pstr for pstr, leaf in _flat_named(abstract)
            if isinstance(leaf, StreamedWeight)}
    assert conc and conc <= abst


def test_unknown_mode_rejected():
    with pytest.raises(ValueError):
        assign_weight_modes({}, mode="turbo")
