"""Device-resident batched compression pipeline (ISSUE 1 tentpole).

Covers: device-side statistics vs the numpy reference, stacked single-
dispatch encode bit-exactness vs the per-layer path, encoder compile-cache
bucketing, the shards-padding branch, dispatch/transfer accounting for
``compress_params_for_streaming`` (no full-tensor ``device_get``, one encode
dispatch per layer-stack), and Pallas-backend parity for the stacked path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_realistic_bf16
from repro.core import api as enec_api
from repro.core import params as params_mod
from repro.core import stats as stats_mod
from repro.core.dtypes import BF16, FORMATS, format_for


def _bits(x):
    dt = np.uint16 if x.dtype != jnp.float32 else np.uint32
    return np.asarray(jax.device_get(x)).view(dt)


def _make_stack(n_layers=4, per_layer=160_000, shape=(400, 400)):
    xs = jnp.stack([make_realistic_bf16(per_layer, seed=i)
                    for i in range(n_layers)])
    return xs.reshape((n_layers,) + shape)


class _DeviceGetSpy:
    """Wraps jax.device_get, recording the byte size of every transfer."""

    def __init__(self):
        self.real = jax.device_get
        self.calls = []

    def __call__(self, tree):
        nbytes = sum(getattr(l, "nbytes", 0)
                     for l in jax.tree_util.tree_leaves(tree))
        self.calls.append(nbytes)
        return self.real(tree)


# ---------------------------------------------------------------------------
# device-side statistics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16, jnp.float32])
def test_device_histogram_matches_numpy(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.standard_normal(50_000) * 0.02).astype("float32")
                    ).astype(dtype)
    fmt = format_for(dtype)
    host_bits = np.asarray(jax.device_get(x)).view(fmt.np_uint_dtype)
    exp = (host_bits >> fmt.mant_bits) & fmt.exp_mask
    ref = params_mod.exponent_histogram(exp, fmt.exp_bits)
    dev = np.asarray(jax.device_get(
        stats_mod.exponent_histogram_device(x, fmt)))
    np.testing.assert_array_equal(ref, dev)


def test_stack_stats_const_flags_and_bounds():
    a = make_realistic_bf16(4096, seed=1)
    c = jnp.full((4096,), 0.5, jnp.bfloat16)
    stack = jnp.stack([a, c])
    st = stats_mod.stack_stats(stack.reshape(2, -1).view(jnp.uint16), BF16)
    assert list(st.is_const) == [False, True]
    l, h = st.bounds()
    host_exp = (_bits(stack).reshape(-1) >> 7) & 0xFF
    assert (l, h) == (int(host_exp.min()), int(host_exp.max()))
    assert int(st.first[1]) == int(_bits(c)[0])


# ---------------------------------------------------------------------------
# stacked encode: bit-exactness + single dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2])
def test_stacked_encode_bit_identical_to_per_layer(shards):
    xs = _make_stack()
    p = params_mod.search_for_array(np.asarray(jax.device_get(xs)), BF16)
    enec_api.reset_encode_cache_stats()
    ct = enec_api.compress_stacked(xs, p, shards=shards)
    assert enec_api.encode_cache_stats()["dispatches"] == 1
    assert ct is not None and ct.mode == "enec"
    for i in range(xs.shape[0]):
        ref = enec_api.compress_array(xs[i], p, shards=shards)
        assert ref.mode == "enec"
        got = enec_api.slice_stacked(ct, i)
        assert got.params == ref.params
        for name in ("mask", "low", "high", "high_len", "raw"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got.streams, name)),
                np.asarray(getattr(ref.streams, name)), err_msg=name)
    out = enec_api.decompress_stacked(ct)
    assert out.shape == xs.shape and out.dtype == xs.dtype
    np.testing.assert_array_equal(_bits(xs), _bits(out))


def test_stacked_search_matches_host_search():
    # small enough that the device histogram stride stays 1 (exact), so the
    # searched params must match the host reference bit-for-bit
    xs = _make_stack(n_layers=3, per_layer=32_768, shape=(128, 256))
    assert xs.size // stats_mod.HIST_SAMPLE_CAP <= 1
    p_host = params_mod.search_for_array(np.asarray(jax.device_get(xs)), BF16)
    ct = enec_api.compress_stacked(xs)
    assert ct.params == p_host


def test_stacked_const_layer_falls_back():
    a = make_realistic_bf16(50_000, seed=3)
    stack = jnp.stack([a, jnp.zeros_like(a)])
    assert enec_api.compress_stacked(stack) is None


def test_compress_stacked_many_groups_share_one_dispatch():
    p = params_mod.search_for_array(
        np.asarray(jax.device_get(make_realistic_bf16(100_000))), BF16)
    stacks = [_make_stack(2, 160_000, (400, 400)),
              _make_stack(3, 160_000, (400, 400))]
    enec_api.reset_encode_cache_stats()
    cts = enec_api.compress_stacked_many(stacks, p=p)
    # same (fmt, params, block_elems) bucket -> one concatenated encode
    assert enec_api.encode_cache_stats()["dispatches"] == 1
    for x, ct in zip(stacks, cts):
        np.testing.assert_array_equal(
            _bits(x), _bits(enec_api.decompress_stacked(ct)))


# ---------------------------------------------------------------------------
# compile-cache hygiene
# ---------------------------------------------------------------------------

def test_encoder_cache_buckets_block_counts():
    p = params_mod.search_for_array(
        np.asarray(jax.device_get(make_realistic_bf16(100_000))), BF16)
    enec_api.reset_encode_cache_stats(clear_cache=True)
    enec_api.compress_array(make_realistic_bf16(3 * 16384, seed=1), p)
    enec_api.compress_array(make_realistic_bf16(4 * 16384, seed=2), p)
    st = enec_api.encode_cache_stats()
    # 3 blocks buckets up to 4: both tensors share one compiled encoder
    assert st["compiles"] == 1 and st["dispatches"] == 2, st
    assert st["cache_hits"] == 1 and st["padded_blocks"] == 1


def test_bucketed_encode_slices_padding_away():
    p = params_mod.search_for_array(
        np.asarray(jax.device_get(make_realistic_bf16(100_000))), BF16)
    x = make_realistic_bf16(5 * 16384, seed=4)   # 5 blocks -> bucket 8
    ct = enec_api.compress_array(x, p)
    assert ct.streams.mask.shape[0] == 5
    np.testing.assert_array_equal(_bits(x), _bits(enec_api.decompress_array(ct)))


# ---------------------------------------------------------------------------
# shards padding branch (previously untested)
# ---------------------------------------------------------------------------

def test_shards_padding_roundtrip():
    x = make_realistic_bf16(7 * 16384, seed=12)   # 7 blocks -> pad to 8
    ct = enec_api.compress_array(x, shards=4)
    assert ct.mode == "enec"
    assert ct.streams.mask.shape[:2] == (4, 2)
    np.testing.assert_array_equal(_bits(x), _bits(enec_api.decompress_array(ct)))


def test_stacked_shards_padding_matches_per_layer():
    xs = _make_stack(n_layers=3, per_layer=3 * 16384 + 1000, shape=(50152,))
    p = params_mod.search_for_array(np.asarray(jax.device_get(xs)), BF16)
    ct = enec_api.compress_stacked(xs, p, shards=2)
    for i in range(3):
        ref = enec_api.compress_array(xs[i], p, shards=2)
        got = enec_api.slice_stacked(ct, i)
        for name in ("mask", "low", "high", "high_len", "raw"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got.streams, name)),
                np.asarray(getattr(ref.streams, name)), err_msg=name)


# ---------------------------------------------------------------------------
# dispatch / transfer accounting on a real model tree
# ---------------------------------------------------------------------------

def test_streaming_is_batched_and_device_resident(monkeypatch):
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.runtime.streaming import (compress_params_for_streaming,
                                         stream_stats)

    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    spy = _DeviceGetSpy()
    monkeypatch.setattr(jax, "device_get", spy)
    enec_api.reset_encode_cache_stats()
    streamed = compress_params_for_streaming(params, min_bytes=1024, shards=2)
    monkeypatch.undo()

    n_streamed = stream_stats(streamed)["streamed_tensors"]
    assert n_streamed >= 3
    st = enec_api.encode_cache_stats()
    # one encode dispatch per (shape, dtype, params) bucket — never per layer
    assert 1 <= st["dispatches"] <= n_streamed, st
    # no full-tensor host round-trips: the largest eligible leaf is >= 64 KiB
    # but only histograms / const flags / high_len vectors may cross
    assert spy.calls, "expected batched stats/accounting transfers"
    assert max(spy.calls) < 32 * 1024, spy.calls

    # and the result still serves bit-identically
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    l_ref, _ = model.prefill_fn(params, pb, 16)
    l_str, _ = model.prefill_fn(streamed, pb, 16)
    assert float(jnp.abs(l_ref - l_str).max()) == 0.0


def test_tree_ratio_batches_accounting_transfers(monkeypatch):
    tree = {"a": make_realistic_bf16(70_000, seed=5),
            "b": make_realistic_bf16(90_000, seed=6),
            "c": make_realistic_bf16(50_000, seed=7)}
    ctree = enec_api.compress_tree(tree)
    # compress_array's never-worse check already cached the wire sizes, so
    # aggregate accounting needs zero further transfers
    spy = _DeviceGetSpy()
    monkeypatch.setattr(jax, "device_get", spy)
    stats = enec_api.tree_ratio(ctree)
    monkeypatch.undo()
    assert stats["tensors"] == 3 and stats["ratio"] > 1.0
    assert len(spy.calls) == 0, spy.calls


def test_fresh_tensor_wire_accounting_single_transfer(monkeypatch):
    xs = _make_stack(n_layers=2, per_layer=160_000, shape=(400, 400))
    ct = enec_api.compress_stacked(xs)
    # strip the cache as if the tensor just came off a stream
    ct2 = enec_api.slice_stacked(ct, 0)
    ct3 = enec_api.slice_stacked(ct, 1)
    spy = _DeviceGetSpy()
    monkeypatch.setattr(jax, "device_get", spy)
    enec_api.precompute_wire_bytes([ct2, ct3])
    n_after_precompute = len(spy.calls)
    _ = ct2.nbytes_wire() + ct3.nbytes_wire()
    monkeypatch.undo()
    assert n_after_precompute == 1, spy.calls      # one batched transfer
    assert len(spy.calls) == 1, spy.calls          # cache hit afterwards


# ---------------------------------------------------------------------------
# Pallas backend drives the same stacked path
# ---------------------------------------------------------------------------

def test_pallas_backend_stacked_parity():
    xs = jnp.stack([make_realistic_bf16(1024, seed=i) for i in range(2)])
    p = params_mod.search_for_array(np.asarray(jax.device_get(xs)), BF16,
                                    block_elems=256)
    try:
        enec_api.set_encode_backend("pallas")
        ct_pallas = enec_api.compress_stacked(xs, p, block_elems=256)
        assert enec_api.encode_cache_stats()["backend"] == "pallas"
    finally:
        enec_api.set_encode_backend("reference")
    ct_ref = enec_api.compress_stacked(xs, p, block_elems=256)
    for name in ("mask", "low", "high", "high_len", "raw"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ct_pallas.streams, name)),
            np.asarray(getattr(ct_ref.streams, name)), err_msg=name)
    np.testing.assert_array_equal(
        _bits(xs), _bits(enec_api.decompress_stacked(ct_pallas)))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        enec_api.set_encode_backend("cuda")
