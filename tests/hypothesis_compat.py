"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is an optional dev dependency (see requirements.txt).  When it
is installed the property tests run exactly as written; when it is missing we
must not fail collection of the whole module (that would also kill the
deterministic tests living next to them), so the stand-ins below turn each
``@given`` test into an explicit skip instead.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
