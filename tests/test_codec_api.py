"""The v1 public API (ISSUE 5): Codec/CodecConfig, plan/execute, instance
isolation, the deprecated-wrapper contract, and exact wire accounting.

Covers the PR's acceptance criteria directly:
  * two Codec instances with different backends coexist in one process —
    same tree, bit-identical round trips, independent cache stats;
  * ``len(plan.buckets)`` equals the dispatches ``execute`` launches, on
    both the encode and decode side;
  * every legacy wrapper emits exactly one DeprecationWarning per call and
    is bit-identical to the codec method;
  * ``repro.core.__all__`` is a reviewed snapshot;
  * ``nbytes_wire()`` equals ``len(frame(to_wire(ct)))`` for every mode.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import (Codec, CodecConfig, CompressedTensor, DecodePlan,
                        EncodePlan, current_codec, default_codec,
                        set_default_codec, use_codec, wire)
from repro.core import api as enec_api
from conftest import make_realistic_bf16


def _bits(x):
    dt = {2: np.uint16, 4: np.uint32}[jnp.dtype(x.dtype).itemsize]
    return np.asarray(jax.device_get(x)).view(dt)


def _stack(n_layers=3, per_layer=32_768, seed=0):
    return jnp.stack([make_realistic_bf16(per_layer, seed=seed + i)
                      for i in range(n_layers)])


# ---------------------------------------------------------------------------
# config + construction
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="encode_backend"):
        CodecConfig(encode_backend="cuda")
    with pytest.raises(ValueError, match="decode_backend"):
        Codec(decode_backend="rocm")
    with pytest.raises(ValueError):
        CodecConfig(block_elems=0)


def test_codec_constructor_sugar():
    c = Codec(encode_backend="pallas", block_elems=1024)
    assert c.config.encode_backend == "pallas"
    assert c.config.block_elems == 1024
    base = CodecConfig()
    c2 = Codec(base, decode_backend="pallas")
    assert c2.config.decode_backend == "pallas"
    assert base.decode_backend == "reference"   # config is immutable


def test_configure_clears_only_affected_caches():
    c = Codec()
    x = make_realistic_bf16(32_768, seed=1)
    ct = c.compress_array(x)
    c.decompress_array(ct)
    assert len(c._encode_cache) == 1 and len(c._decode_cache) == 1
    c.set_decode_backend("pallas")
    assert len(c._encode_cache) == 1      # encoder cache untouched
    assert len(c._decode_cache) == 0      # decoder cache invalidated
    c.configure(c.config)                  # no-op configure clears nothing
    assert len(c._encode_cache) == 1


# ---------------------------------------------------------------------------
# acceptance: two codecs with different backends coexist in one process
# ---------------------------------------------------------------------------

def test_two_codecs_coexist_bit_identical_independent_stats():
    ref = Codec(encode_backend="reference", decode_backend="reference")
    pal = Codec(encode_backend="pallas", decode_backend="pallas")
    tree = {"w1": _stack(2, 16_384, seed=3),
            "w2": make_realistic_bf16(32_768, seed=9)}
    # interleave the two codecs over the SAME tree: per-instance state
    # means neither run can perturb the other
    ct_ref = ref.compress_tree(tree)
    ct_pal = pal.compress_tree(tree)
    out_ref = ref.decompress_tree(ct_ref)
    out_pal = pal.decompress_tree(ct_pal)
    for k in tree:
        np.testing.assert_array_equal(_bits(tree[k]), _bits(out_ref[k]))
        np.testing.assert_array_equal(_bits(out_ref[k]), _bits(out_pal[k]))
    st_ref, st_pal = ref.encode_cache_stats(), pal.encode_cache_stats()
    assert st_ref["backend"] == "reference" and st_pal["backend"] == "pallas"
    assert st_ref["dispatches"] >= 1 and st_pal["dispatches"] >= 1
    # independence: resetting one leaves the other untouched
    ref.reset_encode_cache_stats()
    assert ref.encode_cache_stats()["dispatches"] == 0
    assert pal.encode_cache_stats()["dispatches"] == st_pal["dispatches"]
    assert ref._encode_cache is not pal._encode_cache
    # and the process default codec saw NONE of it
    assert default_codec() not in (ref, pal)


# ---------------------------------------------------------------------------
# plan/execute: the dispatch count is an API property
# ---------------------------------------------------------------------------

def test_plan_encode_buckets_equal_dispatches():
    c = Codec()
    stacks = [_stack(2, 16_384, seed=0), _stack(2, 16_384, seed=7),
              _stack(4, 16_384, seed=11)]
    plan = c.plan_encode(stacks, stacked=True)
    assert isinstance(plan, EncodePlan)
    assert plan.n_inputs == 3 and plan.n_fallback == 0
    assert 1 <= len(plan.buckets) <= 3
    assert plan.dispatch_count == len(plan.buckets)
    assert plan.predicted_wire_bytes > 0
    for b in plan.buckets:
        assert b.backend == "reference"
        assert b.fmt_name == "bf16"
        assert len(b.params_key) == 3           # (n, m, L) on reference
        assert b.block_bucket >= 1 and b.nblocks >= b.n_tensors
        assert b.key[0] == "reference"
    c.reset_encode_cache_stats()
    cts = c.execute(plan)
    assert c.encode_cache_stats()["dispatches"] == len(plan.buckets)
    for x, ct in zip(stacks, cts):
        np.testing.assert_array_equal(_bits(x),
                                      _bits(c.decompress_stacked(ct)))
    # predicted wire bytes are a genuine estimate of the real total
    total = sum(ct.nbytes_wire() for ct in cts)
    assert 0.5 * total < plan.predicted_wire_bytes < 2.0 * total


def test_plan_decode_buckets_equal_restore_dispatches():
    c = Codec()
    cts = c.compress_stacked_many(
        [_stack(2, 16_384, seed=0), _stack(2, 16_384, seed=5),
         _stack(4, 16_384, seed=8)])
    cts.append(c.compress_array(jnp.zeros((64,), jnp.bfloat16)))  # const
    cts.append(None)
    plan = c.plan_decode(cts)
    assert isinstance(plan, DecodePlan)
    assert plan.n_passthrough == 1              # the const tensor
    assert plan.dispatch_count == len(plan.buckets) >= 1
    c.reset_decode_cache_stats()
    outs = c.execute(plan)
    # THE acceptance property: restore dispatch count == len(plan.buckets)
    assert c.decode_cache_stats()["dispatches"] == len(plan.buckets)
    assert outs[-1] is None
    assert float(jnp.abs(outs[-2]).max()) == 0.0


def test_plan_config_mismatch_rejected():
    a, b = Codec(), Codec(decode_backend="pallas")
    ct = a.compress_array(make_realistic_bf16(32_768, seed=2))
    plan = a.plan_decode([ct])
    with pytest.raises(ValueError, match="different CodecConfig"):
        b.execute(plan)
    with pytest.raises(TypeError):
        a.execute("not a plan")


def test_streaming_policy_executes_inspected_plan():
    """streaming_encode_plan -> compress_params_for_streaming(plan=...)
    runs the inspected plan (len(plan.buckets) dispatches), instead of
    planning twice; a mismatched plan is rejected."""
    from repro.runtime.streaming import (compress_params_for_streaming,
                                         streaming_encode_plan)
    params = {"period": [{"w": _stack(4, 65_536, seed=2)
                          .reshape(4, 256, 256)}]}
    codec = Codec()
    plan = streaming_encode_plan(params, min_bytes=1024, shards=1,
                                 codec=codec)
    codec.reset_encode_cache_stats()
    streamed = compress_params_for_streaming(params, min_bytes=1024,
                                             shards=1, codec=codec,
                                             plan=plan)
    assert codec.encode_cache_stats()["dispatches"] == len(plan.buckets) == 1
    sw = streamed["period"][0]["w"]
    np.testing.assert_array_equal(
        _bits(params["period"][0]["w"]),
        _bits(jnp.moveaxis(codec.decompress_stacked(sw.ct), 1,
                           1 + sw.tp_axis)))
    with pytest.raises(ValueError, match="does not match"):
        compress_params_for_streaming(params, min_bytes=1024, shards=2,
                                      codec=codec, plan=plan)


def test_npraw_records_count_on_manager_codec(tmp_path):
    """Raw (non-float) record uploads are accounted on the manager's codec,
    not the ambient one — per-manager transfer accounting is total."""
    from repro.checkpoint.ckpt import CheckpointManager
    codec = Codec()
    tree = {"w": _stack(1, 16_384, seed=3),
            "step": jnp.arange(1000, dtype=jnp.int32)}
    mgr = CheckpointManager(tmp_path, codec=codec)
    mgr.save(1, tree, blocking=True)
    ambient_before = default_codec().transfer_stats()["h2d_bytes"]
    codec.reset_transfer_stats()
    mgr.load(tree)
    assert codec.transfer_stats()["h2d_bytes"] >= 4000   # incl. the npraw
    assert default_codec().transfer_stats()["h2d_bytes"] == ambient_before


def test_checkpoint_restore_dispatches_match_plan(tmp_path):
    """End to end: the dispatches a checkpoint restore performs equal the
    bucket count of a decode plan over the same records."""
    from repro.checkpoint.ckpt import CheckpointManager
    codec = Codec()
    tree = {"a": _stack(2, 16_384, seed=1), "b": _stack(2, 16_384, seed=4),
            "c": make_realistic_bf16(32_768, seed=6)}
    mgr = CheckpointManager(tmp_path, codec=codec)
    mgr.save(1, tree, blocking=True)
    codec.reset_decode_cache_stats()
    out, _ = mgr.load(tree)
    load_dispatches = codec.decode_cache_stats()["dispatches"]
    # the loader records its executed plan — summary only (the execution
    # state would pin the compressed streams on device)
    assert load_dispatches == len(mgr.last_decode_plan.buckets)
    assert mgr.last_decode_plan._groups == []
    assert mgr.last_decode_plan._leaves == []
    for k in tree:
        np.testing.assert_array_equal(_bits(tree[k]), _bits(out[k]))
    # rebuild the record tensors and plan their decode: same bucket count
    cts = [codec.compress_stacked(tree["a"]),
           codec.compress_stacked(tree["b"]),
           codec.compress_array(tree["c"])]
    plan = codec.plan_decode(cts)
    assert load_dispatches == len(plan.buckets)


# ---------------------------------------------------------------------------
# ambient codec: default / use_codec / legacy delegation
# ---------------------------------------------------------------------------

def test_use_codec_scopes_the_ambient_codec():
    mine = Codec()
    assert current_codec() is default_codec()
    with use_codec(mine) as inside:
        assert inside is mine and current_codec() is mine
        with use_codec(Codec()) as inner:
            assert current_codec() is inner
        assert current_codec() is mine
    assert current_codec() is default_codec()


def test_set_default_codec_returns_previous():
    prev = default_codec()
    mine = Codec()
    got = set_default_codec(mine)
    try:
        assert got is prev and default_codec() is mine
    finally:
        set_default_codec(prev)


def test_legacy_wrappers_hit_the_ambient_codec():
    mine = Codec()
    x = make_realistic_bf16(32_768, seed=3)
    before = default_codec().encode_cache_stats()["dispatches"]
    with use_codec(mine), warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ct = core.compress_array(x)
        core.decompress_array(ct)
        assert core.encode_cache_stats()["dispatches"] == 1
    assert mine.encode_cache_stats()["dispatches"] == 1
    assert mine.decode_cache_stats()["dispatches"] == 1
    # the process default codec saw none of it
    assert default_codec().encode_cache_stats()["dispatches"] == before


def test_backend_selection_does_not_leak_without_fixture():
    """set_encode_backend now mutates (only) the default codec's config;
    the autouse conftest fixture restores it after every test.  Emulate
    the fixture inline to prove restoration works."""
    from repro.core import codec_api
    codec = codec_api.default_codec()
    saved = codec.config
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        core.set_encode_backend("pallas")
        assert codec.config.encode_backend == "pallas"
        assert core.encode_cache_stats()["backend"] == "pallas"
    codec.configure(saved)
    assert codec.config.encode_backend == "reference"


# ---------------------------------------------------------------------------
# deprecated wrappers: exactly one warning, bit-identical to the method
# ---------------------------------------------------------------------------

def _one_deprecation(fn, *args, **kw):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*args, **kw)
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)
            and "repro.core" in str(w.message)]
    assert len(deps) == 1, (fn.__name__, [str(w.message) for w in rec])
    assert "docs/API.md" in str(deps[0].message)
    return out


def test_every_legacy_wrapper_warns_once_and_matches_codec():
    codec = Codec()
    x = make_realistic_bf16(32_768, seed=1)
    xs = _stack(2, 16_384, seed=2)
    wkn = jnp.stack([make_realistic_bf16(160 * 200, seed=5).reshape(160, 200)
                     for _ in range(2)])
    tree = {"w": x}
    ct = codec.compress_array(x)
    st = codec.compress_stacked(xs)
    tiled1 = codec.tile_weights_for_fusion(wkn[0])

    with use_codec(codec):
        cases = {
            "compress_array": ((x,), codec.compress_array(x)),
            "decompress_array": ((ct,), codec.decompress_array(ct)),
            "compress_stacked": ((xs,), codec.compress_stacked(xs)),
            "compress_stacked_many": (([xs],),
                                      codec.compress_stacked_many([xs])),
            "decompress_stacked": ((st,), codec.decompress_stacked(st)),
            "decompress_stacked_many": (([st, None],),
                                        codec.decompress_stacked_many(
                                            [st, None])),
            "compress_tree": ((tree,), codec.compress_tree(tree)),
            "decompress_tree": (({"w": ct},),
                                codec.decompress_tree({"w": ct})),
            "tile_weights_for_fusion": ((wkn,),
                                        codec.tile_weights_for_fusion(wkn)),
            "tile_weights_for_fusion_many": (([wkn],),
                                             codec.tile_weights_for_fusion_many(
                                                 [wkn])),
            "untile_matmul_weight": ((tiled1, 160, 200),
                                     codec.untile_matmul_weight(tiled1, 160,
                                                                200)),
            # stats/reset/backend wrappers: warning contract only (their
            # values change as the other wrappers in this loop dispatch)
            "encode_cache_stats": ((), None),
            "decode_cache_stats": ((), None),
            "reset_encode_cache_stats": ((), None),
            "reset_decode_cache_stats": ((), None),
            "set_encode_backend": (("reference",), None),
            "set_decode_backend": (("reference",), None),
        }
        assert set(cases) == set(enec_api.DEPRECATED_WRAPPERS)
        for name, (args, expect) in cases.items():
            got = _one_deprecation(getattr(core, name), *args)
            if expect is None:
                continue
            for a, b in zip(jax.tree.leaves(got,
                                            is_leaf=lambda v: v is None),
                            jax.tree.leaves(expect,
                                            is_leaf=lambda v: v is None)):
                if a is None or isinstance(a, (int, str, float, dict)):
                    assert a == b
                elif isinstance(a, CompressedTensor):
                    pass   # compared via their stream leaves by tree.leaves
                else:
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))


# ---------------------------------------------------------------------------
# __all__ snapshot: the reviewed public surface of repro.core
# ---------------------------------------------------------------------------

PUBLIC_SURFACE = [
    # v1 API
    "BACKENDS", "Codec", "CodecConfig", "DecodeBucket", "DecodePlan",
    "EncodeBucket", "EncodePlan", "current_codec", "default_codec",
    "set_default_codec", "use_codec",
    # data model + stateless utilities
    "CompressedTensor", "abstract_compressed", "matmul_tiles",
    "precompute_wire_bytes", "slice_stacked", "tree_ratio",
    # deprecated wrappers
    "DEPRECATED_WRAPPERS",
    "compress_array", "compress_stacked", "compress_stacked_many",
    "compress_tree", "decode_cache_stats", "decompress_array",
    "decompress_stacked", "decompress_stacked_many", "decompress_tree",
    "encode_cache_stats", "reset_decode_cache_stats",
    "reset_encode_cache_stats", "set_decode_backend", "set_encode_backend",
    "tile_weights_for_fusion", "tile_weights_for_fusion_many",
    "untile_matmul_weight",
    # block codec / formats / params / stats
    "BlockStreams", "decode_blocks", "encode_blocks",
    "BF16", "FORMATS", "FP16", "FP32", "FloatFormat", "format_for",
    "DEFAULT_BLOCK_ELEMS", "EnecParams", "expected_ratio", "search",
    "search_for_array", "StackStats", "exponent_histogram_device",
    "stack_stats",
]


def test_public_all_snapshot():
    """Additions/removals to repro.core.__all__ must update this snapshot —
    the v1 surface is a contract (docs/API.md), not an accident."""
    assert sorted(core.__all__) == sorted(PUBLIC_SURFACE)
    for name in core.__all__:
        assert hasattr(core, name), name


# ---------------------------------------------------------------------------
# satellite: nbytes_wire equals the REAL framed record size
# ---------------------------------------------------------------------------

def _assert_wire_exact(ct, stacked=False):
    blob = wire.frame(wire.to_wire(ct, stacked=stacked))
    assert ct.nbytes_wire() == len(blob), (ct.mode, ct.shape)


def test_nbytes_wire_matches_serializer_all_modes():
    c = Codec()
    # enec, multi-dim shape (header holds 8 bytes per dim)
    ct = c.compress_array(make_realistic_bf16(4 * 128 * 64,
                                              seed=0).reshape(4, 128, 64))
    assert ct.mode == "enec"
    _assert_wire_exact(ct)
    # fresh tensor with no cache: nbytes_wire computes from device streams
    ct2 = core.slice_stacked(c.compress_stacked(_stack(2, 32_768, seed=3)), 0)
    assert getattr(ct2, "_wire_bytes", None) is None
    _assert_wire_exact(ct2)
    # const
    cct = c.compress_array(jnp.full((7, 9), 2.5, jnp.float32))
    assert cct.mode == "const"
    _assert_wire_exact(cct)
    # raw (non-float escape)
    rct = c.compress_array(jnp.arange(100, dtype=jnp.int32))
    assert rct.mode == "raw"
    _assert_wire_exact(rct)
    # sharded
    sct = c.compress_array(make_realistic_bf16(65_536, seed=4), shards=2)
    if sct.mode == "enec":
        _assert_wire_exact(sct)
    # stacked record (serving bundles)
    stk = c.compress_stacked(_stack(3, 16_384, seed=5))
    _assert_wire_exact(stk, stacked=True)


def test_nbytes_wire_counts_per_block_padding():
    """The wire byte-pads the high stream PER BLOCK; summing bits across
    blocks and rounding once undercounts.  Many small blocks with odd bit
    counts make the difference visible."""
    c = Codec(block_elems=1024)
    x = make_realistic_bf16(16 * 1024, seed=6)
    ct = c.compress_array(x)
    assert ct.mode == "enec" and ct.streams.mask.shape[0] == 16
    _assert_wire_exact(ct)
    hl = np.asarray(jax.device_get(ct.streams.high_len), np.int64)
    per_block = int(((hl + 7) // 8).sum())
    once = int((hl.sum() + 7) // 8)
    assert per_block >= once   # equality only if every block is byte-aligned


def test_ratio_uses_exact_accounting():
    c = Codec()
    tree = {"w": make_realistic_bf16(200_000, seed=7)}
    ctree = c.compress_tree(tree)
    stats = core.tree_ratio(ctree)
    assert stats["compressed_bytes"] == len(
        wire.frame(wire.to_wire(ctree["w"])))
    assert stats["ratio"] > 1.0


# ---------------------------------------------------------------------------
# transfer counters are per-codec
# ---------------------------------------------------------------------------

def test_transfer_counter_is_instance_scoped():
    a, b = Codec(), Codec()
    before = default_codec().transfer_stats()["h2d_bytes"]
    ct = a.compress_array(make_realistic_bf16(30_000, seed=8))
    blob = wire.to_wire(ct)
    wire.from_wire(blob, codec=a)
    assert a.transfer_stats()["h2d_arrays"] > 0
    assert b.transfer_stats()["h2d_arrays"] == 0
    assert default_codec().transfer_stats()["h2d_bytes"] == before
    # the module-level legacy helpers hit the ambient codec
    with use_codec(b):
        wire.from_wire(blob)
        assert wire.transfer_stats() == b.transfer_stats()
        assert b.transfer_stats()["h2d_arrays"] > 0
    b.reset_transfer_stats()
    assert b.transfer_stats()["h2d_arrays"] == 0
    assert a.transfer_stats()["h2d_arrays"] > 0
