"""Training-loop integration: runs, checkpoints, resumes deterministically."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime.train_loop import TrainLoopConfig, WatchdogConfig, run


def _setup(tmp_path):
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, remat=False)
    model = build_model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=11)
    ckpt = CheckpointManager(tmp_path / "ckpt", keep_last=2)
    return model, data_cfg, ckpt


def test_loss_decreases_and_checkpoints(tmp_path):
    model, data_cfg, ckpt = _setup(tmp_path)
    out = run(model, adamw.AdamWConfig(lr=3e-3), data_cfg,
              TrainLoopConfig(total_steps=8, ckpt_every=4, log_every=1),
              ckpt=ckpt)
    hist = out["history"]
    assert len(hist) == 8
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert ckpt.latest_step() == 8


def test_resume_continues_from_checkpoint(tmp_path):
    model, data_cfg, ckpt = _setup(tmp_path)
    run(model, adamw.AdamWConfig(lr=3e-3), data_cfg,
        TrainLoopConfig(total_steps=4, ckpt_every=2, log_every=1), ckpt=ckpt)
    assert ckpt.latest_step() == 4
    out = run(model, adamw.AdamWConfig(lr=3e-3), data_cfg,
              TrainLoopConfig(total_steps=6, ckpt_every=10, log_every=1),
              ckpt=ckpt)
    steps = [h["step"] for h in out["history"]]
    assert steps == [4, 5], steps  # resumed at 4, not 0
