"""Collective parsing + ring-model wire accounting (launch/hlo_stats.py)."""
from repro.launch.hlo_stats import collective_stats, wire_bytes


HLO_SAMPLE = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[2,32768,4096]{2,1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[128,64]{1,0} reduce-scatter(%z), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %ard = f32[4,4]{1,0} all-reduce-done(%ar2)
  %ags = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-gather-start(%a, %b), replica_groups=[4,4]<=[16]
"""


def test_parses_all_kinds():
    s = collective_stats(HLO_SAMPLE)
    assert s["all-gather"]["count"] == 2          # ag + ag-start (done skipped)
    assert s["all-reduce"]["count"] == 1
    assert s["reduce-scatter"]["count"] == 1
    assert s["collective-permute"]["count"] == 1
    assert s["total_count"] == 5


def test_result_bytes():
    s = collective_stats(HLO_SAMPLE)
    assert s["all-reduce"]["result_bytes"] == 2 * 32768 * 4096 * 4
    assert s["reduce-scatter"]["result_bytes"] == 128 * 64 * 4
    # tuple-shaped start op sums both elements
    assert s["all-gather"]["result_bytes"] == 16 * 1024 * 2 + 2 * (4 * 8 * 2)


def test_ring_formulas():
    assert wire_bytes("all-reduce", 100, 4) == 2 * 0.75 * 100
    assert wire_bytes("all-gather", 100, 4) == 0.75 * 100
    assert wire_bytes("reduce-scatter", 100, 4) == 300
    assert wire_bytes("collective-permute", 100, 4) == 100
    assert wire_bytes("all-reduce", 100, 1) == 0.0


def test_group_size_detection():
    s = collective_stats(HLO_SAMPLE)
    # ar uses explicit groups of 4 -> 2*(3/4)*bytes
    rb = s["all-reduce"]["result_bytes"]
    assert abs(s["all-reduce"]["wire_bytes"] - 2 * 0.75 * rb) < 1
    # ag uses iota [16,16] -> group size 16
    # (first instr 16*1024*2 bytes at (15/16))
