"""Branch-free linear transform: exact-inverse property over valid params."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import transform
from repro.core.params import base_width_for


@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 2**31))
@settings(max_examples=60, deadline=None)
def test_forward_inverse_roundtrip(l, h, seed):
    if l > h:
        l, h = h, l
    b = (l + h) // 2
    n = base_width_for(b, l, h)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(l, h + 1, size=257, dtype=np.uint16))
    y = transform.forward(x, b, n)
    assert int(jnp.max(y)) < (1 << n)
    back = transform.inverse(y, b, n, l)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=60, deadline=None)
def test_injectivity_on_range(l, h):
    if l > h:
        l, h = h, l
    b = l + (h - l) * 3 // 4  # off-center b still injective per Eq. 1 guard
    n = base_width_for(b, l, h)
    xs = jnp.arange(l, h + 1, dtype=jnp.uint16)
    ys = np.asarray(transform.forward(xs, b, n))
    assert len(np.unique(ys)) == h - l + 1, "linear map must be injective"


def test_paper_example():
    # §V-C worked example: b=123, x=125 -> -2 -> 2^6-2 = 62 (n=6); x=122 -> 1
    y = transform.forward(jnp.asarray([125, 122], jnp.uint16), 123, 6)
    np.testing.assert_array_equal(np.asarray(y), [62, 1])
