"""Batched decompression pipeline (ISSUE 4 tentpole).

Covers: ``decompress_stacked_many`` parity vs the per-leaf path
(bit-identical, all formats, shards > 1, const/raw leaves mixed into the
batch), decoder compile-cache bucketing and hit/miss accounting, the Pallas
decode backend driving the same stacked path, the segment-local gather's
edge cases (all-anomaly / zero-anomaly / tail-padded blocks), batched
checkpoint restore dispatch counts, and the ``ops.idd_scan`` backend
resolution regression (ISSUE 4 satellite).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_realistic_bf16
from repro.core import api as enec_api
from repro.core import codec, params as params_mod
from repro.core.dtypes import BF16, format_for
from repro.core.params import EnecParams
from repro.kernels import ops, ref


def _bits(x):
    dt = np.uint16 if x.dtype != jnp.float32 else np.uint32
    return np.asarray(jax.device_get(x)).view(dt)


def _make(n, seed, dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(n) * 0.02
    w[rng.random(n) < 2e-3] *= 64.0
    return jnp.asarray(w.astype(np.float32)).astype(dtype)


def _make_stack(n_layers=3, per_layer=160_000, shape=(400, 400)):
    xs = jnp.stack([make_realistic_bf16(per_layer, seed=i + 20)
                    for i in range(n_layers)])
    return xs.reshape((n_layers,) + shape)


# ---------------------------------------------------------------------------
# decompress_stacked_many: parity with the per-leaf path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16, jnp.float32])
def test_batched_decode_parity_per_leaf(dtype):
    xs = [_make(40_000, 1, dtype), _make(70_000, 2, dtype)]
    cts = [enec_api.compress_array(x) for x in xs]
    outs = enec_api.decompress_stacked_many(cts)
    for x, ct, out in zip(xs, cts, outs):
        ref_out = enec_api.decompress_array(ct)
        assert out.shape == x.shape and out.dtype == x.dtype
        np.testing.assert_array_equal(_bits(out), _bits(ref_out))
        np.testing.assert_array_equal(_bits(out), _bits(x))


def test_batched_decode_parity_mixed_stacked_shards_const_raw():
    xs = _make_stack()
    stacked = enec_api.compress_stacked(xs)
    sharded = enec_api.compress_array(_make(7 * 16384, 3), shards=4)
    plain = enec_api.compress_array(_make(50_000, 4))
    const = enec_api.compress_array(jnp.full((257,), 1.5, jnp.bfloat16))
    raw = enec_api.compress_array(jnp.arange(13, dtype=jnp.int32))
    batch = [None, stacked, sharded, const, plain, raw]
    outs = enec_api.decompress_stacked_many(batch)
    assert outs[0] is None
    np.testing.assert_array_equal(_bits(outs[1]), _bits(xs))
    np.testing.assert_array_equal(
        _bits(outs[2]), _bits(enec_api.decompress_array(sharded)))
    np.testing.assert_array_equal(
        _bits(outs[3]), _bits(jnp.full((257,), 1.5, jnp.bfloat16)))
    np.testing.assert_array_equal(
        _bits(outs[4]), _bits(enec_api.decompress_array(plain)))
    np.testing.assert_array_equal(np.asarray(outs[5]), np.arange(13))


def test_batched_decode_tail_single_element():
    # last block holds ONE valid element; the rest is encode padding that
    # the decode must slice away exactly
    x = _make(2 * 16384 + 1, 5)
    ct = enec_api.compress_array(x)
    out = enec_api.decompress_stacked_many([ct])[0]
    np.testing.assert_array_equal(_bits(out), _bits(x))


def test_batched_decode_shares_one_dispatch_across_params():
    # distinct tensors with distinct (b, l) but equal (n, m, L) must share
    # ONE concatenated decode dispatch ((b, l) ride as traced per-block
    # vectors on the reference backend)
    xs = [_make(60_000, 6), _make(90_000, 7)]
    ps = []
    for x in xs:
        exp = (_bits(x) >> 7) & 0xFF
        ps.append(EnecParams(b=int(exp.max()), n=6, m=3, L=16,
                             l=int(exp.min())))
    assert (ps[0].b, ps[0].l) != (ps[1].b, ps[1].l)
    cts = [enec_api.compress_array(x, p) for x, p in zip(xs, ps)]
    assert all(ct.mode == "enec" for ct in cts)
    enec_api.reset_decode_cache_stats()
    outs = enec_api.decompress_stacked_many(cts)
    assert enec_api.decode_cache_stats()["dispatches"] == 1
    for x, out in zip(xs, outs):
        np.testing.assert_array_equal(_bits(out), _bits(x))


def test_decompress_tree_batches_dispatches():
    tree = {"a": _make(70_000, 8), "b": _make(90_000, 9),
            "c": jnp.arange(5, dtype=jnp.int32)}
    ctree = enec_api.compress_tree(tree)
    enec_api.reset_decode_cache_stats()
    out = enec_api.decompress_tree(ctree)
    assert enec_api.decode_cache_stats()["dispatches"] == 1
    np.testing.assert_array_equal(_bits(out["a"]), _bits(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["c"]), np.arange(5))


# ---------------------------------------------------------------------------
# decoder compile-cache hygiene
# ---------------------------------------------------------------------------

def test_decoder_cache_buckets_block_counts():
    p = params_mod.search_for_array(
        np.asarray(jax.device_get(make_realistic_bf16(100_000))), BF16)
    ct3 = enec_api.compress_array(make_realistic_bf16(3 * 16384, seed=1), p)
    ct4 = enec_api.compress_array(make_realistic_bf16(4 * 16384, seed=2), p)
    enec_api.reset_decode_cache_stats(clear_cache=True)
    enec_api.decompress_array(ct3)
    enec_api.decompress_array(ct4)
    st = enec_api.decode_cache_stats()
    # 3 blocks buckets up to 4: both tensors share one compiled decoder
    assert st["compiles"] == 1 and st["dispatches"] == 2, st
    assert st["cache_hits"] == 1 and st["padded_blocks"] == 1


def test_decode_cache_stats_reset_and_unknown_backend():
    enec_api.reset_decode_cache_stats()
    st = enec_api.decode_cache_stats()
    assert st["dispatches"] == 0 and st["backend"] == "reference"
    with pytest.raises(ValueError):
        enec_api.set_decode_backend("cuda")


def test_pallas_decode_backend_stacked_parity():
    xs = jnp.stack([make_realistic_bf16(1024, seed=i) for i in range(2)])
    p = params_mod.search_for_array(np.asarray(jax.device_get(xs)), BF16,
                                    block_elems=256)
    ct = enec_api.compress_stacked(xs, p, block_elems=256)
    ref_out = enec_api.decompress_stacked(ct)
    try:
        enec_api.set_decode_backend("pallas")
        enec_api.reset_decode_cache_stats()
        out = enec_api.decompress_stacked_many([ct])[0]
        st = enec_api.decode_cache_stats()
        assert st["backend"] == "pallas" and st["dispatches"] == 1
    finally:
        enec_api.set_decode_backend("reference")
    np.testing.assert_array_equal(_bits(out), _bits(ref_out))
    np.testing.assert_array_equal(_bits(out), _bits(xs))


# ---------------------------------------------------------------------------
# segment-local gather vs the jnp oracle (decode kernel edge cases)
# ---------------------------------------------------------------------------

def _kernel_vs_oracle(x, p, n_elems):
    bits = codec.to_blocks(x, BF16, n_elems)
    s = codec.encode_blocks(bits, BF16, p)
    got = ops.decode_blocks(s, n_elems, BF16, p)                # Pallas
    want = ref.decode_blocks_ref(s, n_elems, BF16, p)           # jnp oracle
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(bits))
    return s


def test_segment_gather_all_anomalous():
    # every element shares one exponent and b sits below it, so y = 2^n - 1
    # everywhere: every group is anomalous, ranks run 0..G-1 and the
    # gather's 128-row windows slide across the full rank range
    n_elems, L = 4096, 16
    x = jnp.full((2 * n_elems,), 0.5, jnp.bfloat16)
    exp = int(_bits(x)[0] >> 7) & 0xFF
    p = EnecParams(b=exp - 1, n=6, m=3, L=L, l=exp - 1)
    s = _kernel_vs_oracle(x, p, n_elems)
    g = n_elems // L
    assert int(np.asarray(s.high_len)[0]) == g * L * (p.n - p.m)  # all anom


def test_segment_gather_zero_anomalies():
    # b equals the only exponent: y = 0 everywhere, mask empty, the gather
    # must produce all zeros (and the high stream carries no set bits)
    n_elems, L = 4096, 16
    x = jnp.full((2 * n_elems,), 0.5, jnp.bfloat16)
    exp = int(_bits(x)[0] >> 7) & 0xFF
    p = EnecParams(b=exp, n=6, m=3, L=L, l=exp)
    s = _kernel_vs_oracle(x, p, n_elems)
    assert int(np.asarray(s.high_len).sum()) == 0


def test_segment_gather_tail_padded_block():
    # ONE real element, the rest of the single block is zero padding whose
    # exponent (0) sits far from b — every pad group is anomalous while the
    # real element's group is not, so the gather's window starts sweep the
    # whole rank range with a one-element head
    n_elems = 4096
    x = jnp.full((1,), 0.5, jnp.bfloat16)
    exp = int(_bits(x)[0] >> 7) & 0xFF
    flat = jnp.concatenate([x, jnp.zeros((n_elems - 1,), jnp.bfloat16)])
    bits = jnp.ravel(flat).view(jnp.uint16)[None, :]
    p = EnecParams(b=exp, n=8, m=3, L=16, l=0)   # injective on [0, exp]
    s = codec.encode_blocks(bits, BF16, p)
    got = ops.decode_blocks(s, n_elems, BF16, p)
    want = ref.decode_blocks_ref(s, n_elems, BF16, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(bits))


def test_decode_kernel_multiple_blocks_per_grid_step():
    from repro.kernels.enec_decode import blocks_per_step
    assert blocks_per_step(8, 1024) == 8
    assert blocks_per_step(4, 16384) == 1
    assert blocks_per_step(6, 1024) == 2          # must divide the total
    n_elems = 1024
    x = _make(8 * n_elems, 12)
    p = params_mod.search_for_array(np.asarray(jax.device_get(x)), BF16,
                                    block_elems=n_elems)
    bits = codec.to_blocks(x, BF16, n_elems)
    s = codec.encode_blocks(bits, BF16, p)
    got = ops.decode_blocks(s, n_elems, BF16, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(bits))


# ---------------------------------------------------------------------------
# consumers: whole-tree materialization + checkpoint restore stay batched
# ---------------------------------------------------------------------------

def test_materialize_weight_tree_batched_and_bit_exact():
    from repro.runtime.streaming import (compress_params_for_streaming,
                                         materialize_weight_tree)
    params = {"period": [{"wq": _make_stack(4), "wk": _make_stack(4),
                          "norm": jnp.ones((4, 400), jnp.bfloat16)}]}
    streamed = compress_params_for_streaming(params, min_bytes=1024,
                                             shards=2)
    assert sum(1 for l in jax.tree.leaves(
        streamed, is_leaf=lambda x: hasattr(x, "ct"))
        if hasattr(l, "ct")) == 2
    enec_api.reset_decode_cache_stats()
    out = materialize_weight_tree(streamed)
    st = enec_api.decode_cache_stats()
    assert st["dispatches"] == 1, st   # wq + wk share one decoder bucket
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(_bits(a), _bits(b))


def test_ckpt_restore_batched_decode(tmp_path):
    from repro.checkpoint.ckpt import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tree = {"params": params}
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(1, tree, blocking=True)
    n_records = len(mgr.manifest()["leaves"])

    enec_api.reset_decode_cache_stats()
    out, _ = mgr.load(tree)
    st = enec_api.decode_cache_stats()
    # restore must cost O(#decoder buckets), never O(#records)
    assert st["dispatches"] < n_records / 2, (st, n_records)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(_bits(a), _bits(b))


# ---------------------------------------------------------------------------
# ops.idd_scan honors the backend selection (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def test_idd_scan_honors_encode_backend(monkeypatch):
    import repro.kernels.ops as ops_mod
    calls = []
    real = ops_mod._idd_scan_jit
    monkeypatch.setattr(
        ops_mod, "_idd_scan_jit",
        lambda x, up: (calls.append(up), real(x, up))[1])
    x = jnp.asarray((np.random.default_rng(0).random((2, 256)) < 0.3)
                    .astype(np.int32))
    out_ref_backend = ops_mod.idd_scan(x)
    assert calls[-1] is False             # default backend is "reference"
    try:
        enec_api.set_encode_backend("pallas")
        out_pallas_backend = ops_mod.idd_scan(x)
        assert calls[-1] is True
    finally:
        enec_api.set_encode_backend("reference")
    ops_mod.idd_scan(x, use_pallas=True)  # explicit override still wins
    assert calls[-1] is True
    np.testing.assert_array_equal(np.asarray(out_ref_backend),
                                  np.asarray(out_pallas_backend))
    np.testing.assert_array_equal(np.asarray(out_ref_backend),
                                  np.asarray(ref.idd_scan_ref(x)))


def test_idd_scan_kernel_interpret_default_resolves():
    from repro.kernels.idd_scan import idd_scan as raw_idd_scan
    x = jnp.ones((1, 256), jnp.int32)
    # on this (non-TPU) container the None default must resolve to the
    # interpreter and still produce the exact scan
    np.testing.assert_array_equal(
        np.asarray(raw_idd_scan(x)),
        np.asarray(ref.idd_scan_ref(x)))
