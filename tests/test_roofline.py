"""Roofline math: scan-period correction, model FLOPs, term derivation."""
import json

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (_corrected, analyze_cell,
                                   model_flops_per_device)


def _fake_rec(p0f, p1f, periods, full=None):
    def cell(f):
        return {"cost": {"flops": f, "bytes accessed": 10 * f},
                "collectives": {"total_wire_bytes": f / 100},
                "memory": {"peak_memory_in_bytes": 1 << 30}}
    e = {"status": "ok", "full": cell(full if full is not None else p1f)}
    if p0f is not None:
        e["p0"], e["p1"] = cell(p0f), cell(p1f)
    return {"arch": "llama3_2_1b", "shape": "train_4k", "n_periods": periods,
            "single": e, "multi": {"status": "ok"}, "layers_mode": "scan"}


def test_period_correction_linear():
    rec = _fake_rec(p0f=1e9, p1f=3e9, periods=16)
    got = _corrected(rec["single"], ("cost", "flops"), 16)
    assert got == 1e9 + 16 * 2e9


def test_correction_falls_back_to_full_when_unrolled():
    rec = _fake_rec(p0f=None, p1f=None, periods=16, full=7e9)
    got = _corrected(rec["single"], ("cost", "flops"), 16)
    assert got == 7e9


def test_model_flops_6nd_train():
    cfg = get_config("llama3_2_1b")
    shape = SHAPES["train_4k"]
    mf = model_flops_per_device(cfg, shape)
    n = 1.24e9
    tokens = 256 * 4096
    assert abs(mf - 6 * n * tokens / 256) / mf < 0.15


def test_moe_uses_active_params():
    dense = model_flops_per_device(get_config("qwen3_32b"),
                                   SHAPES["train_4k"])
    moe = model_flops_per_device(get_config("qwen3_moe_235b_a22b"),
                                 SHAPES["train_4k"])
    # 22B active < 32.8B dense despite 235B total
    assert moe < dense


def test_analyze_cell_terms_and_dominant():
    rec = _fake_rec(p0f=1e12, p1f=2e12, periods=16)
    out = analyze_cell(rec)
    assert out["status"] == "ok"
    assert out["compute_s"] == pytest.approx(out["flops"] / 197e12, abs=1e-6)
    assert out["memory_s"] == pytest.approx(out["bytes"] / 819e9, abs=1e-6)
    assert out["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert 0 <= out["roofline_fraction"]


def test_analyze_cell_skip_passthrough():
    out = analyze_cell({"arch": "llama3_2_1b", "shape": "long_500k",
                        "status": "skipped", "reason": "SKIP(full-attn)"})
    assert out["status"] == "skipped"


def test_real_artifacts_if_present():
    path = "results/roofline.json"
    try:
        rows = json.loads(open(path).read())
    except FileNotFoundError:
        pytest.skip("no dry-run artifacts in this checkout")
    ok = [r for r in rows if r["status"] == "ok"]
    assert len(ok) >= 30          # 32 applicable cells
    assert all(r["multi_pod_ok"] for r in ok)
    skips = [r for r in rows if r["status"] == "skipped"]
    assert len(skips) == 8
