"""Pallas kernels vs pure-jnp oracles: shape/dtype/parameter sweeps.

These run the kernels under interpret=True (kernel body executed on CPU);
codecs must be element-EXACT, the fused GEMM matches to f32
accumulation-order tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BF16, FP16, FP32, EnecParams, codec
from repro.core import search_for_array
from repro.kernels import ops, ref
from repro.kernels.ops import decompress_matmul, tile_weights_for_fusion
from conftest import make_realistic_bf16

FMTS = {"bf16": (BF16, jnp.bfloat16), "fp16": (FP16, jnp.float16),
        "fp32": (FP32, jnp.float32)}


def _blocks_for(fmt_key, n_elems, nblocks, seed=0):
    fmt, dt = FMTS[fmt_key]
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(nblocks * n_elems) * 0.02
    w[rng.random(w.size) < 3e-3] *= 32
    x = jnp.asarray(w.astype("float32")).astype(dt)
    p = search_for_array(np.asarray(jax.device_get(x)), fmt,
                         block_elems=n_elems)
    return codec.to_blocks(x, fmt, n_elems), fmt, p


@pytest.mark.parametrize("shape", [(1, 128), (4, 1024), (2, 4096), (3, 2048)])
def test_idd_scan_matches_cumsum(shape):
    rng = np.random.default_rng(shape[1])
    x = jnp.asarray((rng.random(shape) < 0.3).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(ops.idd_scan(x)),
                                  np.asarray(ref.idd_scan_ref(x)))


@pytest.mark.parametrize("fmt_key", list(FMTS))
@pytest.mark.parametrize("n_elems", [2048, 16384])
def test_encode_decode_kernels_exact(fmt_key, n_elems):
    bits, fmt, p = _blocks_for(fmt_key, n_elems, nblocks=2)
    s_ref = codec.encode_blocks(bits, fmt, p)
    s_ker = ops.encode_blocks(bits, fmt, p)
    for name in s_ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ker, name)),
            np.asarray(getattr(s_ref, name)), err_msg=f"stream {name}")
    out = ops.decode_blocks(s_ref, n_elems, fmt, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


@pytest.mark.parametrize("m,n_width,L", [(1, 4, 16), (3, 6, 16), (5, 6, 32),
                                         (2, 7, 64), (6, 6, 16)])
def test_decode_kernel_param_grid(m, n_width, L):
    n_elems = 4096
    rng = np.random.default_rng(m * 10 + n_width)
    w = rng.standard_normal(2 * n_elems) * 0.02
    x = jnp.asarray(w.astype("float32")).astype(jnp.bfloat16)
    host = np.asarray(jax.device_get(x)).view(np.uint16)
    exp = (host >> 7) & 0xFF
    p = EnecParams(b=int(exp.max()), n=n_width, m=min(m, n_width), L=L,
                   l=int(exp.min()))
    if (int(exp.max()) - int(exp.min())) >= (1 << n_width):
        pytest.skip("params not injective for this draw")
    bits = codec.to_blocks(x, BF16, n_elems)
    s = codec.encode_blocks(bits, BF16, p)
    out = ops.decode_blocks(s, n_elems, BF16, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


@pytest.mark.parametrize("mkn", [(8, 256, 384), (16, 128, 128),
                                 (4, 512, 256)])
def test_fused_decompress_matmul(mkn):
    m, k, n = mkn
    rng = np.random.default_rng(k)
    wm = jnp.asarray((rng.standard_normal((k, n)) * 0.02
                      ).astype("float32")).astype(jnp.bfloat16)
    p = search_for_array(np.asarray(jax.device_get(wm)), BF16,
                         block_elems=128 * 128)
    ct = tile_weights_for_fusion(wm, p)
    x = jnp.asarray(rng.standard_normal((m, k)).astype("float32"))
    got = decompress_matmul(x, ct, k, n)
    want = ref.decompress_matmul_ref(x, ct, k, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)
    # and against the uncompressed matmul (weights are recovered exactly)
    direct = np.asarray(jnp.dot(x, wm.astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(got), direct, rtol=2e-2, atol=1e-2)


def test_kernel_jit_wrappers():
    bits, fmt, p = _blocks_for("bf16", 2048, nblocks=1)
    s = ops.encode_blocks(bits, fmt, p, use_pallas=False)
    out = ops.decode_blocks(s, 2048, fmt, p, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))
