"""Pallas kernels vs pure-jnp oracles: shape/dtype/parameter sweeps.

These run the kernels under interpret=True (kernel body executed on CPU);
codecs must be element-EXACT, the fused GEMM matches to f32
accumulation-order tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BF16, FP16, FP32, EnecParams, codec
from repro.core import search_for_array
from repro.kernels import ops, ref
from repro.kernels.ops import decompress_matmul, tile_weights_for_fusion
from conftest import make_realistic_bf16

FMTS = {"bf16": (BF16, jnp.bfloat16), "fp16": (FP16, jnp.float16),
        "fp32": (FP32, jnp.float32)}


def _blocks_for(fmt_key, n_elems, nblocks, seed=0):
    fmt, dt = FMTS[fmt_key]
    rng = np.random.default_rng(seed)
    w = rng.standard_normal(nblocks * n_elems) * 0.02
    w[rng.random(w.size) < 3e-3] *= 32
    x = jnp.asarray(w.astype("float32")).astype(dt)
    p = search_for_array(np.asarray(jax.device_get(x)), fmt,
                         block_elems=n_elems)
    return codec.to_blocks(x, fmt, n_elems), fmt, p


@pytest.mark.parametrize("shape", [(1, 128), (4, 1024), (2, 4096), (3, 2048)])
def test_idd_scan_matches_cumsum(shape):
    rng = np.random.default_rng(shape[1])
    x = jnp.asarray((rng.random(shape) < 0.3).astype(np.int32))
    # use_pallas=True pins the kernel path (the default defers to the
    # pipeline backend selection, which is "reference" here)
    np.testing.assert_array_equal(np.asarray(ops.idd_scan(x, use_pallas=True)),
                                  np.asarray(ref.idd_scan_ref(x)))


@pytest.mark.parametrize("fmt_key", list(FMTS))
@pytest.mark.parametrize("n_elems", [2048, 16384])
def test_encode_decode_kernels_exact(fmt_key, n_elems):
    bits, fmt, p = _blocks_for(fmt_key, n_elems, nblocks=2)
    s_ref = codec.encode_blocks(bits, fmt, p)
    s_ker = ops.encode_blocks(bits, fmt, p)
    for name in s_ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ker, name)),
            np.asarray(getattr(s_ref, name)), err_msg=f"stream {name}")
    out = ops.decode_blocks(s_ref, n_elems, fmt, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


@pytest.mark.parametrize("m,n_width,L", [(1, 4, 16), (3, 6, 16), (5, 6, 32),
                                         (2, 7, 64), (6, 6, 16)])
def test_decode_kernel_param_grid(m, n_width, L):
    n_elems = 4096
    rng = np.random.default_rng(m * 10 + n_width)
    w = rng.standard_normal(2 * n_elems) * 0.02
    x = jnp.asarray(w.astype("float32")).astype(jnp.bfloat16)
    host = np.asarray(jax.device_get(x)).view(np.uint16)
    exp = (host >> 7) & 0xFF
    p = EnecParams(b=int(exp.max()), n=n_width, m=min(m, n_width), L=L,
                   l=int(exp.min()))
    if (int(exp.max()) - int(exp.min())) >= (1 << n_width):
        pytest.skip("params not injective for this draw")
    bits = codec.to_blocks(x, BF16, n_elems)
    s = codec.encode_blocks(bits, BF16, p)
    out = ops.decode_blocks(s, n_elems, BF16, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


def _fused_case(m, k, n, dt, seed):
    rng = np.random.default_rng(seed)
    wm = jnp.asarray((rng.standard_normal((k, n)) * 0.02
                      ).astype("float32")).astype(dt)
    ct = tile_weights_for_fusion(wm)   # per-stack searched params (pipeline)
    x = jnp.asarray(rng.standard_normal((m, k)).astype("float32")).astype(dt)
    return wm, ct, x


def _assert_fused_exact(x, ct, wm, k, n):
    got = decompress_matmul(x, ct, k, n)
    # the kernel realizes tiled_matmul_ref's exact schedule: bit-identical
    want = ref.decompress_matmul_ref(x, ct, k, n)
    np.testing.assert_array_equal(np.asarray(got).view(np.uint32),
                                  np.asarray(want).view(np.uint32))
    want2 = ref.tiled_matmul_ref(x, wm)  # decompression is lossless
    np.testing.assert_array_equal(np.asarray(got).view(np.uint32),
                                  np.asarray(want2).view(np.uint32))
    # and against the plain uncompressed matmul (accumulation-order tol)
    direct = np.asarray(jnp.dot(x.astype(jnp.float32),
                                wm.astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(got), direct, rtol=2e-2, atol=1e-2)


# non-square tile counts (2x3, 4x2) and ragged K/N that ride the
# zero-padded tile layout (250 -> 256, 120 -> 128)
@pytest.mark.parametrize("mkn", [(8, 256, 384), (16, 128, 128),
                                 (4, 512, 256), (8, 250, 384),
                                 (4, 128, 120)])
def test_fused_decompress_matmul(mkn):
    m, k, n = mkn
    wm, ct, x = _fused_case(m, k, n, jnp.bfloat16, seed=k + n)
    _assert_fused_exact(x, ct, wm, k, n)


@pytest.mark.parametrize("fmt_key", ["fp16", "fp32"])
def test_fused_decompress_matmul_formats(fmt_key):
    _, dt = FMTS[fmt_key]
    m, k, n = 4, 256, 128
    wm, ct, x = _fused_case(m, k, n, dt, seed=11)
    assert ct.fmt_name == fmt_key
    _assert_fused_exact(x, ct, wm, k, n)


def test_fused_matmul_no_high_stream_edge():
    # m == n: every exponent fits the low stream, the high stream has zero
    # width and the kernel substitutes a dummy byte
    rng = np.random.default_rng(7)
    k, n = 256, 128
    wm = jnp.asarray((rng.standard_normal((k, n)) * 0.02
                      ).astype("float32")).astype(jnp.bfloat16)
    exp = ((np.asarray(jax.device_get(wm)).view(np.uint16) >> 7) & 0xFF)
    lo, hi = int(exp.min()), int(exp.max())
    nb = max((hi - lo).bit_length() + 1, 2)
    p = EnecParams(b=hi, n=nb, m=nb, L=16, l=lo)
    ct = tile_weights_for_fusion(wm, p)
    assert codec.stream_shapes(128 * 128, BF16, ct.params)["high"] == 0
    x = jnp.asarray(rng.standard_normal((4, k)).astype("float32"))
    _assert_fused_exact(x, ct, wm, k, n)


def test_fused_matmul_stacked_streams_slice_in_scan():
    # (L, K, N) weights compress as one stacked dispatch; lax.scan slices
    # the tile streams per layer and feeds the kernel unmodified
    import dataclasses as dc
    from repro.core.api import tile_weights_for_fusion_many
    rng = np.random.default_rng(3)
    L, k, n = 3, 256, 128
    ws = jnp.asarray((rng.standard_normal((L, k, n)) * 0.02
                      ).astype("float32")).astype(jnp.bfloat16)
    ct = tile_weights_for_fusion_many([ws])[0]
    assert ct is not None and ct.streams.mask.shape[0] == L
    x = jnp.asarray(rng.standard_normal((4, k)).astype("float32"))

    def body(carry, streams):
        out = decompress_matmul(carry, dc.replace(ct, streams=streams), k, n)
        return carry, out

    _, outs = jax.jit(lambda c, s: jax.lax.scan(body, c, s))(x, ct.streams)
    for i in range(L):
        want = ref.tiled_matmul_ref(x, ws[i])
        np.testing.assert_array_equal(np.asarray(outs[i]).view(np.uint32),
                                      np.asarray(want).view(np.uint32))


def test_kernel_jit_wrappers():
    bits, fmt, p = _blocks_for("bf16", 2048, nblocks=1)
    s = ops.encode_blocks(bits, fmt, p, use_pallas=False)
    out = ops.decode_blocks(s, 2048, fmt, p, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))
