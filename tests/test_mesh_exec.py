"""Mesh-native compressed execution (ISSUE 8): shard-local decode parity,
compressed-bytes collectives, per-link transfer ledger, and the lifted
FusedWeight shards>1 path.

Single-device tests cover the ledger API and the fused-kernel shard lift;
everything that needs a real mesh runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (the same pattern as
tests/test_distributed.py — jax locks the device count at first init).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codec_api import LINKS, Codec
from repro.launch.mesh import largest_model_axis
from repro.runtime.streaming import fused_shards

ROOT = Path(__file__).resolve().parent.parent


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("REPRO_DRYRUN", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# per-link transfer ledger (single device)
# ---------------------------------------------------------------------------

def test_link_ledger_counts_and_h2d_backcompat():
    c = Codec()
    ts = c.transfer_stats()
    assert set(ts["links"]) == set(LINKS)
    c.count_h2d(100, 2)
    c.count_h2d(50, dense=True)
    c.count_link("d2d_allgather", 300, ops=5)
    c.count_link("disk", 70)
    c.count_link("disk", 30, dense=True)
    ts = c.transfer_stats()
    # legacy keys keep counting TOTAL h2d traffic (compressed + dense)
    assert ts["h2d_bytes"] == 150 and ts["h2d_arrays"] == 3
    links = ts["links"]
    assert links["h2d"] == {"compressed_bytes": 100, "dense_bytes": 50,
                            "ops": 3}
    assert links["d2d_allgather"] == {"compressed_bytes": 300,
                                      "dense_bytes": 0, "ops": 5}
    assert links["disk"] == {"compressed_bytes": 70, "dense_bytes": 30,
                             "ops": 2}
    assert links["d2d_psum"]["ops"] == 0
    # link_stats returns copies — mutating them must not corrupt the ledger
    c.link_stats()["h2d"]["compressed_bytes"] = 0
    assert c.link_stats()["h2d"]["compressed_bytes"] == 100
    c.reset_transfer_stats()
    ts = c.transfer_stats()
    assert ts["h2d_bytes"] == 0
    assert all(v == {"compressed_bytes": 0, "dense_bytes": 0, "ops": 0}
               for v in ts["links"].values())


def test_count_link_rejects_unknown_link():
    c = Codec()
    with pytest.raises(ValueError, match="unknown transfer link"):
        c.count_link("carrier_pigeon", 1)


def test_wire_h2d_counts_dense_flag():
    from repro.core import wire
    c = Codec()
    wire.h2d(np.zeros(16, np.uint8), c)
    wire.h2d(np.zeros((4, 4), np.float32), c, dense=True)
    links = c.link_stats()
    assert links["h2d"] == {"compressed_bytes": 16, "dense_bytes": 64,
                            "ops": 2}


# ---------------------------------------------------------------------------
# fused-weight TP shards (single device: the flatten is placement-free)
# ---------------------------------------------------------------------------

def test_fused_shards_policy():
    # 512x512 -> 4x4 = 16 tile blocks: 4 divides, 3 doesn't
    assert fused_shards(512, 512, 4) == 4
    assert fused_shards(512, 512, 3) == 1
    assert fused_shards(512, 512, 1) == 1
    # one ragged 100x100 tile block can never shard
    assert fused_shards(100, 100, 2) == 1
    assert fused_shards(256, 128, 2) == 2   # 2x1 blocks


def test_tile_fusion_many_rejects_indivisible_shards():
    c = Codec()
    w = jnp.asarray(np.random.default_rng(0).standard_normal((384, 128)),
                    jnp.bfloat16)   # 3x1 = 3 tile blocks
    with pytest.raises(ValueError, match="not divisible"):
        c.tile_weights_for_fusion_many([w], shards=2)


def test_fused_matmul_sharded_bit_parity():
    """decompress_matmul on a shards=4 tile stream is bit-identical to the
    shards=1 stream of the same weight — the shard split is a contiguous
    partition of the flat n-major tile axis (PR 2's shards=1 restriction,
    lifted)."""
    from repro.core.api import slice_stacked
    from repro.kernels import ops
    c = Codec()
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((512, 512)), jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((8, 512)), jnp.bfloat16)
    ct1 = c.tile_weights_for_fusion_many([w])[0]
    ct4 = c.tile_weights_for_fusion_many([w], shards=4)[0]
    assert ct1 is not None and ct4 is not None
    assert ct4.shards == 4
    out1 = np.asarray(ops.decompress_matmul(x, slice_stacked(ct1, 0),
                                            512, 512))
    out4 = np.asarray(ops.decompress_matmul(x, slice_stacked(ct4, 0),
                                            512, 512))
    np.testing.assert_array_equal(out1.view(np.uint32), out4.view(np.uint32))
    # and both match the unfused canonical contraction bit-for-bit
    from repro.kernels.ref import tiled_matmul_ref
    ref = np.asarray(tiled_matmul_ref(x, w))
    np.testing.assert_array_equal(ref.view(np.uint32), out4.view(np.uint32))


def test_largest_model_axis():
    assert largest_model_axis(8) == 8
    assert largest_model_axis(8, cap=5) == 4
    assert largest_model_axis(6, cap=4) == 3
    assert largest_model_axis(7, cap=4) == 1
    assert largest_model_axis(1) == 1


# ---------------------------------------------------------------------------
# 8-device mesh tests (subprocesses)
# ---------------------------------------------------------------------------

def test_host_mesh_factorizations_8dev():
    _run("""
    import pytest
    from repro.launch.mesh import make_host_mesh
    assert dict(make_host_mesh().shape) == {"data": 8}
    assert dict(make_host_mesh(model=2).shape) == {"data": 4, "model": 2}
    assert dict(make_host_mesh(model="max").shape) == {"data": 1, "model": 8}
    assert dict(make_host_mesh(model="max", max_model=5).shape) == \
        {"data": 2, "model": 4}
    assert dict(make_host_mesh(max_model=4).shape) == {"data": 2, "model": 4}
    try:
        make_host_mesh(model=3)
    except ValueError:
        pass
    else:
        raise AssertionError("model=3 must not divide 8 devices")
    print("mesh factorization ok")
    """)


def test_shard_local_decode_parity_all_fmts_8dev():
    """Each device decodes ONLY its own block shard under shard_map; the
    result must be bit-identical to the single-device decode for every
    supported float format (per-block decode is independent)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, pytest
    from repro.core.codec_api import Codec
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.collectives import shard_local_decode

    mesh = make_host_mesh(model="max")          # (1, 8)
    c = Codec()
    rng = np.random.default_rng(0)
    for dt in ("bfloat16", "float16", "float32"):
        x = jnp.asarray(rng.standard_normal((64, 4096)), jnp.dtype(dt))
        ct = c.compress_array(x, shards=8)      # 16 blocks -> 2 per device
        assert ct.mode == "enec", (dt, ct.mode)
        ref = np.asarray(c.decompress_array(ct)).view(np.uint8)
        got = np.asarray(shard_local_decode(ct, mesh)).view(np.uint8)
        np.testing.assert_array_equal(ref, got, err_msg=dt)
    # raw / unsharded / stacked tensors are rejected, not mis-decoded
    raw = c.compress_array(jnp.arange(64, dtype=jnp.int32))
    for bad in (raw, c.compress_array(x)):
        try:
            shard_local_decode(bad, mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("expected ValueError")
    print("shard-local decode ok")
    """)


def test_gather_ct_ledger_and_parity_8dev():
    """The compression-aware all-gather replicates only WIRE payloads:
    the d2d_allgather link records (A-1) x device-stream bytes, zero dense
    bytes, and the gathered tensor decodes bit-identically."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.codec_api import Codec
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import sharding
    from repro.runtime.collectives import (gather_ct, maybe_gather_ct,
                                           stream_nbytes, use_serving_mesh)

    mesh = make_host_mesh(model=4)              # (2, 4)
    c = Codec()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 4096)), jnp.bfloat16)
    ct = c.compress_array(x, shards=4)
    assert ct.mode == "enec"
    ref = np.asarray(c.decompress_array(ct)).view(np.uint8)
    # place the shards on their owning devices
    specs = sharding.ct_pspecs(ct, mesh)
    ct = jax.device_put(ct, sharding.to_named(specs, mesh))
    assert "model" in ct.streams.mask.sharding.spec

    g = gather_ct(ct, mesh, codec=c)
    links = c.link_stats()["d2d_allgather"]
    assert links["compressed_bytes"] == 3 * stream_nbytes(ct), links
    assert links["dense_bytes"] == 0
    assert links["ops"] == len(jax.tree.leaves(ct.streams))
    assert all(s is None for s in g.streams.mask.sharding.spec)
    np.testing.assert_array_equal(
        ref, np.asarray(c.decompress_array(g)).view(np.uint8))

    # ambient-mesh hook: identity without a mesh, gather inside one
    assert maybe_gather_ct(ct, c) is ct
    with use_serving_mesh(mesh):
        g2 = maybe_gather_ct(ct, c)
    np.testing.assert_array_equal(
        ref, np.asarray(c.decompress_array(g2)).view(np.uint8))

    # const / raw / unsharded tensors pass through uncounted
    c.reset_transfer_stats()
    const = c.compress_array(jnp.ones((128, 128), jnp.bfloat16))
    raw = c.compress_array(jnp.arange(64, dtype=jnp.int32))
    plain = c.compress_array(x)
    for t in (const, raw, plain):
        assert gather_ct(t, mesh, codec=c) is t
    assert c.link_stats()["d2d_allgather"]["ops"] == 0
    print("gather ok")
    """)


def test_param_pspecs_stream_metadata_8dev():
    """Satellite 1 regression: stream specs come from handle metadata, not
    path heuristics — a flat L=1 handle (or any unsharded ct) replicates;
    a sharded stacked handle puts its shard dim (index 1) on "model"."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.codec_api import Codec
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding
    from repro.runtime.weights import StreamedWeight

    mesh = make_mesh((2, 4), ("data", "model"))
    c = Codec()
    rng = np.random.default_rng(2)
    stacked = jnp.asarray(rng.standard_normal((2, 512, 256)), jnp.bfloat16)
    flat2d = jnp.asarray(rng.standard_normal((512, 256)), jnp.bfloat16)
    ct4 = c.compress_stacked(stacked, shards=4)
    ct1 = c.compress_stacked(flat2d[None], shards=1)
    assert ct4 is not None and ct1 is not None
    tree = {
        "a": StreamedWeight(ct=ct4, tp_axis=0, layer_shape=(512, 256),
                            dtype_str="bfloat16"),
        "b": StreamedWeight(ct=ct1, tp_axis=0,
                            layer_shape=tuple(flat2d.shape),
                            dtype_str="bfloat16", flat=True),
        "w_up": jnp.zeros((2, 64, 128)),
    }
    specs = sharding.param_pspecs(tree, mesh, mode="serve")
    # sharded stacked streams: shard dim 1 -> "model"; high_len too
    assert specs["a"].ct.streams.mask == P(None, "model", None, None)
    assert specs["a"].ct.streams.high_len == P(None, "model", None)
    # flat L=1 / unsharded: fully replicated (the old "/streams/" + dim-1
    # heuristic mis-sharded exactly this layout)
    for s in jax.tree.leaves(specs["b"],
                             is_leaf=lambda x: isinstance(x, P)):
        assert all(n is None for n in s), s
    # plain leaves still ride the name rules
    assert specs["w_up"] == P(None, None, "model")
    # the spec tree device_puts the value tree (treedefs line up)
    placed = jax.device_put(tree, sharding.to_named(specs, mesh))
    assert "model" in placed["a"].ct.streams.mask.sharding.spec
    # bare CompressedTensor leaves work the same way
    ct_specs = sharding.param_pspecs({"ct": ct4}, mesh, mode="serve")
    assert ct_specs["ct"].streams.mask == P(None, "model", None, None)
    print("metadata pspecs ok")
    """)


def test_serve_logits_parity_sharded_8dev():
    """Sharded serving is bit-identical to single-device serving in all
    three weight-execution modes: compressed storage (and wire bytes) are
    distributed, the decoded math is replicated."""
    _run("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.core.codec_api import Codec, use_codec
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.runtime.collectives import (place_serving_tree,
                                           use_serving_mesh)
    from repro.runtime.streaming import assign_weight_modes

    mesh = make_host_mesh(model=4)              # (2, 4)
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 12), 0,
                                       cfg.vocab_size)}

    def serve(tree):
        logits, cache = model.prefill_fn(tree, pb, 24)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        dec, _ = model.decode_fn(tree, cache, tok)
        return np.asarray(logits), np.asarray(dec)

    c = Codec()
    with use_codec(c):
        for mode in ("dense", "stream", "fused"):
            tree = assign_weight_modes(params, mode=mode, min_bytes=1024,
                                       shards=4, codec=c)
            ref = serve(tree)
            placed = place_serving_tree(tree, mesh)
            c.reset_transfer_stats()
            with use_serving_mesh(mesh):
                got = serve(placed)
            for r, g in zip(ref, got):
                np.testing.assert_array_equal(r.view(np.uint32),
                                              g.view(np.uint32),
                                              err_msg=mode)
            links = c.link_stats()["d2d_allgather"]
            assert links["dense_bytes"] == 0, (mode, links)
            if mode == "stream":
                # sharded stream bundles really were gathered as wire bytes
                assert links["compressed_bytes"] > 0, links
            print(mode, "ok", links)
    print("serve parity ok")
    """)


def test_ckpt_mesh_restore_8dev():
    """load_for_serving(mesh=...): adopted stream records upload each shard
    to its owning devices, the disk link sees only compressed record bytes
    for weights, and the mesh-restored tree serves bit-identical logits."""
    _run("""
    import dataclasses, tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint.ckpt import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.core.codec_api import Codec, use_codec
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.runtime.collectives import use_serving_mesh
    from repro.runtime.weights import StreamedWeight, is_handle

    mesh = make_host_mesh(model=4)
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    c = Codec()
    root = tempfile.mkdtemp()
    mgr = CheckpointManager(root, serving_layout="stream",
                            serving_min_bytes=1024, serving_shards=4,
                            codec=c)
    mgr.save(0, {"params": params}, blocking=True)

    like = jax.eval_shape(model.init, jax.random.key(0))
    c.reset_transfer_stats()
    tree_m, man = mgr.load_for_serving(like, mode="stream", prefix="params",
                                       min_bytes=1024, shards=4, mesh=mesh)
    links = c.link_stats()
    assert links["disk"]["compressed_bytes"] > 0, links
    assert links["h2d"]["compressed_bytes"] > 0, links
    assert links["d2d_allgather"]["ops"] == 0, links   # restore != gather
    # adopted records live distributed: shard dim on the model axis
    sharded = [l for l in jax.tree.leaves(tree_m, is_leaf=is_handle)
               if isinstance(l, StreamedWeight) and l.ct.shards == 4]
    assert sharded, "no sharded stream handles restored"
    assert any("model" in l.ct.streams.mask.sharding.spec for l in sharded)

    tree_1, _ = mgr.load_for_serving(like, mode="stream", prefix="params",
                                     min_bytes=1024, shards=4)
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    with use_codec(c):
        ref, _ = model.prefill_fn(tree_1, pb, 16)
        with use_serving_mesh(mesh):
            got, _ = model.prefill_fn(tree_m, pb, 16)
    np.testing.assert_array_equal(np.asarray(ref).view(np.uint32),
                                  np.asarray(got).view(np.uint32))
    links = c.link_stats()["d2d_allgather"]
    assert links["dense_bytes"] == 0 and links["compressed_bytes"] > 0
    print("ckpt mesh restore ok")
    """)
