"""ENEC checkpointing: bit-exact restore, atomicity, retention, resume,
crash-safety (enec-v2 container), and the compressed->handle serving
restore."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointError, CheckpointManager
from repro.core import wire
from conftest import make_realistic_bf16


def _tree(seed=0):
    return {
        "params": {"w": make_realistic_bf16(120_000, seed=seed),
                   "b": jnp.zeros((64,), jnp.bfloat16)},
        "opt": {"m": jnp.asarray(np.random.default_rng(seed)
                                 .standard_normal(1000), jnp.float32),
                "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_trees_equal(a, b):
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape, pa
        np.testing.assert_array_equal(
            la.reshape(-1).view(np.uint8), lb.reshape(-1).view(np.uint8),
            err_msg=str(pa))


def test_save_load_bit_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = _tree()
    mgr.save(100, tree, blocking=True)
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    assert manifest["step"] == 100
    assert manifest["ratio"] > 1.05  # ENEC actually compressed the floats


def test_latest_pointer_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree(step), blocking=True)
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1].endswith("4")


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(3)
    mgr.save(5, tree)          # async
    mgr.wait()
    out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(1)
    mgr.save(1, tree, blocking=True)
    # simulate crash debris: stale tmp dir must not affect load
    (tmp_path / ".tmp-step_000000000002").mkdir()
    out, manifest = mgr.load(tree)
    assert manifest["step"] == 1
    _assert_trees_equal(tree, out)


def test_save_batches_encode_dispatches(tmp_path):
    """Float leaves ride the batched pipeline: leaves whose searched
    (n, m, L) coincide share one encode dispatch (per-leaf searched params —
    NOT shared — so unrelated same-shape tensors keep their own ratio);
    restore must stay bit-exact per leaf."""
    import repro.core.api as enec_api

    w = make_realistic_bf16(64_000, seed=11).reshape(160, 400)
    # Adam-nu-like second moment: same shape, squared values, so its exponent
    # distribution sits far below the weights' — per-leaf search MUST give it
    # different params (sharing them costs ~6% ratio)
    nu = (jnp.asarray(w, jnp.float32) ** 2).astype(jnp.bfloat16)
    tree = {"blk0": {"w": w},
            "blk1": {"w": make_realistic_bf16(64_000, seed=12).reshape(160, 400)},
            "blk2": {"w": make_realistic_bf16(64_000, seed=13).reshape(160, 400)},
            "nu": nu}
    mgr = CheckpointManager(tmp_path)
    enec_api.reset_encode_cache_stats()
    mgr.save(3, tree, blocking=True)
    st = enec_api.encode_cache_stats()
    # far fewer dispatches than leaves is the point; typically 1-2 buckets
    assert st["dispatches"] <= 2, st
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    assert all(by_name[n]["mode"] == "enec"
               for n in ("blk0/w", "blk1/w", "blk2/w", "nu"))
    assert tuple(by_name["nu"]["params"]) != tuple(by_name["blk0/w"]["params"])


def test_const_leaf_in_group_still_safe(tmp_path):
    """A constant leaf inside a same-shape group must fall back to the
    per-leaf path (const escape) without corrupting its siblings."""
    tree = {"a": make_realistic_bf16(40_000, seed=15),
            "b": jnp.zeros((40_000,), jnp.bfloat16)}
    mgr = CheckpointManager(tmp_path)
    mgr.save(4, tree, blocking=True)
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    modes = {e["name"]: e["mode"] for e in manifest["leaves"]}
    assert modes["b"] == "const"


def test_manifest_reports_compression(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _tree(2), blocking=True)
    manifest = json.loads(
        (tmp_path / "step_000000000009" / "manifest.json").read_text())
    modes = {e["mode"] for e in manifest["leaves"]}
    assert "enec" in modes          # big float leaves compressed
    assert manifest["compressed_bytes"] < manifest["raw_bytes"]
    assert manifest["format"] == "enec-v2"
    # every record is indexed by (pack, offset, length)
    assert all({"pack", "offset", "length"} <= e.keys()
               for e in manifest["leaves"])


# ---------------------------------------------------------------------------
# crash safety / fault tolerance (enec-v2)
# ---------------------------------------------------------------------------

def test_gc_removes_stale_tmp_dirs(tmp_path):
    """Crashed saves leave .tmp-step_* debris; the next committed save must
    GC it (the seed's _gc only globbed step_* and leaked them forever)."""
    mgr = CheckpointManager(tmp_path, keep_last=2)
    (tmp_path / ".tmp-step_000000000001").mkdir()
    (tmp_path / ".tmp-step_000000000009" / "sub").mkdir(parents=True)
    mgr.save(2, _tree(1), blocking=True)
    assert not list(tmp_path.glob(".tmp-step_*"))
    out, _ = mgr.load(_tree(1))
    _assert_trees_equal(_tree(1), out)


def test_async_save_failure_reraises(tmp_path, monkeypatch):
    """A failed async save must raise from wait() (and from the next
    save()) — the seed's daemon thread swallowed the exception and wait()
    reported success over a missing checkpoint."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(0)

    def boom(step, names, payload, dense_specs):
        raise OSError("disk full")

    monkeypatch.setattr(mgr, "_save_host", boom)
    mgr.save(1, tree)              # async: exception lands in the thread
    with pytest.raises(CheckpointError, match="disk full"):
        mgr.wait()
    monkeypatch.undo()
    mgr.save(2, tree, blocking=True)   # manager stays usable after failure
    assert mgr.latest_step() == 2

    monkeypatch.setattr(mgr, "_save_host", boom)
    mgr.save(3, tree)
    with pytest.raises(CheckpointError, match="disk full"):
        mgr.save(4, tree, blocking=True)   # next save() re-raises too


def test_corrupt_pack_rejected(tmp_path):
    """A flipped bit anywhere in a record's payload fails the frame CRC and
    load() must refuse with a clear error, not silently misdecode."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(4)
    mgr.save(1, tree, blocking=True)
    man = mgr.manifest()
    e = next(x for x in man["leaves"] if x["mode"] == "enec")
    pack = tmp_path / "step_000000000001" / man["packs"][e["pack"]]
    buf = bytearray(pack.read_bytes())
    buf[e["offset"] + wire.FRAME_HEADER_BYTES + e["bytes"] // 2] ^= 0x08
    pack.write_bytes(bytes(buf))
    with pytest.raises(CheckpointError, match="CRC"):
        mgr.load(tree)


def test_corrupt_manifest_rejected(tmp_path):
    """The manifest is the one file without a CRC — damage to it must still
    surface as CheckpointError, not a bare JSONDecodeError."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(7), blocking=True)
    mpath = tmp_path / "step_000000000001" / "manifest.json"
    mpath.write_text(mpath.read_text()[:40])   # truncated json
    with pytest.raises(CheckpointError, match="corrupt"):
        mgr.load(_tree(7))


def test_truncated_pack_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path, writers=1)
    tree = _tree(5)
    mgr.save(1, tree, blocking=True)
    pack = tmp_path / "step_000000000001" / "pack-00000.bin"
    pack.write_bytes(pack.read_bytes()[:-10])
    with pytest.raises(CheckpointError):
        mgr.load(tree)


def test_v1_checkpoint_still_loads(tmp_path):
    """Back-compat: the seed's per-leaf t_*.enec layout must keep loading
    bit-exactly through the hardened path."""
    from repro.core import api as enec_api

    tree = _tree(6)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    cdir = tmp_path / "step_000000000042"
    cdir.mkdir(parents=True)
    manifest = {"step": 42, "leaves": [], "format": "enec-v1"}
    for i, (path, leaf) in enumerate(flat):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        leaf = jnp.asarray(leaf)
        if leaf.dtype in enec_api.SUPPORTED_FLOAT_DTYPES:
            ct = enec_api.compress_array(leaf)
            blob = wire.to_wire(ct)
            entry = {"name": name, "index": i, "shape": list(ct.shape),
                     "dtype": ct.dtype_str, "mode": ct.mode}
        else:
            host = np.asarray(jax.device_get(leaf))
            blob = b"RAW0" + host.tobytes()
            entry = {"name": name, "index": i, "shape": list(host.shape),
                     "dtype": str(host.dtype), "mode": "npraw"}
        entry["bytes"] = len(blob)
        (cdir / f"t_{i:05d}.enec").write_bytes(blob)
        manifest["leaves"].append(entry)
    (cdir / "manifest.json").write_text(json.dumps(manifest))
    (tmp_path / "LATEST").write_text(cdir.name)
    mgr = CheckpointManager(tmp_path)
    out, man = mgr.load(tree)
    _assert_trees_equal(tree, out)
    assert man["step"] == 42


# ---------------------------------------------------------------------------
# compressed -> serving-handle restore (ISSUE 3 acceptance)
# ---------------------------------------------------------------------------

def _smoke_model():
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def _serve(cfg, model, tree):
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    logits, cache = model.prefill_fn(tree, pb, 16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec, _ = model.decode_fn(tree, cache, tok)
    return np.asarray(logits), np.asarray(dec)


@pytest.mark.parametrize("layout,mode", [("fused", "fused"),
                                         ("stream", "stream"),
                                         ("fused", "stream"),
                                         (None, "fused")])
def test_load_for_serving_bit_identical_logits(tmp_path, layout, mode):
    """save -> load_for_serving -> serve must produce logits BIT-IDENTICAL
    to serving the original params under the same mode, for matching
    layouts (direct record->handle restore), mismatched layouts
    (device-side re-layout), and plain checkpoints (device decompress +
    policy)."""
    from repro.runtime.streaming import assign_weight_modes

    cfg, model, params = _smoke_model()
    ref = _serve(cfg, model, assign_weight_modes(params, mode=mode,
                                                 min_bytes=1024, shards=2))
    mgr = CheckpointManager(tmp_path, serving_layout=layout,
                            serving_min_bytes=1024, serving_shards=2)
    mgr.save(3, {"params": params, "opt": {"mu": jnp.zeros((256,),
                                                           jnp.float32)}},
             blocking=True)
    tree, _ = mgr.load_for_serving(params, mode=mode, prefix="params",
                                   min_bytes=1024, shards=2)
    got = _serve(cfg, model, tree)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


def test_load_for_serving_transfers_compressed_bytes_only(tmp_path):
    """The acceptance counter: restoring a serving-layout checkpoint must
    stage ONLY compressed bytes host->device — the dense weights never
    exist on the host."""
    from repro.runtime.weights import FusedWeight, is_handle

    cfg, model, params = _smoke_model()
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(1, {"params": params}, blocking=True)
    wire.reset_transfer_stats()
    tree, _ = mgr.load_for_serving(
        jax.eval_shape(model.init, jax.random.key(0)),
        mode="fused", prefix="params", min_bytes=1024)
    h2d = wire.transfer_stats()["h2d_bytes"]
    dense = sum(l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(params))
    assert 0 < h2d < dense, (h2d, dense)
    handles = [l for l in jax.tree_util.tree_leaves(tree, is_leaf=is_handle)
               if isinstance(l, FusedWeight)]
    assert handles, "no record deserialized directly into a FusedWeight"
    _serve(cfg, model, tree)   # and the restored tree actually serves


def test_load_for_serving_skips_optimizer_records(tmp_path):
    """Partial load-by-name: serving restore must never read optimizer
    records — even corrupt opt bytes on disk cannot hurt it, while a full
    load() refuses them."""
    cfg, model, params = _smoke_model()
    opt = {"mu": make_realistic_bf16(120_000, seed=21)}
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(2, {"params": params, "opt": opt}, blocking=True)
    man = mgr.manifest()
    e = next(x for x in man["leaves"] if x["name"].startswith("opt/"))
    pack = tmp_path / "step_000000000002" / man["packs"][e["pack"]]
    buf = bytearray(pack.read_bytes())
    buf[e["offset"] + wire.FRAME_HEADER_BYTES + 3] ^= 0xFF
    pack.write_bytes(bytes(buf))
    tree, _ = mgr.load_for_serving(params, mode="fused", prefix="params",
                                   min_bytes=1024)   # must not raise
    with pytest.raises(CheckpointError):
        mgr.load({"params": params, "opt": opt})


def test_load_for_serving_missing_record_is_clear(tmp_path):
    _, _, params = _smoke_model()
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"params": params}, blocking=True)
    with pytest.raises(CheckpointError, match="lacks weight records"):
        mgr.load_for_serving(params, mode="fused", prefix="wrongprefix")


def test_handle_tree_with_dense_weights_saves_and_loads(tmp_path):
    """Saving a tree that already contains handles — including DenseWeight
    fallbacks at policy-eligible positions — must produce a loadable
    checkpoint (regression: the dense spec used to clobber the serving
    record's handle spec, leaving an unrecoverable checkpoint)."""
    from repro.runtime.streaming import assign_weight_modes

    cfg, model, params = _smoke_model()
    dense_tree = assign_weight_modes(params, mode="dense", min_bytes=1024)
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(1, {"params": dense_tree}, blocking=True)
    out, _ = mgr.load({"params": params})
    _assert_trees_equal(params, out["params"])
    tree, _ = mgr.load_for_serving(params, mode="fused", prefix="params",
                                   min_bytes=1024)
    ref = _serve(cfg, model, assign_weight_modes(params, mode="fused",
                                                 min_bytes=1024))
    got = _serve(cfg, model, tree)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))

    # a fused handle tree round-trips through its own records too
    fused_tree = assign_weight_modes(params, mode="fused", min_bytes=1024)
    mgr2 = CheckpointManager(tmp_path / "h", serving_layout="fused",
                             serving_min_bytes=1024)
    mgr2.save(2, {"params": fused_tree}, blocking=True)
    out2, _ = mgr2.load({"params": params})
    _assert_trees_equal(params, out2["params"])


def test_load_for_serving_rejects_shape_mismatch(tmp_path):
    """An adopted serving record must be validated against the model's leaf
    shape — a different-size model with identical names fails with a clear
    error, not a downstream trace-time shape explosion."""
    _, _, params = _smoke_model()
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(1, {"params": params}, blocking=True)
    wrong = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((l.shape[0] + 1,) + l.shape[1:],
                                       l.dtype), params)
    with pytest.raises(CheckpointError, match="vs model"):
        mgr.load_for_serving(wrong, mode="fused", prefix="params",
                             min_bytes=1024)


def test_load_for_serving_honors_requested_shards(tmp_path):
    """Adopting a stored stream record must respect the caller's TP width:
    a shard-count mismatch re-lays-out on device instead of silently
    keeping the checkpoint's sharding."""
    from repro.runtime.weights import StreamedWeight, is_handle

    _, _, params = _smoke_model()
    mgr = CheckpointManager(tmp_path, serving_layout="stream",
                            serving_min_bytes=1024, serving_shards=2)
    mgr.save(1, {"params": params}, blocking=True)
    for req in (2, 1):
        tree, _ = mgr.load_for_serving(params, mode="stream",
                                       prefix="params", min_bytes=1024,
                                       shards=req)
        handles = [l for l in jax.tree_util.tree_leaves(tree,
                                                        is_leaf=is_handle)
                   if isinstance(l, StreamedWeight)]
        assert handles
        assert all(h.ct.shards == req for h in handles), req


def test_corrupt_v1_header_raises_checkpoint_error(tmp_path):
    """v1 blobs have no CRC, so header corruption must still surface as a
    CheckpointError naming the record — not a bare numpy ValueError."""
    from repro.core import api as enec_api

    x = make_realistic_bf16(40_000, seed=30)
    blob = bytearray(wire.to_wire(enec_api.compress_array(x)))
    blob[8] = 9          # ndim u32: 1 -> 9, shape read overruns the buffer
    cdir = tmp_path / "step_000000000001"
    cdir.mkdir(parents=True)
    (cdir / "t_00000.enec").write_bytes(bytes(blob))
    manifest = {"step": 1, "format": "enec-v1", "leaves": [
        {"name": "w", "index": 0, "shape": [40_000], "dtype": "bfloat16",
         "mode": "enec", "bytes": len(blob)}]}
    (cdir / "manifest.json").write_text(json.dumps(manifest))
    (tmp_path / "LATEST").write_text(cdir.name)
    with pytest.raises(CheckpointError, match="w"):
        CheckpointManager(tmp_path).load({"w": x})


def test_optimizer_mirrors_stay_plain_records(tmp_path):
    """Optimizer state mirroring the weight paths ('opt/.../wq') must not
    be re-laid-out into serving records it can never serve."""
    _, _, params = _smoke_model()
    moments = jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) ** 2).astype(l.dtype), params)
    tree = {"params": params, "opt": {"mu": moments}}
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(1, tree, blocking=True)
    man = mgr.manifest()
    for e in man["leaves"]:
        if e["name"].startswith("opt/"):
            assert "stack" not in e and \
                e.get("handle", {}).get("kind") not in ("stream", "fused"), e
    assert any("stack" in e for e in man["leaves"]
               if e["name"].startswith("params/"))
    out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)


def test_serving_layout_checkpoint_restores_dense_training_tree(tmp_path):
    """A serving-layout checkpoint is still a full-fidelity training
    checkpoint: load() must materialize the original dense leaves
    bit-exactly from the stacked serving records."""
    _, _, params = _smoke_model()
    tree = {"params": params, "opt": {"mu": jnp.zeros((64,), jnp.float32)}}
    mgr = CheckpointManager(tmp_path, serving_layout="stream",
                            serving_min_bytes=1024, serving_shards=2)
    mgr.save(5, tree, blocking=True)
    out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)
