"""ENEC checkpointing: bit-exact restore, atomicity, retention, resume."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from conftest import make_realistic_bf16


def _tree(seed=0):
    return {
        "params": {"w": make_realistic_bf16(120_000, seed=seed),
                   "b": jnp.zeros((64,), jnp.bfloat16)},
        "opt": {"m": jnp.asarray(np.random.default_rng(seed)
                                 .standard_normal(1000), jnp.float32),
                "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_trees_equal(a, b):
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape, pa
        np.testing.assert_array_equal(
            la.reshape(-1).view(np.uint8), lb.reshape(-1).view(np.uint8),
            err_msg=str(pa))


def test_save_load_bit_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = _tree()
    mgr.save(100, tree, blocking=True)
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    assert manifest["step"] == 100
    assert manifest["ratio"] > 1.05  # ENEC actually compressed the floats


def test_latest_pointer_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree(step), blocking=True)
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1].endswith("4")


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(3)
    mgr.save(5, tree)          # async
    mgr.wait()
    out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(1)
    mgr.save(1, tree, blocking=True)
    # simulate crash debris: stale tmp dir must not affect load
    (tmp_path / ".tmp-step_000000000002").mkdir()
    out, manifest = mgr.load(tree)
    assert manifest["step"] == 1
    _assert_trees_equal(tree, out)


def test_save_batches_encode_dispatches(tmp_path):
    """Float leaves ride the batched pipeline: leaves whose searched
    (n, m, L) coincide share one encode dispatch (per-leaf searched params —
    NOT shared — so unrelated same-shape tensors keep their own ratio);
    restore must stay bit-exact per leaf."""
    import repro.core.api as enec_api

    w = make_realistic_bf16(64_000, seed=11).reshape(160, 400)
    # Adam-nu-like second moment: same shape, squared values, so its exponent
    # distribution sits far below the weights' — per-leaf search MUST give it
    # different params (sharing them costs ~6% ratio)
    nu = (jnp.asarray(w, jnp.float32) ** 2).astype(jnp.bfloat16)
    tree = {"blk0": {"w": w},
            "blk1": {"w": make_realistic_bf16(64_000, seed=12).reshape(160, 400)},
            "blk2": {"w": make_realistic_bf16(64_000, seed=13).reshape(160, 400)},
            "nu": nu}
    mgr = CheckpointManager(tmp_path)
    enec_api.reset_encode_cache_stats()
    mgr.save(3, tree, blocking=True)
    st = enec_api.encode_cache_stats()
    # far fewer dispatches than leaves is the point; typically 1-2 buckets
    assert st["dispatches"] <= 2, st
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    assert all(by_name[n]["mode"] == "enec"
               for n in ("blk0/w", "blk1/w", "blk2/w", "nu"))
    assert tuple(by_name["nu"]["params"]) != tuple(by_name["blk0/w"]["params"])


def test_const_leaf_in_group_still_safe(tmp_path):
    """A constant leaf inside a same-shape group must fall back to the
    per-leaf path (const escape) without corrupting its siblings."""
    tree = {"a": make_realistic_bf16(40_000, seed=15),
            "b": jnp.zeros((40_000,), jnp.bfloat16)}
    mgr = CheckpointManager(tmp_path)
    mgr.save(4, tree, blocking=True)
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    modes = {e["name"]: e["mode"] for e in manifest["leaves"]}
    assert modes["b"] == "const"


def test_manifest_reports_compression(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _tree(2), blocking=True)
    manifest = json.loads(
        (tmp_path / "step_000000000009" / "manifest.json").read_text())
    modes = {e["mode"] for e in manifest["leaves"]}
    assert "enec" in modes          # big float leaves compressed
    assert manifest["compressed_bytes"] < manifest["raw_bytes"]
