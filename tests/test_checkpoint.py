"""ENEC checkpointing: bit-exact restore, atomicity, retention, resume."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from conftest import make_realistic_bf16


def _tree(seed=0):
    return {
        "params": {"w": make_realistic_bf16(120_000, seed=seed),
                   "b": jnp.zeros((64,), jnp.bfloat16)},
        "opt": {"m": jnp.asarray(np.random.default_rng(seed)
                                 .standard_normal(1000), jnp.float32),
                "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_trees_equal(a, b):
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape, pa
        np.testing.assert_array_equal(
            la.reshape(-1).view(np.uint8), lb.reshape(-1).view(np.uint8),
            err_msg=str(pa))


def test_save_load_bit_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = _tree()
    mgr.save(100, tree, blocking=True)
    out, manifest = mgr.load(tree)
    _assert_trees_equal(tree, out)
    assert manifest["step"] == 100
    assert manifest["ratio"] > 1.05  # ENEC actually compressed the floats


def test_latest_pointer_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree(step), blocking=True)
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1].endswith("4")


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(3)
    mgr.save(5, tree)          # async
    mgr.wait()
    out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(1)
    mgr.save(1, tree, blocking=True)
    # simulate crash debris: stale tmp dir must not affect load
    (tmp_path / ".tmp-step_000000000002").mkdir()
    out, manifest = mgr.load(tree)
    assert manifest["step"] == 1
    _assert_trees_equal(tree, out)


def test_manifest_reports_compression(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _tree(2), blocking=True)
    manifest = json.loads(
        (tmp_path / "step_000000000009" / "manifest.json").read_text())
    modes = {e["mode"] for e in manifest["leaves"]}
    assert "enec" in modes          # big float leaves compressed
    assert manifest["compressed_bytes"] < manifest["raw_bytes"]
