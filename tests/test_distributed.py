"""Multi-device tests. Each spawns a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (jax locks the device
count at first init, so the main pytest process must stay single-device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("REPRO_DRYRUN", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_param_sharding_rules_8dev():
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.runtime import sharding
    mesh = make_mesh((4, 2), ("data", "model"))
    params = {
        "embed": jnp.zeros((512, 64)),
        "period": [{"attn": {"wq": jnp.zeros((2, 64, 64)),
                             "wo": jnp.zeros((2, 64, 64))},
                    "mlp": {"w_gate": jnp.zeros((2, 64, 128)),
                            "w_down": jnp.zeros((2, 128, 64))},
                    "moe": {"e_gate": jnp.zeros((2, 4, 64, 128))},
                    "pre_norm": jnp.zeros((2, 64))}],
        "head": jnp.zeros((64, 512)),
    }
    specs = sharding.param_pspecs(params, mesh, mode="train")
    pos = specs["period"][0]
    assert specs["embed"] == P("model", "data"), specs["embed"]
    assert pos["attn"]["wq"] == P(None, "data", "model")
    assert pos["attn"]["wo"] == P(None, "model", "data")
    assert pos["moe"]["e_gate"] == P(None, "model", None, "data")
    assert pos["pre_norm"] == P(None, None)
    serve = sharding.param_pspecs(params, mesh, mode="serve")
    assert serve["period"][0]["attn"]["wq"] == P(None, None, "model")
    assert serve["period"][0]["moe"]["e_gate"] == P(None, "model", None, "data")
    print("rules ok")
    """)


def test_pjit_train_step_runs_8dev():
    _run("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.optim import adamw
    from repro.runtime import sharding
    from repro.runtime.steps import build_train_step
    from repro.launch.mesh import make_mesh
    from repro.data.pipeline import DataConfig, batch_at

    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, remat=True)
    mesh = make_mesh((4, 2), ("data", "model"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw.init(params)
    pspecs = sharding.param_pspecs(params, mesh, mode="train")
    named = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, named(pspecs))
    opt_specs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
    opt = jax.device_put(opt, named(opt_specs))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    step_fn = jax.jit(build_train_step(model, adamw.AdamWConfig(lr=1e-3)),
                      donate_argnums=(0, 1))
    losses = []
    for step in range(4):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, step).items()}
        bspec = named(sharding.batch_pspecs(
            {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch.items()}, mesh, 8))
        batch = jax.device_put(batch, bspec)
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses  # learning happens
    print("pjit train ok", losses)
    """)


def test_compressed_allreduce_bit_identical_2pods():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.launch.mesh import make_mesh
    from repro.optim.grad_compress import compressed_allreduce
    from repro.core import search_for_array, BF16

    mesh = make_mesh((2, 4), ("pod", "data"))
    rng = np.random.default_rng(0)
    g = rng.standard_normal((2, 4096)).astype("float32") * 1e-3
    grads = jnp.asarray(g).astype(jnp.bfloat16)
    p = search_for_array(np.asarray(grads), BF16, block_elems=4096)

    @partial(shard_map, mesh=mesh, in_specs=P("pod", None),
             out_specs=P("pod", None))
    def sync_enec(x):
        return compressed_allreduce(x[0], "pod", p,
                                    block_elems=4096)[None]

    @partial(shard_map, mesh=mesh, in_specs=P("pod", None),
             out_specs=P("pod", None))
    def sync_plain(x):
        return jax.lax.psum(x, "pod")

    a = np.asarray(sync_enec(grads)).astype(np.float32)
    b = np.asarray(sync_plain(grads)).astype(np.float32)
    np.testing.assert_array_equal(a, b)   # lossless => bit-identical sums
    print("compressed allreduce ok")
    """)


def test_elastic_reshard_4_to_8():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.runtime import elastic, sharding
    cfg = get_smoke_config("llama3_2_1b")
    m4 = elastic.best_mesh_for(cfg, n_devices=4, max_model=4)
    m8 = elastic.best_mesh_for(cfg, n_devices=8, max_model=4)
    assert np.prod(list(m4.shape.values())) == 4
    assert np.prod(list(m8.shape.values())) == 8
    x = {"w": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)}
    specs = {"w": jax.sharding.PartitionSpec(None, "model")
             if "model" in m8.shape else jax.sharding.PartitionSpec()}
    moved = elastic.reshard(x, m8, specs)
    np.testing.assert_array_equal(np.asarray(moved["w"]), np.asarray(x["w"]))
    print("elastic ok")
    """)
