"""Hierarchical halving bit-packing: exhaustive + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import bitio


@pytest.mark.parametrize("width", list(range(0, 17)))
@pytest.mark.parametrize("n", [8, 64, 256, 1024])
def test_roundtrip_all_widths(width, n):
    rng = np.random.default_rng(width * 1000 + n)
    vals = rng.integers(0, 1 << max(width, 1), size=n, dtype=np.uint32)
    if width == 0:
        vals = np.zeros(n, np.uint32)
    v = jnp.asarray(vals.astype(np.uint16 if width <= 16 else np.uint32))
    packed = bitio.pack_fixed(v, width)
    assert packed.shape[-1] == bitio.packed_nbytes(n, width)
    out = bitio.unpack_fixed(packed, n, width)
    np.testing.assert_array_equal(np.asarray(out), vals & ((1 << width) - 1)
                                  if width else np.zeros(n))


def test_packed_nbytes_matches_bit_count():
    # fixed-length coding: total bytes == ceil(n*width/8) whenever n*width
    # is a multiple of 8 (power-of-two lanes) — no hidden padding
    for n in (8, 64, 1024, 16384):
        for width in range(1, 17):
            got = bitio.packed_nbytes(n, width)
            assert got == (n * width + 7) // 8, (n, width, got)


def test_batched_leading_dims():
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, 8, size=(3, 5, 64), dtype=np.uint16))
    packed = bitio.pack_fixed(vals, 3)
    assert packed.shape == (3, 5, bitio.packed_nbytes(64, 3))
    out = bitio.unpack_fixed(packed, 64, 3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(vals))


@given(st.integers(1, 15), st.integers(3, 10), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_roundtrip_property(width, log_n, seed):
    n = 1 << log_n
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << width, size=n, dtype=np.uint16)
    out = bitio.unpack_fixed(bitio.pack_fixed(jnp.asarray(vals), width),
                             n, width)
    np.testing.assert_array_equal(np.asarray(out), vals)


def test_bool_mask_roundtrip():
    rng = np.random.default_rng(1)
    bits = jnp.asarray(rng.random((4, 128)) < 0.3)
    packed = bitio.pack_bool_mask(bits)
    assert packed.shape == (4, 16)
    out = bitio.unpack_bool_mask(packed, 128)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


@given(st.integers(1, 12), st.integers(0, 200), st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_np_exact_bits_roundtrip(width, count, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << width, size=count, dtype=np.uint32)
    buf = bitio.np_pack_bits_exact(vals, width)
    assert len(buf) == (count * width + 7) // 8
    out = bitio.np_unpack_bits_exact(buf, count, width)
    np.testing.assert_array_equal(out, vals)
