"""ISSUE 9: the resilient continuous-batching serving engine.

The load-bearing claims of docs/TRAFFIC.md, each asserted here:
engine logits are BIT-IDENTICAL to the one-shot serve path in every
weight-execution mode (row-independence of the model ops makes slot
occupancy invisible); admission is bounded with deterministic
reject-with-reason; deadlines shed queued work before any prefill and
evict in-flight work at step granularity with the KV slot reclaimed; a
poisoned request is evicted alone (survivors bit-identical, health
``degraded`` not ``failed``); drain finishes in-flight work and refuses
new; the overload governor sheds queued low-priority work and degrades
admission, never admitted-request latency.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime import faults as rt_faults
from repro.runtime.admission import (AdmissionQueue, OverloadGovernor,
                                     Request)
from repro.runtime.engine import (Engine, EngineConfig, EngineError,
                                  ServerHealth)
from repro.runtime.faults import FaultSpec
from repro.runtime.retry import RetryPolicy
from repro.runtime.streaming import assign_weight_modes

PROMPT_LEN = 6
N_NEW = 4


class FakeClock:
    """Deterministic time source for deadline tests (no real sleeping)."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (4, PROMPT_LEN), 0, cfg.vocab_size), np.int32)
    return cfg, model, params, prompts


def _one_shot(model, params, prompt, n_new, max_len):
    """The pre-engine serve loop: batch=1 prefill + argmax decode."""
    logits, cache = model.prefill_fn(params, {"tokens": prompt[None, :]},
                                     max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks, outs = [int(np.asarray(tok)[0])], [np.asarray(logits)[0]]
    for _ in range(n_new - 1):
        logits, cache = model.decode_fn(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(int(np.asarray(tok)[0]))
        outs.append(np.asarray(logits)[0])
    return toks, outs


def _ecfg(**kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_prompt_len", PROMPT_LEN)
    kw.setdefault("max_new_tokens", N_NEW)
    kw.setdefault("collect_logits", True)
    return EngineConfig(**kw)


def _assert_bit_identical(got_logits, ref_logits, msg=""):
    assert len(got_logits) == len(ref_logits), msg
    for i, (g, r) in enumerate(zip(got_logits, ref_logits)):
        np.testing.assert_array_equal(
            np.asarray(g).view(np.uint32), np.asarray(r).view(np.uint32),
            err_msg=f"{msg} token {i}")


# ---------------------------------------------------------------------------
# bit-parity with the one-shot path (the tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "stream", "fused"])
def test_engine_logits_bit_identical_to_one_shot(setup, mode):
    cfg, model, params, prompts = setup
    tree = assign_weight_modes(params, mode=mode, min_bytes=1024, shards=2)
    engine = Engine(model, tree, _ecfg())
    reqs = [engine.submit(prompts[i], N_NEW, name=f"r{i}") for i in range(2)]
    engine.run_until_idle()
    for i, req in enumerate(reqs):
        assert req.state == "done", (req.state, req.detail)
        ref_toks, ref_logits = _one_shot(model, tree, prompts[i], N_NEW,
                                         engine.config.max_len)
        assert req.tokens == ref_toks, mode
        _assert_bit_identical(req.logits, ref_logits, f"{mode} req{i}")


def test_staggered_join_keeps_bit_parity(setup):
    """Continuous batching: a request that joins mid-flight (while another
    is already decoding) still produces exactly the one-shot logits, and
    so does the request it joined."""
    cfg, model, params, prompts = setup
    engine = Engine(model, params, _ecfg(max_slots=4))
    first = engine.submit(prompts[0], N_NEW, name="first")
    engine.step()            # first is admitted and emits token 1
    engine.step()            # first decodes alone
    late = engine.submit(prompts[1], N_NEW, name="late")
    engine.run_until_idle()
    for req, prompt in ((first, prompts[0]), (late, prompts[1])):
        assert req.state == "done"
        ref_toks, ref_logits = _one_shot(model, params, prompt, N_NEW,
                                         engine.config.max_len)
        assert req.tokens == ref_toks
        _assert_bit_identical(req.logits, ref_logits, req.name)
    # both requests shared the ring: slots differ, logits don't
    st = engine.stats()["engine"]
    assert st["prefills"] == 2 and st["done"] == 2


def test_bucket_compiles_are_bounded(setup):
    """4 concurrent requests over a 4-slot ring compile at most
    log2(4)+1 = 3 step variants, and only the ones actually occupied."""
    cfg, model, params, prompts = setup
    engine = Engine(model, params, _ecfg(max_slots=4, queue_depth=8))
    for i in range(4):
        engine.submit(prompts[i], N_NEW, name=f"b{i}")
    engine.run_until_idle()
    buckets = engine.stats()["engine"]["compiled_buckets"]
    assert set(buckets) <= {1, 2, 4} and len(buckets) <= 3


# ---------------------------------------------------------------------------
# admission: bounded queue, deterministic reject-with-reason
# ---------------------------------------------------------------------------

def test_queue_full_rejection_is_deterministic(setup):
    cfg, model, params, prompts = setup
    for _ in range(2):   # identical outcome on repeat runs
        engine = Engine(model, params, _ecfg(max_slots=1, queue_depth=2))
        reqs = [engine.submit(prompts[i % 4], 1, name=f"q{i}")
                for i in range(4)]
        assert [r.state for r in reqs] == ["queued", "queued",
                                           "rejected", "rejected"]
        assert [r.detail for r in reqs[2:]] == ["queue_full", "queue_full"]
        st = engine.stats()["queue"]
        assert st["rejected_queue_full"] == 2
        assert st["max_depth_seen"] == 2 <= engine.queue.depth
        engine.run_until_idle()
        assert [r.state for r in reqs[:2]] == ["done", "done"]


def test_invalid_request_raises_not_rejects(setup):
    cfg, model, params, prompts = setup
    engine = Engine(model, params, _ecfg())
    with pytest.raises(EngineError, match="prompt length"):
        engine.submit(np.zeros((PROMPT_LEN + 5,), np.int32))
    with pytest.raises(EngineError, match="max_new_tokens"):
        engine.submit(prompts[0], N_NEW + 1)


# ---------------------------------------------------------------------------
# deadlines: shed before prefill, evict at step granularity, honest bookkeeping
# ---------------------------------------------------------------------------

def test_expired_queued_request_shed_before_prefill(setup):
    cfg, model, params, prompts = setup
    clock = FakeClock()
    engine = Engine(model, params, _ecfg(), clock=clock, sleep=lambda s: None)
    req = engine.submit(prompts[0], N_NEW, ttft_deadline_s=1.0, name="late")
    clock.advance(2.0)       # TTFT deadline passes while queued
    engine.step()
    assert req.state == "shed" and req.detail == "deadline"
    st = engine.stats()["engine"]
    assert st["prefills"] == 0 and st["shed"] == 1


def test_in_flight_deadline_evicts_and_reclaims_slot(setup):
    cfg, model, params, prompts = setup
    clock = FakeClock()
    engine = Engine(model, params, _ecfg(max_slots=2, queue_depth=8),
                    clock=clock, sleep=lambda s: None)
    keeper = engine.submit(prompts[0], N_NEW, deadline_s=1000.0,
                           name="keeper")
    victim = engine.submit(prompts[1], N_NEW, deadline_s=5.0, name="victim")
    engine.step()            # both admitted, first decode
    victim_slot = victim.slot
    assert victim_slot is not None
    clock.advance(10.0)      # victim's total deadline passes mid-flight
    engine.step()
    assert victim.state == "evicted" and victim.detail == "deadline"
    assert victim.slot is None
    assert keeper.state in ("running", "done")
    # the reclaimed slot is reused by the next admission
    succ = engine.submit(prompts[2], N_NEW, deadline_s=1000.0, name="succ")
    engine.step()
    assert succ.slot == victim_slot
    engine.run_until_idle()
    assert keeper.state == "done" and succ.state == "done"
    assert engine.stats()["engine"]["evicted_deadline"] == 1
    # the keeper was never perturbed by the eviction
    ref_toks, ref_logits = _one_shot(model, params, prompts[0], N_NEW,
                                     engine.config.max_len)
    assert keeper.tokens == ref_toks
    _assert_bit_identical(keeper.logits, ref_logits, "keeper")


def test_late_completion_is_timed_out_not_done(setup):
    """A request that finishes past its total deadline must be accounted
    timed_out: the CI deadline gate (admitted-and-done => within deadline)
    holds by construction."""
    cfg, model, params, prompts = setup
    clock = FakeClock()
    engine = Engine(model, params, _ecfg(), clock=clock,
                    sleep=lambda s: None)
    req = engine.submit(prompts[0], 1, deadline_s=5.0, name="tardy")
    # the deadline passes between admission and completion: advance the
    # clock from inside the prefill dispatch
    orig = engine._run_prefill

    def slow_prefill(r, slot):
        clock.advance(10.0)
        orig(r, slot)

    engine._run_prefill = slow_prefill
    engine.run_until_idle()
    assert req.state == "timed_out"
    st = engine.stats()["engine"]
    assert st["timed_out"] == 1 and st["done"] == 0


# ---------------------------------------------------------------------------
# serving-time faults: transient absorbed, permanent evicts only the poisoned
# ---------------------------------------------------------------------------

def _fault_retry():
    return RetryPolicy(base_delay_s=0.0001, max_delay_s=0.001,
                       sleep=lambda s: None)


def test_transient_step_fault_absorbed_by_retry(setup):
    cfg, model, params, prompts = setup
    engine = Engine(model, params, _ecfg(), retry=_fault_retry())
    with rt_faults.inject(FaultSpec(kind="step", match="flaky", times=2)):
        req = engine.submit(prompts[0], N_NEW, name="flaky")
        engine.run_until_idle()
    assert req.state == "done"
    assert req.retries == 2
    assert engine.stats()["engine"]["fault_retries"] == 2
    assert engine.health.state == "ready"       # absorbed, not degraded
    ref_toks, _ = _one_shot(model, params, prompts[0], N_NEW,
                            engine.config.max_len)
    assert req.tokens == ref_toks


def test_permanent_step_fault_evicts_only_poisoned(setup):
    """The fault-isolation acceptance: a permanent step fault on one
    request evicts exactly it; the survivors' tokens AND logits are
    bit-identical to a fault-free run; health degrades, never fails."""
    cfg, model, params, prompts = setup
    # reference: fault-free run with the same three requests
    ref_engine = Engine(model, params, _ecfg(max_slots=4, queue_depth=8))
    ref = [ref_engine.submit(prompts[i], N_NEW, name=f"p{i}")
           for i in range(3)]
    ref_engine.run_until_idle()
    assert all(r.state == "done" for r in ref)

    engine = Engine(model, params, _ecfg(max_slots=4, queue_depth=8),
                    retry=_fault_retry())
    with rt_faults.inject(FaultSpec(kind="step", match="p1", times=-1)):
        reqs = [engine.submit(prompts[i], N_NEW, name=f"p{i}")
                for i in range(3)]
        engine.run_until_idle()
    assert reqs[1].state == "evicted" and reqs[1].detail == "fault"
    for i in (0, 2):
        assert reqs[i].state == "done", (i, reqs[i].state, reqs[i].detail)
        assert reqs[i].tokens == ref[i].tokens
        _assert_bit_identical(reqs[i].logits, ref[i].logits, f"survivor {i}")
    assert engine.health.state == "degraded"
    assert "p1" in engine.health.detail
    assert engine.stats()["engine"]["evicted_fault"] == 1


def test_mid_flight_step_fault_evicts_after_admission(setup):
    """A fault that starts firing after the request is already decoding
    evicts it mid-flight (some tokens emitted) while the rest of the
    batch finishes untouched."""
    cfg, model, params, prompts = setup
    engine = Engine(model, params, _ecfg(max_slots=4, queue_depth=8),
                    retry=_fault_retry())
    survivor = engine.submit(prompts[0], N_NEW, name="ok")
    victim = engine.submit(prompts[1], N_NEW, name="victim")
    engine.step()            # both admitted cleanly, first tokens out
    assert victim.tokens, "victim should have emitted before the fault"
    with rt_faults.inject(FaultSpec(kind="step", match="victim", times=-1)):
        engine.run_until_idle()
    assert victim.state == "evicted" and victim.detail == "fault"
    assert 1 <= len(victim.tokens) < N_NEW
    assert survivor.state == "done"
    ref_toks, ref_logits = _one_shot(model, params, prompts[0], N_NEW,
                                     engine.config.max_len)
    assert survivor.tokens == ref_toks
    _assert_bit_identical(survivor.logits, ref_logits, "survivor")
    assert engine.health.state == "degraded"


# ---------------------------------------------------------------------------
# graceful drain
# ---------------------------------------------------------------------------

def test_shutdown_drains_in_flight_and_refuses_new(setup):
    cfg, model, params, prompts = setup
    engine = Engine(model, params, _ecfg(max_slots=1, queue_depth=8))
    running = engine.submit(prompts[0], N_NEW, name="running")
    queued = engine.submit(prompts[1], N_NEW, name="queued")
    engine.step()            # running admitted; queued waits (1 slot)
    assert running.state == "running" and queued.state == "queued"
    engine.shutdown()
    assert running.state == "done"                 # in-flight finished
    assert len(running.tokens) == N_NEW
    assert queued.state == "shed" and queued.detail == "drain"
    late = engine.submit(prompts[2], N_NEW, name="too-late")
    assert late.state == "rejected" and late.detail == "draining"
    assert engine.health.state == "stopped"
    assert not engine.health.ready()


def test_shutdown_deadline_aborts_stragglers(setup):
    cfg, model, params, prompts = setup
    clock = FakeClock()
    engine = Engine(model, params, _ecfg(max_slots=1), clock=clock,
                    sleep=lambda s: None)
    req = engine.submit(prompts[0], N_NEW, name="straggler")
    engine.step()
    assert req.state == "running"
    clock.advance(0.0)
    # the drain budget expires immediately: every engine.step() inside
    # shutdown() is preceded by the deadline check
    orig_step = engine.step

    def step_advancing():
        clock.advance(100.0)
        return orig_step()

    engine.step = step_advancing
    engine.shutdown(deadline_s=50.0)
    assert req.state == "evicted" and req.detail == "abort"
    assert engine.health.state == "stopped"


# ---------------------------------------------------------------------------
# overload governor: watchdog trips shed queued work, admission degrades
# ---------------------------------------------------------------------------

def test_governor_learns_baseline_and_trips_on_slow():
    gov = OverloadGovernor(watchdog_s=5.0, overload_factor=4.0,
                           warmup_steps=3, recovery_steps=2)
    for _ in range(3):
        assert not gov.observe_step(0.1)
    assert gov.state == "nominal" and abs(gov.baseline_s - 0.1) < 1e-9
    assert gov.observe_step(1.0)            # 1.0 > 4 x 0.1: slow
    assert gov.overloaded
    baseline = gov.baseline_s
    assert gov.observe_step(10.0)           # stuck (absolute watchdog)
    assert gov.baseline_s == baseline       # violations never move the EMA
    assert not gov.observe_step(0.1)        # healthy 1/2
    assert gov.overloaded                   # still overloaded
    assert not gov.observe_step(0.1)        # healthy 2/2: recovered
    assert gov.state == "nominal"
    st = gov.stats()
    assert st["slow_steps"] == 1 and st["stuck_steps"] == 1
    assert st["trips"] == 2 and st["recoveries"] == 1


def test_governor_watchdog_catches_stuck_step_during_warmup():
    gov = OverloadGovernor(watchdog_s=5.0, warmup_steps=3)
    assert gov.observe_step(6.0)
    assert gov.overloaded and gov.baseline_s is None


def test_engine_overload_sheds_queued_and_degrades_admission(setup):
    """watchdog_s=0 makes every real decode step a violation: each step
    sheds the lowest-priority queued request, and while overloaded the
    front door rejects priority<=0 work but still admits priority>0."""
    cfg, model, params, prompts = setup
    engine = Engine(model, params,
                    _ecfg(max_slots=1, queue_depth=8, watchdog_s=0.0))
    running = engine.submit(prompts[0], N_NEW, name="running")
    low = engine.submit(prompts[1], N_NEW, priority=0, name="low")
    high = engine.submit(prompts[2], N_NEW, priority=1, name="high")
    engine.step()            # decode step trips the watchdog
    assert engine.governor.overloaded
    # the LOWEST priority queued request was shed, the higher one kept
    assert low.state == "shed" and low.detail == "overload"
    assert high.state == "queued"
    # overloaded admission: priority 0 rejected, priority > 0 admitted
    r0 = engine.submit(prompts[3], N_NEW, priority=0, name="walk-in")
    r1 = engine.submit(prompts[3], N_NEW, priority=1, name="vip")
    assert r0.state == "rejected" and r0.detail == "overloaded"
    assert r1.state == "queued"
    engine.run_until_idle()
    # the ADMITTED request finished untouched; under sustained overload
    # (every step trips here) the queued work is progressively shed —
    # admission degrades, admitted-request latency does not
    assert running.state == "done" and len(running.tokens) == N_NEW
    assert {high.state, r1.state} == {"shed"}
    assert engine.stats()["queue"]["rejected_overloaded"] == 1
    assert engine.stats()["engine"]["shed"] >= 3


# ---------------------------------------------------------------------------
# admission-layer unit tests (no model)
# ---------------------------------------------------------------------------

def test_admission_queue_sheds_lowest_priority_newest_first():
    q = AdmissionQueue(depth=8)
    reqs = [Request(prompt=np.zeros(1, np.int32), max_new_tokens=1,
                    priority=p, name=f"a{i}")
            for i, p in enumerate([1, 0, 0, 2])]
    for r in reqs:
        assert q.offer(r)[0]
    shed = q.shed_lowest_priority(2, reason="overload")
    # ties on priority 0 break newest-first: a2 before a1
    assert [r.name for r in shed] == ["a2", "a1"]
    assert len(q) == 2 and q.counters["shed_overload"] == 2


def test_admission_queue_reject_reasons_have_precedence():
    q = AdmissionQueue(depth=1)
    ok, _ = q.offer(Request(prompt=np.zeros(1, np.int32), max_new_tokens=1))
    assert ok
    full = Request(prompt=np.zeros(1, np.int32), max_new_tokens=1)
    assert q.offer(full) == (False, "queue_full")
    over = Request(prompt=np.zeros(1, np.int32), max_new_tokens=1)
    assert q.offer(over, overloaded=True) == (False, "overloaded")
    q.close()
    drained = Request(prompt=np.zeros(1, np.int32), max_new_tokens=1)
    assert q.offer(drained, overloaded=True) == (False, "draining")


def test_server_health_transitions_and_reset():
    h = ServerHealth()
    assert h.state == "initializing" and not h.ready()
    h.transition("ready")
    assert h.ready()
    h.transition("degraded", "one record on fallback")
    assert h.ready() and h.detail == "one record on fallback"
    h.transition("draining")
    assert not h.ready()
    with pytest.raises(ValueError, match="unknown health state"):
        h.transition("on-fire")
    h.reset()
    assert h.state == "initializing" and h.detail == ""
