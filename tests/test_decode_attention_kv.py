"""Fused decode attention over ENEC-compressed KV (beyond-paper kernel):
flash-decoding semantics must match dense attention to f32 accumulation
noise; the KV codec inside the kernel is element-exact."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BF16, search_for_array
from repro.kernels.decode_attention_kv import (HD, TOK, compress_kv_prefix,
                                               decode_attention_kv_enec)


def _mk(B, S, KV, grp, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    def t(shape):
        return jnp.asarray(rng.standard_normal(shape).astype("float32")
                           * scale).astype(jnp.bfloat16)
    k, v = t((B, S, KV, HD)), t((B, S, KV, HD))
    q = t((B, KV, grp, HD))
    both = np.concatenate([np.asarray(jax.device_get(k)).ravel(),
                           np.asarray(jax.device_get(v)).ravel()])
    p = search_for_array(both, BF16, block_elems=TOK * HD)
    return q, k, v, p


def _dense(q, k, v):
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kf) / math.sqrt(HD)
    return jnp.einsum("bkgs,bskh->bkgh", jax.nn.softmax(scores, -1), vf)


@pytest.mark.parametrize("B,S,KV,grp", [(1, 128, 1, 1), (2, 256, 2, 4),
                                        (1, 512, 4, 8)])
def test_matches_dense_attention(B, S, KV, grp):
    q, k, v, p = _mk(B, S, KV, grp, seed=S)
    got = decode_attention_kv_enec(q, compress_kv_prefix(k, p),
                                   compress_kv_prefix(v, p), p)
    want = _dense(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_compressed_bytes_smaller_than_dense():
    q, k, v, p = _mk(1, 512, 2, 2, seed=7)
    ks = compress_kv_prefix(k, p)
    comp = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(ks))
    dense = k.size * 2
    assert comp < dense  # HBM reads shrink by ~the compression ratio
