"""ISSUE 7: the double-buffered decode-prefetch pipeline (runtime/overlap).

The pipeline is a pure scheduling transform: logits with overlap on/off
must be BIT-identical in every serving mode and family (the prefetch
decode is the same exact inverse of the lossless coder as the serial
per-leaf path, finished by the same moveaxis+astype, consumed by the same
canonical contraction).  The scan and unrolled drivers must agree, and the
per-step prefetch must cost exactly ``buckets_per_layer`` decode
dispatches — one batched decode per decoder bucket, never one per leaf.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.codec_api import Codec, use_codec
from repro.models import build_model
from repro.runtime.overlap import (build_schedule, decode_layer,
                                   overlap_enabled, pipeline_scan)
from repro.runtime.streaming import assign_weight_modes, stream_stats
from repro.runtime.weights import StreamedWeight, is_handle


def _serve(model, tree, pb, max_len, steps=2):
    logits, cache = model.prefill_fn(tree, pb, max_len)
    outs = [np.asarray(logits)]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        logits, cache = model.decode_fn(tree, cache, tok)
        outs.append(np.asarray(logits))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return outs


def _assert_bit_equal(ref, got, msg):
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32),
                                      err_msg=msg)


@pytest.mark.parametrize("arch,scan", [
    ("llama3_2_1b", True),          # dense, multi-period scan
    ("llama3_2_1b", False),         # dense, unrolled
    ("phi3_5_moe_42b_a6_6b", True),   # MoE: materialize-execution experts
    ("xlstm_125m", True),           # SSM: n_periods == 1 (epilogue-only)
])
def test_overlap_logits_bit_identical_stream_mode(arch, scan):
    cfg = dataclasses.replace(get_smoke_config(arch), scan_layers=scan)
    model_off = build_model(dataclasses.replace(cfg, overlap="off"))
    model_on = build_model(dataclasses.replace(cfg, overlap="on"))
    params = model_off.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                               shards=2)
    assert stream_stats(tree)["streamed_tensors"] > 0
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    ref = _serve(model_off, tree, pb, 16)
    got = _serve(model_on, tree, pb, 16)
    _assert_bit_equal(ref, got, f"{arch} scan={scan} overlap on vs off")


@pytest.mark.parametrize("mode", ["dense", "stream", "fused"])
def test_overlap_logits_bit_identical_all_modes(mode):
    """--overlap on is safe in EVERY weight-execution mode: with no
    streamed leaves (dense; fused without materialize-leaves) the pipeline
    disables itself, with streams it reschedules without changing bits."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model_off = build_model(dataclasses.replace(cfg, overlap="off"))
    model_on = build_model(dataclasses.replace(cfg, overlap="on"))
    params = model_off.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode=mode, min_bytes=1024, shards=2)
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    _assert_bit_equal(_serve(model_off, tree, pb, 16),
                      _serve(model_on, tree, pb, 16),
                      f"mode={mode} overlap on vs off")


def test_overlap_scan_unrolled_parity():
    """scan and unrolled pipelined drivers agree numerically; bit-equality
    across the two drivers is NOT required (XLA fuses — and rounds —
    scan-body math differently from inlined math, so even the SERIAL scan
    and unrolled drivers differ in final bits).  The hard bit-identity
    contract is overlap-vs-serial under the SAME driver, covered above."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              overlap="on")
    model_s = build_model(dataclasses.replace(cfg, scan_layers=True))
    model_u = build_model(dataclasses.replace(cfg, scan_layers=False))
    params = model_s.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                               shards=2)
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    for a, b in zip(_serve(model_s, tree, pb, 16),
                    _serve(model_u, tree, pb, 16)):
        np.testing.assert_allclose(a.astype(np.float64),
                                   b.astype(np.float64),
                                   rtol=5e-2, atol=5e-2,
                                   err_msg="overlap scan vs unrolled")


def test_prefetch_costs_buckets_per_layer_dispatches():
    """The per-step prefetch is O(#decoder buckets per layer): tracing the
    pipelined decode step issues 2*B + E decode dispatches under scan
    (prologue + one body trace) and P*B + E unrolled, where B is the
    schedule's bucket count and E the flat (embed/head) decodes outside
    the layer loop — never one dispatch per streamed leaf per layer."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              overlap="on")
    codec = Codec()
    model_s = build_model(dataclasses.replace(cfg, scan_layers=True))
    model_u = build_model(dataclasses.replace(cfg, scan_layers=False))
    params = model_s.init(jax.random.key(0))
    with use_codec(codec):
        tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                                   shards=2, codec=codec)
        sched = build_schedule(tree["period"], cfg.n_layers, codec=codec)
        n_leaves = len(sched.slots)
        B = sched.buckets_per_layer
        assert 1 <= B <= n_leaves
        logits, cache = model_s.prefill_fn(tree, {"tokens": jnp.zeros(
            (1, 4), jnp.int32)}, 8)
        tok = jnp.zeros((1,), jnp.int32)

        # flat-handle decodes outside the layer loop (embed; tied head)
        codec.reset_decode_cache_stats()
        jax.eval_shape(lambda t: t["embed"].materialize(),
                       {"embed": tree["embed"]})
        E = codec.decode_cache_stats()["dispatches"]
        assert isinstance(tree["embed"], StreamedWeight)
        assert E >= 1

        codec.reset_decode_cache_stats()
        jax.eval_shape(model_s.decode_fn, tree, cache, tok)
        d_scan = codec.decode_cache_stats()["dispatches"]
        assert d_scan == 2 * B + E, (d_scan, B, E)

        codec.reset_decode_cache_stats()
        jax.eval_shape(model_u.decode_fn, tree, cache, tok)
        d_unr = codec.decode_cache_stats()["dispatches"]
        assert d_unr == cfg.n_layers * B + E, (d_unr, B, E)


def test_decode_layer_matches_materialize_bit_exact():
    """The batched exact-bucketed prefetch decode of one layer is
    bit-identical to per-leaf StreamedWeight.materialize on the slice."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                               shards=2)
    sched = build_schedule(tree["period"], cfg.n_layers)
    for layer in range(cfg.n_layers):
        decs = decode_layer(sched, layer)
        for slot, got in zip(sched.slots, decs):
            h = sched.leaves[slot]
            ref = jax.tree.map(lambda a: a[layer], h).materialize()
            np.testing.assert_array_equal(
                np.asarray(got).view(np.uint8),
                np.asarray(ref).view(np.uint8),
                err_msg=f"layer {layer} slot {slot}")


def test_overlap_enabled_policy():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    streamed = assign_weight_modes(params, mode="stream", min_bytes=1024,
                                   shards=2)["period"]
    dense = assign_weight_modes(params, mode="dense",
                                min_bytes=1024)["period"]
    assert overlap_enabled("on", streamed)
    assert overlap_enabled("auto", streamed)
    assert not overlap_enabled("off", streamed)
    # nothing to prefetch -> auto/on degrade to the serial loop
    assert not overlap_enabled("auto", dense)
    assert not overlap_enabled("on", dense)
    with pytest.raises(ValueError):
        overlap_enabled("sideways", streamed)


def test_stream_stats_overlap_counters():
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                               shards=2)
    st = stream_stats(tree)
    assert st["flat_stream_tensors"] >= 1        # embed streams as L=1
    assert st["overlap_eligible_tensors"] >= 1   # period streams prefetch
    assert st["streamed_tensors"] == (st["flat_stream_tensors"]
                                      + st["overlap_eligible_tensors"])
    flats = [leaf for leaf in jax.tree.leaves(tree, is_leaf=is_handle)
             if isinstance(leaf, StreamedWeight) and leaf.flat]
    assert len(flats) == st["flat_stream_tensors"]


def test_pipeline_scan_xs_extra_and_ys_shape():
    """pipeline_scan stacks ys over all P layers exactly like lax.scan."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True, n_layers=3)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tree = assign_weight_modes(params, mode="stream", min_bytes=1024,
                               shards=2)
    sched = build_schedule(tree["period"], cfg.n_layers)
    xs = jnp.arange(cfg.n_layers, dtype=jnp.float32)

    def apply_fn(carry, _sliced, extra, _i):
        return carry + extra, carry

    carry, ys = pipeline_scan(sched, apply_fn, jnp.float32(0), xs_extra=xs)
    assert float(carry) == float(xs.sum())
    np.testing.assert_allclose(np.asarray(ys), [0.0, 0.0, 1.0])
