"""Fault injection, retry/backoff, record quarantine, and degraded-mode
restore (ISSUE 6): transient I/O faults absorbed with exact attempt
counters, corrupt records quarantined with per-record prior-step fallback,
decode-dispatch failures degraded, manifest/LATEST damage survived, and the
uncorrupted path bit-identical with unchanged dispatch counts."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointError, CheckpointManager
from repro.core import Codec
from repro.runtime import faults as rt_faults
from repro.runtime.faults import (FaultConfigError, FaultInjector, FaultSpec,
                                  InjectedFault)
from repro.runtime.retry import RetryPolicy
from conftest import make_realistic_bf16


def _tree(seed=0):
    return {
        "params": {"w": make_realistic_bf16(120_000, seed=seed),
                   "b": jnp.zeros((64,), jnp.bfloat16)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def _assert_trees_equal(a, b):
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape, pa
        np.testing.assert_array_equal(
            la.reshape(-1).view(np.uint8), lb.reshape(-1).view(np.uint8),
            err_msg=str(pa))


# ---------------------------------------------------------------------------
# the harness itself: specs, counters, determinism, env hook
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="explode")
    with pytest.raises(ValueError, match="mode"):
        FaultSpec(kind="corrupt", mode="scramble")


def test_injector_times_bounds_firings():
    inj = FaultInjector([FaultSpec(kind="read", match="pack", times=2)])
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.check_read("/x/pack-00000.bin")
    inj.check_read("/x/pack-00000.bin")      # exhausted: no longer fires
    inj.check_read("/x/manifest.json")       # never matched
    assert inj.stats()[0]["fired"] == 2


def test_injector_corruption_is_seeded_and_deterministic():
    data = bytes(range(256))
    a = FaultInjector([FaultSpec(kind="corrupt")], seed=7)
    b = FaultInjector([FaultSpec(kind="corrupt")], seed=7)
    assert a.corrupt("f", data) == b.corrupt("f", data) != data
    # explicit offset: exactly that byte, exactly that xor
    c = FaultInjector([FaultSpec(kind="corrupt", offset=3, xor=0x10)])
    out = c.corrupt("f", data)
    assert out[3] == data[3] ^ 0x10 and out[:3] == data[:3]
    # truncate keeps the requested prefix
    t = FaultInjector([FaultSpec(kind="corrupt", mode="truncate", offset=5)])
    assert t.corrupt("f", data) == data[:5]


def test_inject_contextmanager_scopes_activation():
    assert rt_faults.active() is None
    with rt_faults.inject(FaultSpec(kind="read", times=1)) as inj:
        assert rt_faults.active() is inj
        with pytest.raises(InjectedFault):
            rt_faults.read_file(__file__)
        rt_faults.read_file(__file__)      # transient: second read is clean
    assert rt_faults.active() is None


def test_env_hook_parses_enec_faults(monkeypatch):
    monkeypatch.setenv("ENEC_FAULTS", json.dumps(
        {"seed": 3, "specs": [{"kind": "write", "match": "pack", "times": 1}]}))
    inj = rt_faults.active()
    assert inj is not None and inj.seed == 3
    with pytest.raises(InjectedFault):
        inj.check_write("pack-00000.bin")
    monkeypatch.delenv("ENEC_FAULTS")
    assert rt_faults.active() is None


@pytest.mark.parametrize("raw,match", [
    ("{not json", "not valid JSON"),
    ('"a string"', "must be a JSON list"),
    ("42", "must be a JSON list"),
    ('[{"kind": "explode"}]', "bad fault spec"),
    ('[{"kind": "read", "bogus_field": 1}]', "bad fault spec"),
])
def test_malformed_env_schedule_fails_fast_naming_env_var(monkeypatch,
                                                          raw, match):
    """A typo'd ENEC_FAULTS must die at the first injection point with a
    one-line FaultConfigError that names the env var — never a raw
    JSON/TypeError traceback from deep inside a checkpoint read."""
    monkeypatch.setenv("ENEC_FAULTS", raw)
    with pytest.raises(FaultConfigError, match="ENEC_FAULTS") as ei:
        rt_faults.active()
    assert match in str(ei.value)
    # the read funnel surfaces the same one-liner
    with pytest.raises(FaultConfigError, match="ENEC_FAULTS"):
        rt_faults.read_file(__file__)
    monkeypatch.delenv("ENEC_FAULTS")
    assert rt_faults.active() is None


def test_step_fault_kind_matches_request_keys():
    inj = FaultInjector([FaultSpec(kind="step", match="req-7", times=1)])
    inj.check_step("req-3")              # no match
    with pytest.raises(InjectedFault, match="req-7"):
        inj.check_step("req-7")
    inj.check_step("req-7")              # exhausted
    assert inj.stats()[0]["fired"] == 1


def test_retry_policy_absorbs_transient_and_counts():
    pol = RetryPolicy(base_delay_s=0.0001, max_delay_s=0.001, seed=1)
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] <= 2:
            raise OSError("transient")
        return "ok"

    assert pol.call(flaky) == "ok"
    st = pol.stats()
    assert st == {"calls": 1, "attempts": 3, "retries": 2, "gave_up": 0}


def test_retry_policy_gives_up_on_permanent():
    pol = RetryPolicy(max_attempts=3, base_delay_s=0.0001)

    def dead():
        raise OSError("permanent")

    with pytest.raises(OSError, match="permanent"):
        pol.call(dead)
    st = pol.stats()
    assert st["attempts"] == 3 and st["gave_up"] == 1
    # non-retryable exceptions propagate on the first attempt
    with pytest.raises(ValueError):
        pol.call(lambda: (_ for _ in ()).throw(ValueError("not io")))
    assert pol.stats()["attempts"] == 4


def _budget_policy(**kw):
    """Policy on a fake clock: sleeps advance time, nothing real-sleeps."""
    state = {"t": 0.0, "slept": []}

    def sleep(s):
        state["slept"].append(s)
        state["t"] += s

    kw.setdefault("base_delay_s", 1.0)
    kw.setdefault("max_delay_s", 1.0)
    kw.setdefault("jitter", 0.0)
    pol = RetryPolicy(sleep=sleep, clock=lambda: state["t"], **kw)
    return pol, state


def test_retry_total_elapsed_budget_gives_up_before_sleeping():
    """max_elapsed_s bounds tries + backoff: the policy re-raises instead
    of sleeping through a deadline the caller has already missed."""
    pol, state = _budget_policy(max_attempts=10, max_elapsed_s=2.5)

    def dead():
        raise OSError("permanent")

    with pytest.raises(OSError, match="permanent"):
        pol.call(dead)
    # 1s + 1s sleeps fit the 2.5s budget; the third would overrun it
    assert state["slept"] == [1.0, 1.0]
    st = pol.stats()
    assert st["attempts"] == 3 and st["gave_up"] == 1


def test_retry_per_call_budget_tightens_instance_budget():
    pol, state = _budget_policy(max_attempts=10, max_elapsed_s=100.0)

    def dead():
        raise OSError("nope")

    with pytest.raises(OSError):
        pol.call(dead, max_elapsed_s=0.5)    # tighter per-call budget wins
    assert state["slept"] == []              # gave up before ANY sleep
    assert pol.stats()["attempts"] == 1
    # the instance budget still applies when the call passes none
    with pytest.raises(OSError):
        pol.call(dead)
    assert len(state["slept"]) == 9          # attempt-bounded, budget roomy


def test_retry_budget_still_allows_success_within_window():
    pol, state = _budget_policy(max_attempts=5, max_elapsed_s=10.0)
    n = {"v": 0}

    def flaky():
        n["v"] += 1
        if n["v"] <= 2:
            raise OSError("transient")
        return "ok"

    assert pol.call(flaky) == "ok"
    assert state["slept"] == [1.0, 1.0]
    assert pol.stats()["gave_up"] == 0


def test_backoff_grows_and_is_jittered_deterministically():
    a = RetryPolicy(seed=5)
    b = RetryPolicy(seed=5)
    da = [a.backoff_s(i) for i in (1, 2, 3)]
    assert [a_i for a_i in da] == [b.backoff_s(i) for i in (1, 2, 3)]
    assert da[0] < da[1] < da[2] <= a.max_delay_s * (1 + a.jitter)


# ---------------------------------------------------------------------------
# checkpoint restore under faults
# ---------------------------------------------------------------------------

def test_transient_read_faults_absorbed_by_retry(tmp_path):
    """fail-twice-then-succeed reads must be invisible to a STRICT load,
    with the retry counters proving the policy did the work."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(1)
    mgr.save(1, tree, blocking=True)
    mgr.retry.reset_stats()
    with rt_faults.inject(FaultSpec(kind="read", match="pack-", times=2)):
        out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)
    st = mgr.retry.stats()
    assert st["retries"] == 2 and st["gave_up"] == 0, st
    report = mgr.last_restore_report
    assert not report.degraded and report.retry["retries"] == 2


def test_permanent_read_fault_exhausts_retries_strict(tmp_path):
    mgr = CheckpointManager(tmp_path,
                            retry=RetryPolicy(base_delay_s=0.0001))
    tree = _tree(2)
    mgr.save(1, tree, blocking=True)
    with rt_faults.inject(FaultSpec(kind="read", match="pack-")):
        with pytest.raises(CheckpointError, match="injected read fault"):
            mgr.load(tree)
    assert mgr.retry.stats()["gave_up"] >= 1


def test_transient_write_faults_absorbed_on_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(3)
    mgr.retry.reset_stats()
    with rt_faults.inject(FaultSpec(kind="write", match="pack-", times=2)):
        mgr.save(1, tree, blocking=True)
    assert mgr.retry.stats()["retries"] == 2
    out, _ = mgr.load(tree)
    _assert_trees_equal(tree, out)


def test_corrupt_record_quarantined_with_prior_step_fallback(tmp_path):
    """One flipped byte in a committed record: degraded load restores the
    record from the previous step, bit-exactly, and the report names the
    damage; strict load still refuses."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(4)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    name, pack, pos = rt_faults.flip_pack_byte(tmp_path, "params/w", step=2)
    assert name == "params/w" and pos > 0
    with pytest.raises(CheckpointError, match="CRC"):
        mgr.load(tree)
    out, man = mgr.load(tree, policy="degraded")
    assert man["step"] == 2
    _assert_trees_equal(tree, out)
    report = mgr.last_restore_report
    assert [q.name for q in report.quarantined] == ["params/w"]
    q = report.quarantined[0]
    assert "CRC" in q.cause and q.offset >= 0 and "pack-" in q.pack
    assert q.fallback.startswith("step 1")
    assert "params/w" in report.summary()


def test_quarantined_record_without_source_raises(tmp_path):
    """Degraded mode trades freshness, never correctness: a record with no
    intact copy anywhere must still fail, listing the quarantine."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(5)
    mgr.save(1, tree, blocking=True)
    rt_faults.flip_pack_byte(tmp_path, "params/w", step=1)
    with pytest.raises(CheckpointError, match="no intact source"):
        mgr.load(tree, policy="degraded")


def test_decode_fault_degrades_to_prior_step(tmp_path):
    """An injected decode-dispatch failure (bytes intact, decode dies) is
    quarantined and the record restored through the fallback; strict mode
    surfaces it as CheckpointError."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(6)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    with rt_faults.inject(FaultSpec(kind="decode", match="params/w",
                                    times=1)):
        with pytest.raises(CheckpointError, match="decode failed"):
            mgr.load(tree)
    with rt_faults.inject(FaultSpec(kind="decode", match="params/w",
                                    times=1)) as inj:
        out, _ = mgr.load(tree, policy="degraded")
    _assert_trees_equal(tree, out)
    report = mgr.last_restore_report
    assert [q.name for q in report.quarantined] == ["params/w"]
    assert "decode failed" in report.quarantined[0].cause
    assert report.quarantined[0].fallback.startswith("step 1")
    assert inj.stats()[0]["fired"] == 1


def test_uncorrupted_degraded_restore_identical_to_strict(tmp_path):
    """Acceptance: with nothing injected, policy="degraded" must be
    byte-for-byte the strict path — same values, same decode dispatch
    count, empty quarantine."""
    codec = Codec()
    mgr = CheckpointManager(tmp_path, codec=codec)
    tree = _tree(7)
    mgr.save(1, tree, blocking=True)
    codec.reset_decode_cache_stats()
    strict_out, _ = mgr.load(tree)
    strict_dispatches = codec.decode_cache_stats()["dispatches"]
    strict_buckets = len(mgr.last_decode_plan.buckets)
    codec.reset_decode_cache_stats()
    degraded_out, _ = mgr.load(tree, policy="degraded")
    st = codec.decode_cache_stats()
    assert st["dispatches"] == strict_dispatches == strict_buckets
    assert len(mgr.last_decode_plan.buckets) == strict_buckets
    assert not mgr.last_restore_report.degraded
    _assert_trees_equal(strict_out, degraded_out)


def test_unknown_restore_policy_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree(8), blocking=True)
    with pytest.raises(ValueError, match="restore policy"):
        mgr.load(_tree(8), policy="yolo")


# ---------------------------------------------------------------------------
# manifest / LATEST damage, GC parse-safety (satellite)
# ---------------------------------------------------------------------------

def test_garbage_latest_falls_back_to_newest_intact_step(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(9)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    (tmp_path / "LATEST").write_text("not_a_step_pointer!!")
    assert mgr.latest_step() is None
    out, man = mgr.load(tree)
    assert man["step"] == 2
    _assert_trees_equal(tree, out)


def test_dangling_latest_falls_back(tmp_path):
    import shutil

    mgr = CheckpointManager(tmp_path)
    tree = _tree(10)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    shutil.rmtree(tmp_path / "step_000000000002")   # LATEST now dangles
    out, man = mgr.load(tree)
    assert man["step"] == 1
    _assert_trees_equal(tree, out)


def test_corrupt_manifest_falls_back_to_earlier_step(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(11)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    mpath = tmp_path / "step_000000000002" / "manifest.json"
    mpath.write_text(mpath.read_text()[:37])
    out, man = mgr.load(tree)
    assert man["step"] == 1
    _assert_trees_equal(tree, out)
    # an EXPLICIT step request keeps the hard failure
    with pytest.raises(CheckpointError, match="corrupt"):
        mgr.load(tree, step=2)


def test_gc_never_deletes_unparseable_steps(tmp_path):
    """Retention must only count (and delete) steps it can actually parse —
    a corrupt-manifest step might hold the only intact copy of a record."""
    mgr = CheckpointManager(tmp_path, keep_last=1)
    tree = _tree(12)
    mgr.save(1, tree, blocking=True)
    mpath = tmp_path / "step_000000000001" / "manifest.json"
    mpath.write_text("{corrupt")
    mgr.save(2, tree, blocking=True)
    mgr.save(3, tree, blocking=True)   # GC: step 2 goes, step 1 must stay
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_000000000001", "step_000000000003"], kept


# ---------------------------------------------------------------------------
# degraded SERVING restore (ISSUE 6 acceptance)
# ---------------------------------------------------------------------------

def _smoke_model():
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def _serve(cfg, model, tree):
    pb = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0,
                                       cfg.vocab_size)}
    logits, cache = model.prefill_fn(tree, pb, 16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec, _ = model.decode_fn(tree, cache, tok)
    return np.asarray(logits), np.asarray(dec)


def test_degraded_serving_restore_mixed_mode_bit_identical(tmp_path):
    """The tentpole acceptance: corrupt ONE serving-layout record; the
    degraded load_for_serving quarantines exactly it, adopts the previous
    step's STREAM bundle for it (the damaged fused record degrades to a
    different execution mode), the rest of the tree restores batched as
    before, and the logits stay bit-identical to the undamaged tree."""
    from repro.runtime.streaming import assign_weight_modes
    from repro.runtime.weights import StreamedWeight, is_handle

    cfg, model, params = _smoke_model()
    # step 1: stream layout (the redundancy level the fallback adopts);
    # step 2: fused layout (what serving wants)
    mgr_old = CheckpointManager(tmp_path, serving_layout="stream",
                                serving_min_bytes=1024, serving_shards=1)
    mgr_old.save(1, {"params": params}, blocking=True)
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(2, {"params": params}, blocking=True)
    man = mgr.manifest()
    victim = next(e["name"] for e in man["leaves"]
                  if (e.get("handle") or {}).get("kind") == "fused")
    rt_faults.flip_pack_byte(tmp_path, victim, step=2)

    with pytest.raises(CheckpointError, match="CRC"):
        mgr.load_for_serving(params, mode="fused", prefix="params",
                             min_bytes=1024)
    tree, _ = mgr.load_for_serving(params, mode="fused", prefix="params",
                                   min_bytes=1024, policy="degraded")
    report = mgr.last_restore_report
    assert [q.name for q in report.quarantined] == [victim]
    assert report.quarantined[0].fallback.startswith("step 1")
    # the quarantined fused record now executes as an adopted stream handle
    handles = [l for l in jax.tree_util.tree_leaves(tree, is_leaf=is_handle)
               if isinstance(l, StreamedWeight)]
    assert handles, "fallback did not adopt the stream bundle"
    ref = _serve(cfg, model, assign_weight_modes(params, mode="fused",
                                                 min_bytes=1024))
    got = _serve(cfg, model, tree)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))


def test_degraded_serving_report_counts_single_quarantine(tmp_path):
    """CI's fault-smoke contract in-process: same-layout two-step history,
    one byte flipped at the newest step -> exactly one quarantined record,
    fallback adopted from the prior step, serving-capable tree."""
    cfg, model, params = _smoke_model()
    mgr = CheckpointManager(tmp_path, serving_layout="fused",
                            serving_min_bytes=1024)
    mgr.save(1, {"params": params}, blocking=True)
    mgr.save(2, {"params": params}, blocking=True)
    man = mgr.manifest()
    victim = next(e["name"] for e in man["leaves"] if e.get("stack"))
    rt_faults.flip_pack_byte(tmp_path, victim, step=2)
    like = jax.eval_shape(model.init, jax.random.key(0))
    tree, _ = mgr.load_for_serving(like, mode="fused", prefix="params",
                                   min_bytes=1024, policy="degraded")
    report = mgr.last_restore_report
    assert len(report.quarantined) == 1
    assert report.quarantined[0].name == victim
    assert report.quarantined[0].fallback.startswith("step 1")
    _serve(cfg, model, tree)   # the degraded tree actually serves
