"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.models.registry import input_specs, param_count
from repro.configs.base import SHAPES, shape_applicable


def _batch(cfg, rng, B=2, T=16):
    if cfg.is_encdec:
        pb = {"frames": jax.random.normal(rng, (B, T, cfg.d_model),
                                          jnp.bfloat16),
              "tokens": jax.random.randint(rng, (B, T), 0, cfg.vocab_size)}
        return dict(pb, targets=jax.random.randint(rng, (B, T), 0,
                                                   cfg.vocab_size)), pb
    tt = T - cfg.prefix_embed
    batch = {"tokens": jax.random.randint(rng, (B, tt), 0, cfg.vocab_size),
             "targets": jax.random.randint(rng, (B, tt), 0, cfg.vocab_size)}
    if cfg.prefix_embed:
        batch["prefix_embeds"] = jax.random.normal(
            rng, (B, cfg.prefix_embed, cfg.d_model), jnp.bfloat16)
    pb = {k: v for k, v in batch.items() if k != "targets"}
    return batch, pb


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)
    batch, _ = _batch(cfg, rng)
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all()), (arch, path)
    # one SGD step changes the loss (graph is connected)
    new_params = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - 0.5 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    loss2, _ = model.loss_fn(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_path(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.key(1)
    params = model.init(rng)
    _, pb = _batch(cfg, rng)
    logits, cache = model.prefill_fn(params, pb, 32)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_fn(params, cache, tok)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache["lengths"][0]) == 16 + 3


def test_decode_matches_teacher_forcing_dense():
    cfg = get_smoke_config("llama3_2_1b")
    model = build_model(cfg)
    rng = jax.random.key(2)
    params = model.init(rng)
    batch, pb = _batch(cfg, rng)
    logits, cache = model.prefill_fn(params, pb, 32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec_logits, _ = model.decode_fn(params, cache, tok)
    tf_batch = {"tokens": jnp.concatenate([pb["tokens"], tok[:, None]], 1)}
    tf_logits, _ = model.prefill_fn(params, tf_batch, 33)
    assert float(jnp.abs(tf_logits - dec_logits).max()) < 0.1  # bf16 noise


def test_ssm_state_handoff_exact():
    cfg = get_smoke_config("xlstm_125m")
    model = build_model(cfg)
    rng = jax.random.key(3)
    params = model.init(rng)
    batch, pb = _batch(cfg, rng)
    logits, cache = model.prefill_fn(params, pb, 32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec_logits, _ = model.decode_fn(params, cache, tok)
    tf_batch = {"tokens": jnp.concatenate([pb["tokens"], tok[:, None]], 1)}
    tf_logits, _ = model.prefill_fn(params, tf_batch, 33)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(tf_logits), atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_sizes(arch):
    """The full configs carry the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
        "phi3_5_moe_42b_a6_6b": (32, 4096, 32, 8, 6400, 32064),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)


def test_moe_expert_counts():
    assert get_config("qwen3_moe_235b_a22b").n_experts == 128
    assert get_config("qwen3_moe_235b_a22b").experts_per_token == 8
    assert get_config("phi3_5_moe_42b_a6_6b").n_experts == 16
    assert get_config("phi3_5_moe_42b_a6_6b").experts_per_token == 2
    assert get_config("jamba_v0_1_52b").n_experts == 16


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, name)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, name)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long_500k_skips_are_exactly_full_attention_archs():
    skipped = [a for a in ARCH_IDS
               if not shape_applicable(get_config(a), "long_500k")[0]]
    assert set(skipped) == {
        "qwen3_32b", "minitron_4b", "llama3_2_1b", "stablelm_3b",
        "whisper_tiny", "paligemma_3b", "qwen3_moe_235b_a22b",
        "phi3_5_moe_42b_a6_6b"}


def test_param_counts_near_nameplate():
    """Full-size configs land near their nameplate parameter counts."""
    approx = {"qwen3_32b": 32.8e9, "llama3_2_1b": 1.24e9,
              "qwen3_moe_235b_a22b": 235e9, "xlstm_125m": 0.125e9}
    for arch, expect in approx.items():
        n = param_count(get_config(arch))
        assert 0.75 * expect < n < 1.35 * expect, (arch, n, expect)
