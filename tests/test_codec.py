"""End-to-end codec losslessness: the paper's headline property
("bit-identical reconstruction", §VI-A) under adversarial inputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (BF16, FP16, FP32, compress_array, compress_tree,
                        decompress_array, decompress_tree, search_for_array,
                        tree_ratio)
from repro.core import wire
from conftest import make_realistic_bf16


def _bits(x):
    dt = np.uint16 if x.dtype != jnp.float32 else np.uint32
    return np.asarray(jax.device_get(x)).view(dt)


DTYPES = [jnp.bfloat16, jnp.float16, jnp.float32]


@pytest.mark.parametrize("dtype", DTYPES)
def test_lossless_with_specials(dtype):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(40_000) * 0.02).astype("float32")
    x[:8] = [0.0, -0.0, np.inf, -np.inf, np.nan, -np.nan, 1e-40, -1e-40]
    x = jnp.asarray(x).astype(dtype)
    ct = compress_array(x)
    y = decompress_array(ct)
    np.testing.assert_array_equal(_bits(x), _bits(y))


@given(st.integers(0, 2**31), st.sampled_from(["narrow", "wide", "const",
                                               "tiny", "denormal"]))
@settings(max_examples=20, deadline=None)
def test_lossless_property(seed, kind):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 50_000))
    if kind == "narrow":
        w = rng.standard_normal(n) * 0.02
    elif kind == "wide":
        w = rng.standard_normal(n) * np.exp(rng.standard_normal(n) * 4)
    elif kind == "const":
        w = np.full(n, float(rng.standard_normal()))
    elif kind == "tiny":
        w = rng.standard_normal(n) * 1e-30
    else:
        w = rng.standard_normal(n) * 1e-42  # subnormal territory
    x = jnp.asarray(w.astype("float32")).astype(jnp.bfloat16)
    y = decompress_array(compress_array(x))
    np.testing.assert_array_equal(_bits(x), _bits(y))


def test_realistic_ratio_matches_paper():
    """BF16 trained-like weights: ratio ~1.35 and params ~(122,6,3,16)
    (paper Tables II & IV)."""
    x = make_realistic_bf16(2_000_000)
    ct = compress_array(x)
    assert ct.mode == "enec"
    b, n, m, L = ct.params.astuple()
    assert n == 6 and m == 3 and L == 16, ct.params
    assert 118 <= b <= 126, b
    assert 1.30 <= ct.ratio() <= 1.42, ct.ratio()


def test_wire_roundtrip_all_dtypes():
    rng = np.random.default_rng(3)
    for dtype in DTYPES:
        x = jnp.asarray((rng.standard_normal(30_000) * 0.02
                         ).astype("float32")).astype(dtype)
        ct = compress_array(x)
        ct2 = wire.from_wire(wire.to_wire(ct))
        y = decompress_array(ct2)
        np.testing.assert_array_equal(_bits(x), _bits(y))


def test_sharded_compression_roundtrip():
    x = make_realistic_bf16(100_000, seed=7)
    for shards in (1, 2, 4):
        ct = compress_array(x, shards=shards)
        y = decompress_array(ct)
        np.testing.assert_array_equal(_bits(x), _bits(y))


def test_raw_escape_never_worse():
    rng = np.random.default_rng(5)
    # adversarial: full-entropy bits — must fall back to raw, ratio ~1
    x = jnp.asarray(rng.integers(0, 2**16, 50_000, dtype=np.uint16)
                    ).view(jnp.bfloat16)
    ct = compress_array(x)
    y = decompress_array(ct)
    np.testing.assert_array_equal(_bits(x), _bits(y))
    assert ct.ratio() >= 0.99


def test_transferred_params_stay_lossless():
    """Paper §VI-E: params searched on model A applied to model B."""
    a = make_realistic_bf16(500_000, seed=1)
    b = make_realistic_bf16(500_000, seed=2, outlier_frac=1e-2)
    p = search_for_array(np.asarray(jax.device_get(a)), BF16)
    ct = compress_array(b, p)  # may widen internally
    y = decompress_array(ct)
    np.testing.assert_array_equal(_bits(b), _bits(y))


def test_tree_api_and_ratio():
    tree = {"w1": make_realistic_bf16(70_000, seed=3),
            "nested": {"w2": make_realistic_bf16(50_000, seed=4)},
            "step": jnp.asarray(3, jnp.int32)}
    ctree = compress_tree(tree)
    out = decompress_tree(ctree)
    np.testing.assert_array_equal(_bits(tree["w1"]), _bits(out["w1"]))
    np.testing.assert_array_equal(_bits(tree["nested"]["w2"]),
                                  _bits(out["nested"]["w2"]))
    assert int(out["step"]) == 3
    stats = tree_ratio(ctree)
    assert stats["tensors"] == 3 and stats["ratio"] > 1.0


def test_multidim_shapes_preserved():
    x = make_realistic_bf16(4 * 333 * 17, seed=9).reshape(4, 333, 17)
    y = decompress_array(compress_array(x))
    assert y.shape == (4, 333, 17) and y.dtype == x.dtype
    np.testing.assert_array_equal(_bits(x).ravel(), _bits(y).ravel())
