"""Parameter-search machinery (§V-E): Eq. 1 base width, Eq. 3 cost, Eq. 4
joint search, widening escape, and the joint-search improvement."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import BF16, FP16
from repro.core.params import (base_width_for, expected_ratio, search,
                               widen_for_range)


def _paper_like_hist():
    """Histogram matching Obs. 5: geometric bulk around 120 + rare high
    outliers (Fig. 3 red circle)."""
    hist = np.zeros(256, np.int64)
    for e in range(96, 127):
        hist[e] = int(1e7 * 0.55 ** abs(120 - e))
    hist[127:133] = 40  # outliers
    return hist


def test_search_matches_table4():
    p = search(_paper_like_hist(), BF16)
    assert p.n == 6 and p.L == 16
    assert 118 <= p.b <= 124
    assert p.m in (3, 4)
    assert 1.25 <= expected_ratio(p, BF16) <= 1.45


def test_eq1_base_width_injective():
    for l, h in [(96, 132), (0, 255), (120, 121), (50, 50)]:
        for b in range(l, h + 1):
            n = base_width_for(b, l, h)
            ys = {(b - x) % (1 << n) for x in range(l, h + 1)}
            assert len(ys) == h - l + 1, (l, h, b, n)


def test_joint_search_never_worse():
    hist = _paper_like_hist()
    p_paper = search(hist, BF16, mode="paper")
    p_joint = search(hist, BF16, mode="joint")
    assert p_joint.expected_bits <= p_paper.expected_bits + 1e-9


def test_widen_escape_covers_new_range():
    p = search(_paper_like_hist(), BF16)
    w = widen_for_range(p, 10, 200)
    assert w.l <= 10
    assert (200 - w.l) < (1 << w.n)  # injective over the widened range
    assert (w.b, w.L) == (p.b, p.L)  # structural params preserved


def test_widen_escape_both_ends():
    """Regression: a transferred-params tensor whose range escapes BELOW and
    ABOVE the donor window must still land in [l, l + 2**n) after widening,
    with (b, m, L) untouched (the documented contract)."""
    p = search(_paper_like_hist(), BF16)
    lo, hi = p.l - 40, p.l + (1 << p.n) + 60   # escapes on both ends
    w = widen_for_range(p, lo, hi)
    assert w.l <= lo
    assert hi < w.l + (1 << w.n)               # decode window covers [lo, hi]
    assert (w.b, w.m, w.L) == (p.b, p.m, p.L)  # only (n, l) may change
    assert w.m <= w.n


def test_widen_noop_when_covered():
    p = search(_paper_like_hist(), BF16)
    assert widen_for_range(p, p.l, p.l + (1 << p.n) - 1) is p


def test_transferred_params_double_escape_roundtrip():
    """End-to-end: donor params from a narrow tensor applied to a tensor with
    subnormal-range AND huge-exponent values is still bit-exact."""
    import jax
    import jax.numpy as jnp
    from conftest import make_realistic_bf16
    from repro.core import compress_array, decompress_array, search_for_array

    donor = make_realistic_bf16(200_000, seed=21)
    p = search_for_array(np.asarray(jax.device_get(donor)), BF16)
    r = np.random.default_rng(22)
    w = (r.standard_normal(100_000) * 0.02).astype("float32")
    w[:100] = 1e38        # exponent far above the donor window
    w[100:200] = 1e-38    # exponent far below the donor window
    x = jnp.asarray(w).astype(jnp.bfloat16)
    ct = compress_array(x, p)
    y = decompress_array(ct)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(x)).view(np.uint16),
        np.asarray(jax.device_get(y)).view(np.uint16))
    assert ct.mode != "enec" or (
        ct.params.b == p.b and ct.params.m == p.m and ct.params.L == p.L)


def test_fp16_narrow_exponent():
    hist = np.zeros(32, np.int64)
    for e in range(5, 20):
        hist[e] = int(1e6 * 0.6 ** abs(12 - e))
    p = search(hist, FP16)
    assert p.n <= 6 and p.b in range(5, 20)


@given(st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_search_handles_random_histograms(seed):
    rng = np.random.default_rng(seed)
    hist = rng.integers(0, 1000, 256).astype(np.int64)
    hist[rng.random(256) < 0.7] = 0
    if hist.sum() == 0:
        hist[128] = 1
    p = search(hist, BF16)
    nz = np.nonzero(hist)[0]
    l, h = int(nz[0]), int(nz[-1])
    assert (h - l) < (1 << p.n)      # always injective
    assert 1 <= p.m <= p.n
    assert p.L in (16, 32, 64, 128)
