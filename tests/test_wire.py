"""ISSUE 3: framed wire records — edge-case round-trips + corruption
rejection.

The enec-v2 container concatenates framed records into pack files, so the
wire layer must (a) round-trip every mode and edge shape bit-exactly,
(b) be self-delimiting (explicit payload length), and (c) reject truncated
or bit-flipped bytes with a clear :class:`WireError` instead of misdecoding.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitio, wire
from repro.core.api import (compress_array, compress_stacked,
                            decompress_array, decompress_stacked)
from repro.core.params import EnecParams
from conftest import make_realistic_bf16


def _bits(x):
    x = np.asarray(jax.device_get(x))
    return x.view(np.uint16 if x.dtype.itemsize == 2 else np.uint32)


def _roundtrip(ct):
    return wire.from_wire(wire.frame(wire.to_wire(ct))[wire.FRAME_HEADER_BYTES:])


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_frame_roundtrip_and_pack_iteration():
    payloads = [b"", b"x", b"hello world" * 100]
    pack = b"".join(wire.frame(p) for p in payloads)
    got = [(off, bytes(p)) for off, p in wire.iter_frames(pack)]
    assert [p for _, p in got] == payloads
    # offsets are exact frame starts
    for off, p in got:
        q, _ = wire.read_frame(pack, off)
        assert bytes(q) == p


def test_frame_rejects_truncation_bitflip_and_bad_magic():
    fr = wire.frame(b"some payload bytes")
    with pytest.raises(wire.WireError, match="truncated"):
        wire.read_frame(fr[:-3])
    with pytest.raises(wire.WireError, match="header truncated"):
        wire.read_frame(fr[: wire.FRAME_HEADER_BYTES - 2])
    flipped = bytearray(fr)
    flipped[wire.FRAME_HEADER_BYTES + 4] ^= 0x20
    with pytest.raises(wire.WireError, match="CRC"):
        wire.read_frame(bytes(flipped))
    with pytest.raises(wire.WireError, match="magic"):
        wire.read_frame(b"\x00" * len(fr))


def test_record_truncation_and_garbage_rejected():
    ct = compress_array(make_realistic_bf16(40_000, seed=1))
    blob = wire.to_wire(ct)
    with pytest.raises(wire.WireError):
        wire.from_wire(blob[:-3])          # truncated high stream
    with pytest.raises(wire.WireError):
        wire.from_wire(blob[:20])          # truncated header/params
    with pytest.raises(wire.WireError, match="trailing"):
        wire.from_wire(blob + b"\x00\x00")  # mis-framed length
    with pytest.raises(wire.WireError, match="magic"):
        wire.from_wire(b"\xff" * len(blob))


def test_raw_record_length_validated():
    x = jnp.asarray(np.arange(100, dtype=np.int32))
    ct = compress_array(x)                 # non-float -> raw escape
    assert ct.mode == "raw"
    blob = wire.to_wire(ct)
    np.testing.assert_array_equal(
        np.asarray(decompress_array(wire.from_wire(blob))), np.asarray(x))
    with pytest.raises(wire.WireError, match="payload bytes"):
        wire.from_wire(blob[:-4])          # raw payload shorter than shape


# ---------------------------------------------------------------------------
# edge-case round-trips
# ---------------------------------------------------------------------------

def test_width_zero_no_high_stream():
    """n == m: every group fits the threshold, the high stream is empty and
    the record must still frame and round-trip bit-exactly."""
    r = np.random.default_rng(0)
    # exponents confined to [120, 126] so n=4 (== m) covers the range
    x = jnp.asarray((r.uniform(0.25, 1.9, 30_000)
                     * r.choice([-1.0, 1.0], 30_000)).astype("float32")
                    ).astype(jnp.bfloat16)
    p = EnecParams(b=126, n=4, m=4, L=16, l=119)
    ct = compress_array(x, p=p)
    assert ct.mode == "enec" and ct.params.n == ct.params.m
    assert int(np.asarray(jax.device_get(ct.streams.high_len)).sum()) == 0
    ct2 = _roundtrip(ct)
    np.testing.assert_array_equal(_bits(x), _bits(decompress_array(ct2)))


def test_empty_and_const_leaves_roundtrip():
    empty = jnp.zeros((0,), jnp.bfloat16)
    ct = compress_array(empty)
    out = decompress_array(_roundtrip(ct))
    assert out.shape == (0,) and out.dtype == jnp.bfloat16

    const = jnp.full((4096,), 1.5, jnp.float16)
    ct = compress_array(const)
    assert ct.mode == "const"
    out = decompress_array(_roundtrip(ct))
    np.testing.assert_array_equal(_bits(const), _bits(out))


def test_bf16_dtype_tag_is_eight_chars():
    """'bfloat16' is exactly 8 characters — the fixed u8[8] dtype tag must
    survive without truncation or stray NULs."""
    x = make_realistic_bf16(20_000, seed=3)
    ct = compress_array(x)
    assert ct.dtype_str == "bfloat16" and len(ct.dtype_str) == 8
    ct2 = _roundtrip(ct)
    assert ct2.dtype_str == "bfloat16"
    assert decompress_array(ct2).dtype == jnp.bfloat16


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_streams_roundtrip(shards):
    x = make_realistic_bf16(200_000, seed=5)
    ct = compress_array(x, shards=shards)
    ct2 = _roundtrip(ct)
    assert ct2.streams.mask.shape[0] == shards
    np.testing.assert_array_equal(_bits(x), _bits(decompress_array(ct2)))


@pytest.mark.parametrize("shards", [1, 2])
def test_stacked_records_roundtrip(shards):
    xs = jnp.stack([make_realistic_bf16(200_000, seed=10 + i)
                    for i in range(3)])
    ct = compress_stacked(xs, shards=shards)
    blob = wire.to_wire(ct, stacked=True)
    ct2 = wire.from_wire(blob)
    assert wire.wire_stack(ct2) == 3
    assert ct2.streams.mask.shape[:1] == (3,)
    np.testing.assert_array_equal(_bits(decompress_stacked(ct)),
                                  _bits(decompress_stacked(ct2)))


def test_stacked_requires_enec_mode():
    ct = compress_array(jnp.asarray(np.arange(64, dtype=np.int32)))
    with pytest.raises(wire.WireError, match="stacked"):
        wire.to_wire(ct, stacked=True)


# ---------------------------------------------------------------------------
# host-side bit packing (the xp=np path the wire codec rides)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [1, 3, 5, 8, 11, 16])
def test_pack_fixed_host_matches_device(width):
    r = np.random.default_rng(width)
    vals = r.integers(0, 1 << width, (4, 2048)).astype(np.uint16)
    dev = np.asarray(jax.device_get(bitio.pack_fixed(jnp.asarray(vals), width)))
    host = bitio.pack_fixed(vals, width, xp=np)
    np.testing.assert_array_equal(dev, host)
    back = bitio.unpack_fixed(host, 2048, width, xp=np)
    np.testing.assert_array_equal(back, vals)


def test_np_unpack_bits_exact_rejects_short_buffer():
    vals = np.arange(64, dtype=np.uint32) % 8
    packed = bitio.np_pack_bits_exact(vals, 3)
    with pytest.raises(ValueError, match="truncated"):
        bitio.np_unpack_bits_exact(packed[:-2], 64, 3)


def test_transfer_counter_counts_uploads():
    wire.reset_transfer_stats()
    # block-aligned so the padded device layout stays below dense bytes
    ct = compress_array(make_realistic_bf16(4 * 16384, seed=9))
    blob = wire.to_wire(ct)
    assert wire.transfer_stats()["h2d_bytes"] == 0   # serialization is host-only
    ct2 = wire.from_wire(blob)
    st = wire.transfer_stats()
    assert st["h2d_bytes"] > 0
    # only the (padded) compressed streams were uploaded — far below dense
    assert st["h2d_bytes"] < ct.nbytes_raw()
    np.testing.assert_array_equal(_bits(decompress_array(ct)),
                                  _bits(decompress_array(ct2)))
