"""End-to-end behaviour tests for the paper's system: compress a model's
weights, verify bit-identical reconstruction + paper-level ratios, and run
the serve path from compressed state (the §VI-C scenario, CPU-scale)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import compress_tree, decompress_tree, tree_ratio
from repro.data.synthetic_weights import PAPER_MODELS, generate
from repro.models import build_model
from repro.runtime.streaming import compress_params_for_streaming


def test_paper_table2_style_ratios():
    """BF16 sets compress ~1.35x, FP16 ~1.1x, FP32 ~1.15x (paper Table II)."""
    bands = {"bf16": (1.25, 1.45), "fp16": (1.04, 1.25),
             "fp32": (1.08, 1.25)}
    for spec in PAPER_MODELS[:2] + PAPER_MODELS[5:6] + PAPER_MODELS[8:9]:
        x = generate(dataclasses.replace(spec, n_elems=1 << 20))
        from repro.core import compress_array, decompress_array
        ct = compress_array(x)
        lo, hi = bands[spec.dtype]
        assert lo <= ct.ratio() <= hi, (spec.name, ct.ratio())
        y = decompress_array(ct)
        dt = np.uint16 if spec.dtype != "fp32" else np.uint32
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(x)).view(dt),
            np.asarray(jax.device_get(y)).view(dt))


def test_whole_model_compress_roundtrip():
    cfg = get_smoke_config("qwen3_32b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ctree = compress_tree(params)
    restored = decompress_tree(ctree)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8),
            err_msg=str(pa))
    stats = tree_ratio(ctree)
    assert stats["ratio"] >= 0.99  # random-init tiny tensors: raw escape ok


def test_serve_from_compressed_weights_end_to_end():
    """The paper's inference scenario: weights resident compressed,
    decompressed layer-wise inside the step, outputs bit-identical."""
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    streamed = compress_params_for_streaming(params, min_bytes=1024, shards=2)
    rng = jax.random.key(2)
    pb = {"tokens": jax.random.randint(rng, (2, 12), 0, cfg.vocab_size)}
    l_ref, c_ref = model.prefill_fn(params, pb, 24)
    l_str, c_str = model.prefill_fn(streamed, pb, 24)
    assert float(jnp.abs(l_ref - l_str).max()) == 0.0
    tok = jnp.argmax(l_str, -1).astype(jnp.int32)
    for _ in range(4):
        d_ref, c_ref = model.decode_fn(params, c_ref, tok)
        d_str, c_str = model.decode_fn(streamed, c_str, tok)
        assert float(jnp.abs(d_ref - d_str).max()) == 0.0
        tok = jnp.argmax(d_str, -1).astype(jnp.int32)
