"""Data pipeline: determinism, host sharding, prefetch."""
import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, batch_at, iterate


def test_deterministic_across_calls():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    a = batch_at(cfg, 5)
    b = batch_at(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["targets"], b["targets"])


def test_targets_are_shifted_tokens():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
    b = batch_at(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_host_shards_partition_batch():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=3)
    shards = [batch_at(
        DataConfig(vocab_size=512, seq_len=16, global_batch=8, seed=3,
                   shard_index=i, shard_count=4), 2) for i in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # different shards produce different data
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


def test_steps_differ():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=2)
    assert not np.array_equal(batch_at(cfg, 0)["tokens"],
                              batch_at(cfg, 1)["tokens"])


def test_tokens_in_range_and_zipfish():
    cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=16)
    b = batch_at(cfg, 0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100
    counts = np.bincount(b["tokens"].ravel(), minlength=100)
    assert counts[:10].sum() > counts[50:60].sum()  # skewed distribution


def test_prefetcher_matches_iterate():
    cfg = DataConfig(vocab_size=512, seq_len=8, global_batch=2, seed=1)
    pf = Prefetcher(cfg, start_step=3)
    it = iterate(cfg, start_step=3)
    for _ in range(3):
        a, b = next(pf), next(it)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    pf.close()


def test_modality_prefix_stub():
    cfg = DataConfig(vocab_size=512, seq_len=8, global_batch=2,
                     prefix_embed=4, d_model=16)
    b = batch_at(cfg, 0)
    assert b["prefix_embeds"].shape == (2, 4, 16)
