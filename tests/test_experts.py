"""ISSUE 10: compressed MoE expert streaming with an LRU decode cache.

Expert stacks live as per-expert compressed wire records in an
``ExpertStore``; routed experts decode on demand through a byte-budgeted
LRU (``runtime/experts.py``).  The contracts under test:

  * per-expert records round-trip bit-exactly (host numpy decode);
  * serve logits with the expert cache are BIT-IDENTICAL to dense at ANY
    budget — unlimited, eviction-forcing, and zero — in every weight mode;
  * one routing step's misses decode in O(#buckets) vectorized dispatches
    (at most one per distinct leaf geometry), not O(#experts);
  * LRU counter arithmetic: hits/misses/evictions/resident-bytes;
  * enec-v2 checkpoints with ``expert_records=True`` restore into the
    store without inflating a single cold expert, and refuse a serving
    mesh (the store decodes host-side).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointError, CheckpointManager
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.experts import (ExpertRef, ExpertStore, ExpertStoreError,
                                   install_expert_store)
from repro.runtime.streaming import assign_weight_modes, mode_mix
from repro.runtime.weights import handle_kind

# two distinct record geometries: e_gate/e_up are (D, F), e_down is (F, D)
N_GEOMS = 2


def _u32(x):
    return np.asarray(jax.device_get(x)).view(np.uint32)


def _bits(x):
    a = np.asarray(jax.device_get(x))
    return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint32)


def _moe_setup(seed=0):
    cfg = dataclasses.replace(get_smoke_config("phi3_5_moe_42b_a6_6b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    pb = {"tokens": jax.random.randint(jax.random.key(seed + 1), (2, 8), 0,
                                       cfg.vocab_size)}
    return cfg, model, params, pb


def _serve(model, tree, pb, max_len=16):
    logits, cache = model.prefill_fn(tree, pb, max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec, _ = model.decode_fn(tree, cache, tok)
    return np.asarray(logits), np.asarray(dec)


def _expert_leaves(params):
    moe = params["period"][0]["moe"]
    return {f"period/0/moe/{k}": moe[k]
            for k in ("e_gate", "e_up", "e_down")}


def test_store_roundtrip_bit_exact():
    _, _, params, _ = _moe_setup()
    dense = _expert_leaves(params)
    tree, store = install_expert_store(params)
    assert store is not None and store.names() == sorted(dense)
    for name, orig in dense.items():
        assert store.complete(name)
        got = store.materialize_leaf(name)
        assert got.shape == orig.shape
        np.testing.assert_array_equal(_bits(got), _bits(orig), err_msg=name)
    # the refs replaced the stacks in the tree and know their raw size
    moe = tree["period"][0]["moe"]
    for k in ("e_gate", "e_up", "e_down"):
        assert isinstance(moe[k], ExpertRef)
        assert moe[k].raw_nbytes() == dense[f"period/0/moe/{k}"].size * 2


def test_lru_counters_and_eviction():
    _, _, params, _ = _moe_setup()
    _, store = install_expert_store(params)
    names = store.names()
    per_expert = sum(store.expert_nbytes(n) for n in names)

    outs = store.fetch_step(names, 0, np.array([0, 1]))
    st = store.stats()
    assert st["misses"] == 2 * len(names) and st["hits"] == 0
    assert st["resident_bytes"] == 2 * per_expert
    assert st["resident_experts"] == 2 * len(names)
    # unrouted slots are exact zeros, routed slots match the dense stack
    for n, full in zip(names, outs):
        ref = store.materialize_leaf(n)[0]
        np.testing.assert_array_equal(_bits(full[:2]), _bits(ref[:2]))
        assert not np.any(_bits(full[2:]))
    # a repeat of the same step is all hits, no new fetch
    store.fetch_step(names, 0, np.array([1, 0]))
    st = store.stats()
    assert st["hits"] == 2 * len(names) and st["fetches"] == 1

    # LRU order: layer-1 fetch under a 2-expert-step budget evicts layer 0
    store.budget_bytes = 2 * per_expert
    store.fetch_step(names, 1, np.array([2, 3]))
    st = store.stats()
    assert st["evictions"] == 2 * len(names)
    assert st["resident_bytes"] == 2 * per_expert


def test_zero_budget_caches_nothing_but_serves_exact():
    _, _, params, _ = _moe_setup()
    _, store = install_expert_store(params, budget_bytes=0)
    names = store.names()
    outs = store.fetch_step(names, 1, np.array([3]))
    ref = store.materialize_leaf(names[0])[1]
    np.testing.assert_array_equal(_bits(outs[0][3]), _bits(ref[3]))
    st = store.stats()
    assert st["resident_bytes"] == 0 and st["resident_experts"] == 0
    assert st["evictions"] == st["misses"] == len(names)


def test_batched_fetch_is_bucketed_not_per_expert():
    _, _, params, _ = _moe_setup()
    _, store = install_expert_store(params)
    names = store.names()
    n_experts = store.meta(names[0])["n_experts"]
    store.fetch_step(names, 0, np.arange(n_experts))
    lf = store.last_fetch
    assert lf["records"] == len(names) * n_experts
    # O(#buckets), not O(#experts): every record of a leaf shares searched
    # params and block geometry, so the whole step decodes in at most one
    # vectorized dispatch per distinct geometry
    assert lf["buckets"] <= N_GEOMS < lf["records"]


def test_missing_record_raises():
    _, _, params, _ = _moe_setup()
    _, store = install_expert_store(params)
    name = store.names()[0]
    del store._records[(name, 0, 1)]
    assert store.missing(name) == [(0, 1)]
    with pytest.raises(ExpertStoreError, match="no record"):
        store.fetch_step([name], 0, np.array([1]))


def test_mode_mix_reports_expert_handles():
    _, _, params, _ = _moe_setup()
    tree, store = install_expert_store(params)
    tree = assign_weight_modes(tree, mode="stream", min_bytes=1024)
    mm = mode_mix(tree)
    assert mm.get("expert") == 3, mm
    assert handle_kind(tree["period"][0]["moe"]["e_gate"]) == "expert"
    # assign_weight_modes passed the refs through to the same store
    assert tree["period"][0]["moe"]["e_gate"].store is store


@pytest.mark.parametrize("mode", ["dense", "stream", "fused"])
def test_serve_logits_bit_identical_with_expert_cache(mode):
    _, model, params, pb = _moe_setup()
    ref = _serve(model, params, pb)

    tree, store = install_expert_store(params)
    tree = assign_weight_modes(tree, mode=mode, min_bytes=1024)
    got = _serve(model, tree, pb)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(_u32(r), _u32(g), err_msg=mode)
    st = store.stats()
    assert st["fetches"] > 0 and st["evictions"] == 0
    # acceptance: per-step dispatch bound holds across the whole serve
    assert st["fetch_buckets"] <= st["fetches"] * N_GEOMS
    assert st["fetch_buckets"] < st["fetch_records"]


def test_serve_bit_identical_under_eviction_pressure():
    _, model, params, pb = _moe_setup()
    ref = _serve(model, params, pb)
    # budget below one layer's full working set: every step misses and
    # evicts, logits must still be bit-identical to dense
    tree, store = install_expert_store(params, budget_bytes=40_000)
    tree = assign_weight_modes(tree, mode="stream", min_bytes=1024)
    got = _serve(model, tree, pb)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(_u32(r), _u32(g))
    st = store.stats()
    assert st["evictions"] > 0
    assert st["resident_bytes"] <= 40_000
    assert st["fetch_buckets"] <= st["fetches"] * N_GEOMS


def test_ckpt_expert_records_roundtrip(tmp_path):
    _, model, params, pb = _moe_setup()
    ref = _serve(model, params, pb)
    mgr = CheckpointManager(tmp_path / "ck", serving_layout="stream",
                            serving_min_bytes=1024, expert_records=True)
    mgr.save(0, {"params": params}, blocking=True)
    manifest = mgr.manifest()
    xent = [e for e in manifest["leaves"]
            if (e.get("handle") or {}).get("kind") == "expert"]
    assert len(xent) == 2 * 4 * 3       # layers x experts x moe leaves

    # training load reassembles the dense stacks bit-exactly
    out, _ = mgr.load({"params": params})
    for name, orig in _expert_leaves(params).items():
        got = out["params"]["period"][0]["moe"][name.rsplit("/", 1)[-1]]
        np.testing.assert_array_equal(_bits(got), _bits(orig), err_msg=name)

    # serving load restores records into the store WITHOUT inflating a
    # single cold expert, and serves bit-identically to dense
    like = jax.eval_shape(model.init, jax.random.key(0))
    tree, _ = mgr.load_for_serving(like, mode="stream", prefix="params",
                                   min_bytes=1024)
    store = mgr.last_expert_store
    assert store is not None
    st = store.stats()
    assert st["records"] == len(xent) and st["resident_bytes"] == 0
    got = _serve(model, tree, pb)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(_u32(r), _u32(g))

    # a tree holding ExpertRefs re-saves by re-emitting the records
    # verbatim (no re-encode) and round-trips again
    mgr2 = CheckpointManager(tmp_path / "ck2", serving_layout="stream",
                             serving_min_bytes=1024)
    mgr2.save(1, {"params": tree}, blocking=True)
    tree2, _ = mgr2.load_for_serving(like, mode="stream", prefix="params",
                                     min_bytes=1024)
    store2 = mgr2.last_expert_store
    for name, orig in _expert_leaves(params).items():
        got = store2.materialize_leaf(f"params/{name}")
        np.testing.assert_array_equal(_bits(got), _bits(orig), err_msg=name)


def test_ckpt_serving_restore_into_bounded_store(tmp_path):
    """An explicit eviction-forcing store handed to load_for_serving is
    the one the refs use, and serve stays bit-identical."""
    _, model, params, pb = _moe_setup()
    ref = _serve(model, params, pb)
    mgr = CheckpointManager(tmp_path, serving_layout="stream",
                            serving_min_bytes=1024, expert_records=True)
    mgr.save(0, {"params": params}, blocking=True)
    like = jax.eval_shape(model.init, jax.random.key(0))
    store = ExpertStore(budget_bytes=64 * 1024)
    tree, _ = mgr.load_for_serving(like, mode="stream", prefix="params",
                                   min_bytes=1024, expert_store=store)
    assert tree["period"][0]["moe"]["e_up"].store is store
    got = _serve(model, tree, pb)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(_u32(r), _u32(g))
    assert store.stats()["evictions"] > 0


def test_ckpt_expert_records_refuse_mesh(tmp_path):
    _, model, params, _ = _moe_setup()
    mgr = CheckpointManager(tmp_path, serving_layout="stream",
                            serving_min_bytes=1024, expert_records=True)
    mgr.save(0, {"params": params}, blocking=True)
    like = jax.eval_shape(model.init, jax.random.key(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(CheckpointError, match="mesh"):
        mgr.load_for_serving(like, mode="stream", prefix="params",
                             min_bytes=1024, mesh=mesh)
