"""Parse collective traffic out of post-SPMD HLO text.

cost_analysis() has no collective accounting, so we regex the compiled
module: for every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute we take the instruction's result shape and replica-group
size and convert to *per-device bytes on the wire* with the standard ring
formulas:

  all-reduce          2 * (n-1)/n * bytes
  all-gather              (n-1)/n * bytes          (result bytes)
  reduce-scatter          (n-1)   * bytes          (result bytes; operand = n*result)
  all-to-all              (n-1)/n * bytes
  collective-permute               bytes

While-loop bodies appear once in the text — the caller applies the same
period-count correction as for FLOPs (launch/roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=...
_INSTR_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )

_TUPLE_INSTR_RE = re.compile(
    r"=\s*\(((?:[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?(?:,\s*)?)+)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            size *= int(d)
    return size


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2  # conservative default


def wire_bytes(kind: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return float(n - 1) * result_bytes
    if kind == "all-to-all":
        return (n - 1) / n * result_bytes
    return float(result_bytes)  # collective-permute


def collective_stats(hlo_text: str) -> dict:
    """-> {kind: {count, result_bytes, wire_bytes}} + totals."""
    stats = defaultdict(lambda: {"count": 0, "result_bytes": 0,
                                 "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-done" in line or "-update" in line:
            continue  # async pair: count the -start only
        m = _INSTR_RE.search(line)
        shapes = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_INSTR_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if not kind:
            continue
        rb = sum(_shape_bytes(d, s) for d, s in shapes)
        n = _group_size(line)
        stats[kind]["count"] += 1
        stats[kind]["result_bytes"] += rb
        stats[kind]["wire_bytes"] += wire_bytes(kind, rb, n)
    out = {k: dict(v) for k, v in stats.items()}
    out["total_wire_bytes"] = sum(v["wire_bytes"] for v in stats.values())
    out["total_count"] = sum(v["count"] for v in stats.values())
    return out
