import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any jax import (device count locks on
# first init).  512 placeholder host devices back the production meshes:
# 16x16 single pod and 2x16x16 multi-pod.
os.environ.setdefault("REPRO_DRYRUN", "1")  # keep bf16 dots in lowered HLO

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell this driver

  1. builds the step function for the cell kind (train_step for train_4k,
     serve prefill/decode steps for the inference shapes),
  2. ``jax.jit(step, in_shardings, out_shardings).lower(**input_specs)``
     on the production mesh and ``.compile()``s it — sharding mismatches,
     compile-time OOM or unsupported collectives fail here,
  3. prints ``compiled.memory_analysis()`` / ``cost_analysis()`` and writes
     a JSON record (results/dryrun/<cell>.json) with the roofline terms'
     raw inputs, including collective bytes parsed from the HLO.

Scan-correction protocol: models whose layer stack is lowered as lax.scan
have loop bodies counted once by cost_analysis; we additionally lower
1-period and 0-period variants and extrapolate
``cost = p0 + n_periods * (p1 - p0)`` (exact for homogeneous stacks).
Models with <= 18 periods are fully unrolled instead (exact by
construction).

Usage:
  python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod-only|--single-only]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, get_config, shape_applicable)
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.registry import (abstract_params, active_param_count,
                                   cache_specs, input_specs, param_count)
from repro.optim import adamw
from repro.runtime import sharding
from repro.runtime.steps import (build_decode_step, build_prefill_step,
                                 build_train_step)

UNROLL_MAX_PERIODS = 18


def _periods(cfg) -> int:
    from repro.models.lm import block_program
    if cfg.is_encdec:
        return cfg.n_layers
    return cfg.n_layers // len(block_program(cfg))


def _with_periods(cfg, n: int):
    from repro.models.lm import block_program
    if cfg.is_encdec:
        return dataclasses.replace(cfg, n_layers=n, encoder_layers=n)
    return dataclasses.replace(cfg, n_layers=n * len(block_program(cfg)))


def _mem_dict(mem) -> dict:
    out = {}
    for attr in dir(mem):
        if attr.startswith("_"):
            continue
        try:
            v = getattr(mem, attr)
        except Exception:
            continue
        if isinstance(v, (int, float)):
            out[attr] = v
    return out


def _cost_dict(cost) -> dict:
    keys = ("flops", "transcendentals", "bytes accessed",
            "bytes accessedout{}")
    return {k: float(cost[k]) for k in keys if k in cost}


def lower_cell(cfg, shape, mesh, *, compile_=True, variant="baseline"):
    """Build + lower (+ compile) one cell on one mesh. Returns stats dict.

    variant="streamed": serve with ENEC-compressed weights resident
    (StreamedWeight pytree; the model resolves the handles in-step) — the
    paper's §VI-C deployment, lowered for the production mesh."""
    model = build_model(cfg)
    if variant == "streamed":
        from repro.core.params import EnecParams
        from repro.runtime import streaming
        p_enec = EnecParams(b=122, n=6, m=3, L=16, l=96)  # Table IV params
        params_abs = streaming.abstract_streamed_params(cfg, p_enec)
    else:
        params_abs = abstract_params(cfg)

    def named(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    smode = "train" if shape.kind == "train" else "serve"
    if variant.startswith(("ep_contract", "ep_a2a")) and shape.kind != "train":
        smode = "serve_ep"
    pspecs = named(sharding.param_pspecs(params_abs, mesh, mode=smode))
    specs = input_specs(cfg, shape)
    bspecs = named(sharding.batch_pspecs(specs, mesh, shape.global_batch))
    scalar = NamedSharding(mesh, P())

    t0 = time.time()
    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        opt_specs = adamw.AdamWState(
            step=scalar, m=pspecs, v=jax.tree.map(lambda s: s, pspecs))
        step = build_train_step(model, adamw.AdamWConfig())
        metrics_specs = {"loss": scalar, "nll": scalar, "aux": scalar,
                         "grad_norm": scalar, "lr": scalar}
        fn = jax.jit(step,
                     in_shardings=(pspecs, opt_specs, bspecs),
                     out_shardings=(pspecs, opt_specs, metrics_specs),
                     donate_argnums=(0, 1))  # in-place params/opt update
        lowered = fn.lower(params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        step = build_prefill_step(model, max_len=shape.seq_len)
        cspecs = named(sharding.cache_pspecs(
            cache_specs(cfg, shape.global_batch, shape.seq_len), mesh,
            shape.global_batch))
        lspec = named(sharding.logits_pspec(mesh, shape.global_batch,
                                            cfg.vocab_size))
        fn = jax.jit(step, in_shardings=(pspecs, bspecs),
                     out_shardings=(lspec, cspecs))
        lowered = fn.lower(params_abs, specs)
    else:  # decode
        step = build_decode_step(model)
        cache_abs = specs["cache"]
        cspecs = named(sharding.cache_pspecs(cache_abs, mesh,
                                             shape.global_batch))
        tok_spec = named(P(sharding.batch_axis(mesh, shape.global_batch)))
        lspec = named(sharding.logits_pspec(mesh, shape.global_batch,
                                            cfg.vocab_size))
        fn = jax.jit(step, in_shardings=(pspecs, cspecs, tok_spec),
                     out_shardings=(lspec, cspecs),
                     donate_argnums=(1,))  # in-place KV-cache update
        lowered = fn.lower(params_abs, cache_abs, specs["tokens"])
    t_lower = time.time() - t0

    rec = {"lower_s": round(t_lower, 2)}
    if not compile_:
        return rec, lowered, None
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    cost = compiled.cost_analysis()
    rec["cost"] = _cost_dict(cost)
    rec["memory"] = _mem_dict(compiled.memory_analysis())
    rec["collectives"] = hlo_stats.collective_stats(compiled.as_text())
    return rec, lowered, compiled


VARIANT_TWEAKS = {
    "baseline": {},
    "streamed": {},
    "remat_dots": {"remat_policy": "dots"},
    "bf16_combine": {"moe_combine_dtype": "bf16"},
    "ep_contract": {},
    "ep_contract_bf16": {"moe_combine_dtype": "bf16"},
    "ep_a2a": {"moe_dispatch_a2a": True},
    "flash_decode": {"decode_score_shard": True},
    "attn_chunk_full": {"attn_chunk": 1 << 20},  # single-pass softmax attn
}


def run_cell(arch: str, shape_name: str, outdir: Path, multi_pod_modes,
             layers_mode: str = "auto", variant: str = "baseline",
             mesh_shape=None) -> dict:
    cfg = get_config(arch)
    if variant in VARIANT_TWEAKS and VARIANT_TWEAKS[variant]:
        cfg = dataclasses.replace(cfg, **VARIANT_TWEAKS[variant])
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape_name)
    record = {
        "arch": arch, "shape": shape_name,
        "params": param_count(cfg), "active_params": active_param_count(cfg),
        "n_periods": _periods(cfg),
    }
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        _write(outdir, arch, shape_name, record)
        print(f"[dryrun] {arch} x {shape_name}: {reason}")
        return record

    unroll = (layers_mode == "unroll" or
              (layers_mode == "auto" and _periods(cfg) <= UNROLL_MAX_PERIODS))
    cfg_full = dataclasses.replace(cfg, scan_layers=not unroll,
                                   remat=(shape.kind == "train"))
    record["layers_mode"] = "unroll" if unroll else "scan"

    record["variant"] = variant
    for mesh_name in multi_pod_modes:
        if mesh_shape is not None:
            import jax as _jax
            mesh = _jax.make_mesh(tuple(mesh_shape), ("data", "model"))
        else:
            mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        entry = {}
        try:
            with mesh:
                rec, lowered, compiled = lower_cell(cfg_full, shape, mesh,
                                                    variant=variant)
                entry["full"] = rec
                print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
                      f"compiled in {rec['compile_s']}s  "
                      f"flops={rec['cost'].get('flops'):.3e}")
                mem = rec["memory"]
                print("  memory_analysis:",
                      json.dumps({k: v for k, v in sorted(mem.items())
                                  if "size" in k or "bytes" in k}))
                print("  cost_analysis:", json.dumps(rec["cost"]))
                # scan-correction lowers (single-pod only, cheap shapes)
                if not unroll and mesh_name == "single":
                    for n_p, key in ((1, "p1"), (0, "p0")):
                        cfg_v = dataclasses.replace(
                            _with_periods(cfg_full, n_p))
                        rec_v, _, _ = lower_cell(cfg_v, shape, mesh,
                                                 variant=variant)
                        entry[key] = rec_v
                entry["status"] = "ok"
        except Exception as e:  # noqa: BLE001 — record the failure verbatim
            entry["status"] = "failed"
            entry["error"] = f"{type(e).__name__}: {e}"
            entry["traceback"] = traceback.format_exc()[-4000:]
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name} FAILED: "
                  f"{entry['error']}")
        record[mesh_name] = entry
    record["status"] = ("ok" if all(
        record.get(m, {}).get("status") == "ok" for m in multi_pod_modes)
        else "failed")
    suffix = shape_name if variant == "baseline" else f"{shape_name}__{variant}"
    if mesh_shape is not None:
        suffix += "__mesh" + "x".join(map(str, mesh_shape))
    _write(outdir, arch, suffix, record)
    return record


def _write(outdir: Path, arch: str, shape_name: str, record: dict):
    outdir.mkdir(parents=True, exist_ok=True)
    path = outdir / f"{arch}__{shape_name}.json"
    existing = {}
    if path.exists():
        try:
            existing = json.loads(path.read_text())
        except Exception:
            existing = {}
    existing.update(record)
    path.write_text(json.dumps(existing, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--layers", default="auto",
                    choices=("auto", "scan", "unroll"))
    ap.add_argument("--variant", default="baseline",
                    choices=("baseline", "streamed", "remat_dots",
                             "bf16_combine", "ep_contract",
                             "ep_contract_bf16", "ep_a2a",
                             "flash_decode", "attn_chunk_full"))
    ap.add_argument("--mesh-shape", default=None,
                    help="override single-pod mesh, e.g. 4x64")
    ap.add_argument("--single-only", action="store_true")
    ap.add_argument("--multi-only", action="store_true")
    args = ap.parse_args()

    modes = ["single", "multi"]
    if args.single_only:
        modes = ["single"]
    if args.multi_only:
        modes = ["multi"]

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    outdir = Path(args.out)
    failures = 0
    mesh_shape = None
    if args.mesh_shape:
        mesh_shape = tuple(int(v) for v in args.mesh_shape.split("x"))
    for arch in archs:
        for shape_name in shapes:
            rec = run_cell(arch, shape_name, outdir, modes, args.layers,
                           variant=args.variant, mesh_shape=mesh_shape)
            failures += rec.get("status") == "failed"
    print(f"[dryrun] done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
