"""Production serving launcher: batched generation behind the weight-
execution policy (paper §VI-C + the fused decode path of DESIGN.md §8).

Modes (runtime/streaming.py, docs/SERVING.md):
  dense   raw weights, canonical tiled matmul executor (baseline)
  stream  ENEC streams decompressed layer-by-layer inside the step
  fused   ENEC tile streams decompressed inside the matmul kernel itself
          (default — the high-throughput decode route)

All three produce bit-identical logits; they differ only in where weight
bytes live and when they decompress.

Checkpoints (docs/CHECKPOINT.md): ``--ckpt DIR`` restores weights through
``CheckpointManager.load_for_serving`` — compressed records flow disk->HBM
and deserialize straight into weight handles; the dense model never exists
on the host.  ``--save-ckpt DIR`` writes an enec-v2 checkpoint (in the
serving layout of the active mode) and continues serving, so a smoke cycle
can produce and consume its own checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
        --batch 4 --tokens 8 --mode fused --save-ckpt /tmp/ck
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
        --batch 4 --tokens 8 --mode fused --ckpt /tmp/ck

Reliability (docs/RELIABILITY.md): restores run with record quarantine and
per-record fallback.  ``--degraded`` (default) serves with the fallback
handles and prints the RestoreReport; ``--strict`` exits nonzero with the
full quarantine list.  :data:`HEALTH` exposes the readiness state
(initializing/restoring/ready/degraded/failed) for probes.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core.codec_api import Codec, use_codec
from repro.models import build_model
from repro.runtime.streaming import assign_weight_modes, mode_mix, \
    stream_stats


@dataclasses.dataclass
class ServerHealth:
    """Readiness/health state of the serving process — the launcher's
    answer to a load balancer's probe (docs/RELIABILITY.md).

    States: ``initializing`` -> ``restoring`` -> ``ready`` | ``degraded``
    (serving with fallback handles after a quarantined restore) |
    ``failed`` (strict policy refused a damaged restore, or no restore
    source at all — the process exits nonzero).
    """
    state: str = "initializing"
    detail: str = ""

    def ready(self) -> bool:
        """Should a load balancer route traffic here?  Degraded serving
        is still correct serving (logits are bit-identical across handle
        modes) — it answers yes."""
        return self.state in ("ready", "degraded")


# module-level so smoke tests and embedding code can probe the last run's
# health without threading it through main()
HEALTH = ServerHealth()


def _link_line(tag, codec):
    """One-line per-link transfer ledger (docs/DISTRIBUTED.md): which links
    moved bytes and whether any of them carried DENSE weights (a sharded
    serve should show dense traffic on no link but the npraw h2d escape)."""
    live = {k: v for k, v in codec.link_stats().items() if v["ops"]}
    if not live:
        return f"[launch.serve] {tag} links: none"
    parts = []
    for k, v in live.items():
        s = f"{k}:{v['compressed_bytes'] / 1e6:.1f}MB"
        if v["dense_bytes"]:
            s += f"+{v['dense_bytes'] / 1e6:.1f}MB-dense"
        parts.append(s)
    return f"[launch.serve] {tag} links: " + " ".join(parts)


def _restore_params(args, model, mode, codec, policy, mesh=None):
    """--ckpt: weights come from the checkpoint, never from init.  The
    launcher's explicit codec owns the restore: its transfer counter and
    decoder cache stats are what gets reported.

    The restore always runs under ``policy="degraded"`` so the FULL
    quarantine list is collected in one pass; main() then decides between
    serving degraded and exiting nonzero (--strict).  Returns
    ``(params, RestoreReport)``."""
    from repro.checkpoint.ckpt import CheckpointManager

    mgr = CheckpointManager(args.ckpt, codec=codec)
    manifest = mgr.manifest()
    names = {e["name"] for e in manifest["leaves"]}
    # train-loop checkpoints are saved as {"params": ..., "opt": ...};
    # serving checkpoints hold the params tree at the root
    prefix = "params" if any(n.startswith("params/") for n in names) else ""
    like = jax.eval_shape(model.init, jax.random.key(0))
    codec.reset_transfer_stats()
    codec.reset_decode_cache_stats()
    t0 = time.perf_counter()
    params, _ = mgr.load_for_serving(like, mode=mode, prefix=prefix,
                                     min_bytes=args.min_bytes,
                                     shards=args.shards, policy="degraded",
                                     mesh=mesh)
    jax.block_until_ready(jax.tree.leaves(params))
    dt = time.perf_counter() - t0
    ts = codec.transfer_stats()
    dst = codec.decode_cache_stats()
    report = mgr.last_restore_report
    rs = report.retry if report is not None else {}
    print(f"[launch.serve] restored step {manifest['step']} from "
          f"{args.ckpt} in {dt:.2f}s "
          f"(h2d {ts['h2d_bytes'] / 1e6:.1f} MB compressed, "
          f"ratio {manifest.get('ratio', 0):.3f}x, "
          f"{dst['dispatches']} decode dispatches, "
          f"io retries {rs.get('retries', 0)}/"
          f"{rs.get('attempts', 0)} attempts)")
    print(_link_line("restore", codec))
    return params, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--mode", default=None,
                    choices=("dense", "stream", "fused"),
                    help="weight-execution mode (docs/SERVING.md); "
                         "default fused")
    ap.add_argument("--dense", action="store_true",
                    help="deprecated alias for --mode dense")
    ap.add_argument("--overlap", default="auto",
                    choices=("off", "on", "auto"),
                    help="decode-prefetch pipeline for streamed weights "
                         "(docs/SERVING.md): decode layer l+1 while layer l "
                         "computes; auto enables it whenever streamed "
                         "leaves are present; logits are bit-identical "
                         "either way")
    ap.add_argument("--min-bytes", type=int, default=4096,
                    help="smallest leaf worth compressing")
    ap.add_argument("--shards", type=int, default=None,
                    help="stream-mode TP shard count for the block dim "
                         "(default: the serving mesh's model-axis width "
                         "under --tp/--mesh, else 2)")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis width of the serving mesh "
                         "(docs/DISTRIBUTED.md): stream shards live "
                         "distributed over this axis and are gathered as "
                         "compressed bytes at consumption; must divide "
                         "the device count; 1 = single-device layout")
    ap.add_argument("--mesh", action="store_true",
                    help="build a (data, model) serving mesh with the "
                         "largest model axis the local device count "
                         "divides by (shorthand for --tp <max divisor>)")
    ap.add_argument("--codec-backend", default="reference",
                    choices=("reference", "pallas"),
                    help="encode/decode backend of the launcher's Codec "
                         "instance (docs/API.md)")
    ap.add_argument("--ckpt", default=None, metavar="DIR",
                    help="restore weights from an ENEC checkpoint via "
                         "load_for_serving (docs/CHECKPOINT.md)")
    ap.add_argument("--save-ckpt", default=None, metavar="DIR",
                    help="write an enec-v2 serving-layout checkpoint of "
                         "the initialized weights, then serve")
    pol = ap.add_mutually_exclusive_group()
    pol.add_argument("--strict", action="store_true",
                     help="refuse a damaged restore: exit nonzero with the "
                          "full quarantine list instead of serving "
                          "fallback handles (docs/RELIABILITY.md)")
    pol.add_argument("--degraded", action="store_true",
                     help="serve through damage with per-record fallbacks "
                          "and print the RestoreReport (default)")
    args = ap.parse_args()
    if args.dense and args.mode not in (None, "dense"):
        ap.error("--dense conflicts with --mode " + args.mode)
    if args.ckpt and args.save_ckpt:
        ap.error("--ckpt and --save-ckpt are mutually exclusive "
                 "(restored weights are already checkpointed)")
    mode = "dense" if args.dense else (args.mode or "fused")
    policy = "strict" if args.strict else "degraded"
    HEALTH.state, HEALTH.detail = "initializing", ""

    mesh = None
    if args.mesh or args.tp > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model="max" if args.mesh and args.tp <= 1
                              else args.tp)
        print(f"[launch.serve] serving mesh axes {dict(mesh.shape)}")
    if args.shards is None:
        # shard width follows the mesh so the stream shards actually land
        # one-per-device (an explicit --shards may still over/under-shard)
        args.shards = mesh.shape["model"] if mesh is not None else 2

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, scan_layers=True, overlap=args.overlap)
    model = build_model(cfg)
    # one explicit Codec instance owns this server's compression state —
    # caches, cache stats, and the h2d transfer counter are all scoped to
    # it, so a second model in the same process cannot perturb them
    codec = Codec(encode_backend=args.codec_backend,
                  decode_backend=args.codec_backend)
    if args.ckpt:
        from repro.checkpoint.ckpt import CheckpointError
        HEALTH.state = "restoring"
        try:
            params, report = _restore_params(args, model, mode, codec,
                                             policy, mesh=mesh)
        except (CheckpointError, FileNotFoundError) as e:
            HEALTH.state, HEALTH.detail = "failed", str(e)
            print(f"[launch.serve] restore FAILED: {e}")
            raise SystemExit(1)
        if report is not None and report.degraded:
            print("[launch.serve]", report.summary())
            if policy == "strict":
                HEALTH.state = "failed"
                HEALTH.detail = (f"{len(report.quarantined)} quarantined "
                                 f"record(s) under --strict")
                print(f"[launch.serve] --strict: refusing to serve with "
                      f"{len(report.quarantined)} quarantined record(s); "
                      f"exiting nonzero")
                raise SystemExit(1)
            HEALTH.state = "degraded"
            HEALTH.detail = f"{len(report.quarantined)} record(s) on fallback"
        else:
            HEALTH.state = "ready"
    else:
        params = model.init(jax.random.key(0))
        params = assign_weight_modes(params, mode=mode,
                                     min_bytes=args.min_bytes,
                                     shards=args.shards, codec=codec)
        if mesh is not None:
            from repro.runtime.collectives import place_serving_tree
            params = place_serving_tree(params, mesh)
        if args.save_ckpt:
            # the handle tree is saved directly (its stream bundles become
            # the records), so the weights are compressed exactly once
            from repro.checkpoint.ckpt import CheckpointManager
            mgr = CheckpointManager(
                args.save_ckpt,
                serving_layout=None if mode == "dense" else mode,
                serving_min_bytes=args.min_bytes,
                serving_shards=args.shards,
                codec=codec)
            t0 = time.perf_counter()
            mgr.save(0, {"params": params}, blocking=True)
            print(f"[launch.serve] saved serving checkpoint to "
                  f"{args.save_ckpt} in {time.perf_counter() - t0:.2f}s")
        HEALTH.state = "ready"
    print(f"[launch.serve] health={HEALTH.state} ready={HEALTH.ready()} "
          f"policy={policy} mode_mix={mode_mix(params)}")
    print(f"[launch.serve] mode={mode} overlap={args.overlap}:",
          stream_stats(params))

    max_len = args.prompt_len + args.tokens
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, max_len))

    # one jit'd decode step: model step + argmax sampling fused, KV cache
    # donated — no per-step cache copy, no host round-trip for the token
    donate = (1,) if jax.default_backend() != "cpu" else ()

    @functools.partial(jax.jit, donate_argnums=donate)
    def decode_step(p, cache, tok):
        logits, cache = model.decode_fn(p, cache, tok)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    # the jitted steps trace under this codec: streamed handles decode
    # through ITS compile caches, not the process default's.  Under a
    # serving mesh, every handle consumption point gathers its compressed
    # shards first (collectives.maybe_gather_ct) — the ambient context is
    # read at trace time
    import contextlib
    if mesh is not None:
        from repro.runtime.collectives import use_serving_mesh
        mesh_ctx = use_serving_mesh(mesh)
    else:
        mesh_ctx = contextlib.nullcontext()
    with use_codec(codec), mesh_ctx:
        t0 = time.perf_counter()
        logits, cache = prefill(params, {"tokens": prompts})
        logits.block_until_ready()
        ttft = time.perf_counter() - t0
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [tok]
        if args.tokens > 1:
            t0 = time.perf_counter()
            for _ in range(args.tokens - 1):
                tok, cache = decode_step(params, cache, tok)
                toks.append(tok)
            jax.block_until_ready(tok)
            dt = time.perf_counter() - t0
            steps = args.tokens - 1
            tpot = dt / steps
            tok_s = args.batch * steps / dt
            print(f"[launch.serve] batch={args.batch} TTFT={ttft*1e3:.1f}ms "
                  f"TPOT={tpot*1e3:.1f}ms tok/s={tok_s:.1f} mode={mode}")
        else:
            # a single token never enters the decode loop — timing it would
            # divide by ~0 and print inf/garbage tok/s, so report TTFT only
            print(f"[launch.serve] batch={args.batch} TTFT={ttft*1e3:.1f}ms "
                  f"(prefill only; --tokens 1 has no decode steps) "
                  f"mode={mode}")
    print(_link_line("serve", codec))
    print("[launch.serve] seq0:", jnp.stack(toks, 1)[0].tolist())


if __name__ == "__main__":
    main()
