"""Production serving launcher: batched generation with ENEC
weight-streaming (the paper's §VI-C deployment).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
        --batch 4 --tokens 8 [--dense]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.runtime.streaming import (compress_params_for_streaming,
                                     decompress_sliced, stream_stats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--dense", action="store_true",
                    help="serve uncompressed weights (baseline)")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    decomp = None
    if not args.dense:
        params = compress_params_for_streaming(params, min_bytes=4096,
                                               shards=2)
        decomp = decompress_sliced
        print("[launch.serve] streaming:", stream_stats(params))

    max_len = args.prompt_len + args.tokens
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    prefill = jax.jit(lambda p, b: model.prefill_fn(
        p, b, max_len, decompressor=decomp))
    decode = jax.jit(lambda p, c, t: model.decode_fn(
        p, c, t, decompressor=decomp))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts})
    logits.block_until_ready()
    ttft = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    toks = [tok]
    for _ in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    tpot = (time.perf_counter() - t0) / max(args.tokens - 1, 1)
    print(f"[launch.serve] batch={args.batch} TTFT={ttft*1e3:.1f}ms "
          f"TPOT={tpot*1e3:.1f}ms mode={'dense' if args.dense else 'enec'}")
    print("[launch.serve] seq0:", jnp.stack(toks, 1)[0].tolist())


if __name__ == "__main__":
    main()
