"""Production serving launcher: continuous-batching generation behind the
weight-execution policy (paper §VI-C + the fused decode path of DESIGN.md
§8), driven by the resilient engine of ``runtime/engine.py``.

Modes (runtime/streaming.py, docs/SERVING.md):
  dense   raw weights, canonical tiled matmul executor (baseline)
  stream  ENEC streams decompressed layer-by-layer inside the step
  fused   ENEC tile streams decompressed inside the matmul kernel itself
          (default — the high-throughput decode route)

All three produce bit-identical logits; they differ only in where weight
bytes live and when they decompress.

Serving (docs/TRAFFIC.md): every run goes through the continuous-batching
engine — ``--batch N`` submits N requests into a bounded admission queue
(``--queue-depth``), they join a ``--concurrency``-slot KV ring at token
granularity, and ``--deadline-ms`` attaches a total per-request deadline
(expired work is shed before prefill or evicted at step granularity).
The one-shot path of earlier PRs is just an engine run whose requests all
arrive at t=0; logits are bit-identical to the old loop.

Checkpoints (docs/CHECKPOINT.md): ``--ckpt DIR`` restores weights through
``CheckpointManager.load_for_serving`` — compressed records flow disk->HBM
and deserialize straight into weight handles; the dense model never exists
on the host.  ``--save-ckpt DIR`` writes an enec-v2 checkpoint (in the
serving layout of the active mode) and continues serving, so a smoke cycle
can produce and consume its own checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
        --batch 4 --tokens 8 --mode fused --save-ckpt /tmp/ck
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --smoke \
        --batch 4 --tokens 8 --mode fused --ckpt /tmp/ck

Reliability (docs/RELIABILITY.md): restores run with record quarantine and
per-record fallback.  ``--degraded`` (default) serves with the fallback
handles and prints the RestoreReport; ``--strict`` exits nonzero with the
full quarantine list.  :data:`HEALTH` exposes the readiness state
(initializing/restoring/ready/degraded/draining/stopped/failed) for
probes; it is an engine-owned, thread-safe
:class:`repro.runtime.engine.ServerHealth` and is reset at every
``main()`` entry so embedded back-to-back runs never inherit a stale
state from an earlier exception.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.codec_api import Codec
from repro.models import build_model
from repro.runtime.engine import Engine, EngineConfig, ServerHealth
from repro.runtime.streaming import assign_weight_modes, mode_mix, \
    stream_stats

# module-level so smoke tests and embedding code can probe the last run's
# health without threading it through main().  The class lives in
# runtime/engine.py now (engine-owned, thread-safe transitions); this
# instance is the launcher's alias — main() resets it at entry and hands
# it to the Engine, which owns every later transition.
HEALTH = ServerHealth()


def _link_line(tag, codec):
    """One-line per-link transfer ledger (docs/DISTRIBUTED.md): which links
    moved bytes and whether any of them carried DENSE weights (a sharded
    serve should show dense traffic on no link but the npraw h2d escape)."""
    live = {k: v for k, v in codec.link_stats().items() if v["ops"]}
    if not live:
        return f"[launch.serve] {tag} links: none"
    parts = []
    for k, v in live.items():
        s = f"{k}:{v['compressed_bytes'] / 1e6:.1f}MB"
        if v["dense_bytes"]:
            s += f"+{v['dense_bytes'] / 1e6:.1f}MB-dense"
        parts.append(s)
    return f"[launch.serve] {tag} links: " + " ".join(parts)


def _restore_params(args, model, mode, codec, policy, mesh=None,
                    expert_store=None):
    """--ckpt: weights come from the checkpoint, never from init.  The
    launcher's explicit codec owns the restore: its transfer counter and
    decoder cache stats are what gets reported.

    The restore always runs under ``policy="degraded"`` so the FULL
    quarantine list is collected in one pass; main() then decides between
    serving degraded and exiting nonzero (--strict).  Returns
    ``(params, RestoreReport)``."""
    from repro.checkpoint.ckpt import CheckpointManager

    mgr = CheckpointManager(args.ckpt, codec=codec)
    manifest = mgr.manifest()
    names = {e["name"] for e in manifest["leaves"]}
    # train-loop checkpoints are saved as {"params": ..., "opt": ...};
    # serving checkpoints hold the params tree at the root
    prefix = "params" if any(n.startswith("params/") for n in names) else ""
    like = jax.eval_shape(model.init, jax.random.key(0))
    codec.reset_transfer_stats()
    codec.reset_decode_cache_stats()
    t0 = time.perf_counter()
    params, _ = mgr.load_for_serving(like, mode=mode, prefix=prefix,
                                     min_bytes=args.min_bytes,
                                     shards=args.shards, policy="degraded",
                                     mesh=mesh, expert_store=expert_store)
    jax.block_until_ready(jax.tree.leaves(params))
    dt = time.perf_counter() - t0
    ts = codec.transfer_stats()
    dst = codec.decode_cache_stats()
    report = mgr.last_restore_report
    rs = report.retry if report is not None else {}
    print(f"[launch.serve] restored step {manifest['step']} from "
          f"{args.ckpt} in {dt:.2f}s "
          f"(h2d {ts['h2d_bytes'] / 1e6:.1f} MB compressed, "
          f"ratio {manifest.get('ratio', 0):.3f}x, "
          f"{dst['dispatches']} decode dispatches, "
          f"io retries {rs.get('retries', 0)}/"
          f"{rs.get('attempts', 0)} attempts)")
    print(_link_line("restore", codec))
    return params, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--mode", default=None,
                    choices=("dense", "stream", "fused"),
                    help="weight-execution mode (docs/SERVING.md); "
                         "default fused")
    ap.add_argument("--dense", action="store_true",
                    help="deprecated alias for --mode dense")
    ap.add_argument("--overlap", default="auto",
                    choices=("off", "on", "auto"),
                    help="decode-prefetch pipeline for streamed weights "
                         "(docs/SERVING.md): decode layer l+1 while layer l "
                         "computes; auto enables it whenever streamed "
                         "leaves are present; logits are bit-identical "
                         "either way")
    ap.add_argument("--min-bytes", type=int, default=4096,
                    help="smallest leaf worth compressing")
    ap.add_argument("--expert-cache-mb", type=float, default=None,
                    metavar="MB",
                    help="MoE expert streaming (docs/MOE.md): keep expert "
                         "stacks as per-expert compressed records and "
                         "decode routed experts through a byte-budgeted "
                         "LRU cache of this many MB (0 caches nothing; "
                         "only MoE arches have eligible leaves)")
    ap.add_argument("--shards", type=int, default=None,
                    help="stream-mode TP shard count for the block dim "
                         "(default: the serving mesh's model-axis width "
                         "under --tp/--mesh, else 2)")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis width of the serving mesh "
                         "(docs/DISTRIBUTED.md): stream shards live "
                         "distributed over this axis and are gathered as "
                         "compressed bytes at consumption; must divide "
                         "the device count; 1 = single-device layout")
    ap.add_argument("--mesh", action="store_true",
                    help="build a (data, model) serving mesh with the "
                         "largest model axis the local device count "
                         "divides by (shorthand for --tp <max divisor>)")
    ap.add_argument("--codec-backend", default="reference",
                    choices=("reference", "pallas"),
                    help="encode/decode backend of the launcher's Codec "
                         "instance (docs/API.md)")
    ap.add_argument("--ckpt", default=None, metavar="DIR",
                    help="restore weights from an ENEC checkpoint via "
                         "load_for_serving (docs/CHECKPOINT.md)")
    ap.add_argument("--save-ckpt", default=None, metavar="DIR",
                    help="write an enec-v2 serving-layout checkpoint of "
                         "the initialized weights, then serve")
    ap.add_argument("--concurrency", type=int, default=None,
                    help="KV slot-ring size of the serving engine "
                         "(docs/TRAFFIC.md): how many requests decode "
                         "together; default = --batch")
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="bounded admission queue depth; offers beyond it "
                         "are rejected with queue_full (docs/TRAFFIC.md)")
    ap.add_argument("--deadline-ms", type=float, default=0,
                    help="total per-request deadline in ms (0 = none): "
                         "expired queued work is shed before prefill, "
                         "in-flight work past it is evicted at step "
                         "granularity (docs/TRAFFIC.md)")
    pol = ap.add_mutually_exclusive_group()
    pol.add_argument("--strict", action="store_true",
                     help="refuse a damaged restore: exit nonzero with the "
                          "full quarantine list instead of serving "
                          "fallback handles (docs/RELIABILITY.md)")
    pol.add_argument("--degraded", action="store_true",
                     help="serve through damage with per-record fallbacks "
                          "and print the RestoreReport (default)")
    args = ap.parse_args()
    if args.dense and args.mode not in (None, "dense"):
        ap.error("--dense conflicts with --mode " + args.mode)
    if args.ckpt and args.save_ckpt:
        ap.error("--ckpt and --save-ckpt are mutually exclusive "
                 "(restored weights are already checkpointed)")
    mode = "dense" if args.dense else (args.mode or "fused")
    policy = "strict" if args.strict else "degraded"
    if args.expert_cache_mb is not None and (args.mesh or args.tp > 1):
        ap.error("--expert-cache-mb does not compose with --mesh/--tp yet: "
                 "the expert store decodes host-side per step (docs/MOE.md)")
    HEALTH.reset()   # embedded back-to-back runs never inherit stale state

    mesh = None
    if args.mesh or args.tp > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model="max" if args.mesh and args.tp <= 1
                              else args.tp)
        print(f"[launch.serve] serving mesh axes {dict(mesh.shape)}")
    if args.shards is None:
        # shard width follows the mesh so the stream shards actually land
        # one-per-device (an explicit --shards may still over/under-shard)
        args.shards = mesh.shape["model"] if mesh is not None else 2

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, scan_layers=True, overlap=args.overlap)
    model = build_model(cfg)
    # one explicit Codec instance owns this server's compression state —
    # caches, cache stats, and the h2d transfer counter are all scoped to
    # it, so a second model in the same process cannot perturb them
    codec = Codec(encode_backend=args.codec_backend,
                  decode_backend=args.codec_backend)
    expert_store = None
    if args.expert_cache_mb is not None:
        # 0 MB is a legal budget: every routed expert is a miss and is
        # dropped right after the step (the worst-case decode cost probe)
        from repro.runtime.experts import ExpertStore
        expert_store = ExpertStore(
            budget_bytes=int(args.expert_cache_mb * 2**20), codec=codec)
    if args.ckpt:
        from repro.checkpoint.ckpt import CheckpointError
        HEALTH.transition("restoring")
        try:
            params, report = _restore_params(args, model, mode, codec,
                                             policy, mesh=mesh,
                                             expert_store=expert_store)
        except (CheckpointError, FileNotFoundError) as e:
            HEALTH.transition("failed", str(e))
            print(f"[launch.serve] restore FAILED: {e}")
            raise SystemExit(1)
        if report is not None and report.degraded:
            print("[launch.serve]", report.summary())
            if policy == "strict":
                HEALTH.transition(
                    "failed", f"{len(report.quarantined)} quarantined "
                              f"record(s) under --strict")
                print(f"[launch.serve] --strict: refusing to serve with "
                      f"{len(report.quarantined)} quarantined record(s); "
                      f"exiting nonzero")
                raise SystemExit(1)
            HEALTH.transition(
                "degraded",
                f"{len(report.quarantined)} record(s) on fallback")
        else:
            HEALTH.transition("ready")
    else:
        params = model.init(jax.random.key(0))
        if expert_store is not None:
            # BEFORE assign_weight_modes: expert stacks become ExpertRef
            # handles and the mode assignment passes them through
            from repro.runtime.experts import install_expert_store
            params, _ = install_expert_store(params, store=expert_store,
                                             min_bytes=args.min_bytes)
        params = assign_weight_modes(params, mode=mode,
                                     min_bytes=args.min_bytes,
                                     shards=args.shards, codec=codec)
        if mesh is not None:
            from repro.runtime.collectives import place_serving_tree
            params = place_serving_tree(params, mesh)
        if args.save_ckpt:
            # the handle tree is saved directly (its stream bundles become
            # the records), so the weights are compressed exactly once
            from repro.checkpoint.ckpt import CheckpointManager
            mgr = CheckpointManager(
                args.save_ckpt,
                serving_layout=None if mode == "dense" else mode,
                serving_min_bytes=args.min_bytes,
                serving_shards=args.shards,
                expert_records=expert_store is not None,
                codec=codec)
            t0 = time.perf_counter()
            mgr.save(0, {"params": params}, blocking=True)
            print(f"[launch.serve] saved serving checkpoint to "
                  f"{args.save_ckpt} in {time.perf_counter() - t0:.2f}s")
        HEALTH.transition("ready")
    print(f"[launch.serve] health={HEALTH.state} ready={HEALTH.ready()} "
          f"policy={policy} mode_mix={mode_mix(params)}")
    print(f"[launch.serve] mode={mode} overlap={args.overlap}:",
          stream_stats(params))

    # ---- engine-driven serving (docs/TRAFFIC.md) -------------------------
    # The Engine traces every jit dispatch under the launcher's codec (its
    # compile caches, not the process default's) and, under a serving
    # mesh, gathers compressed shards at each handle consumption point.
    extra_ctx = None
    if mesh is not None:
        from repro.runtime.collectives import use_serving_mesh
        extra_ctx = lambda: use_serving_mesh(mesh)   # noqa: E731
    slots = args.concurrency if args.concurrency else args.batch
    ecfg = EngineConfig(
        max_slots=max(1, slots),
        queue_depth=max(args.queue_depth, args.batch),
        max_prompt_len=args.prompt_len,
        max_new_tokens=args.tokens,
        default_deadline_s=args.deadline_ms / 1e3 if args.deadline_ms
        else None)
    engine = Engine(model, params, ecfg, codec=codec, health=HEALTH,
                    extra_context=extra_ctx, expert_store=expert_store)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size), np.int32)

    t0 = time.perf_counter()
    reqs = [engine.submit(prompts[i], args.tokens, name=f"seq{i}")
            for i in range(args.batch)]
    engine.run_until_idle()
    wall = time.perf_counter() - t0

    finished = [r for r in reqs if r.state in ("done", "timed_out")]
    ttfts = [r.ttft_s() for r in finished if r.ttft_s() is not None]
    ttft = sum(ttfts) / len(ttfts) if ttfts else 0.0
    if args.tokens > 1:
        tpots = [r.tpot_s() for r in finished if r.tpot_s() is not None]
        tpot = sum(tpots) / len(tpots) if tpots else 0.0
        n_tok = sum(len(r.tokens) for r in finished)
        print(f"[launch.serve] batch={args.batch} TTFT={ttft*1e3:.1f}ms "
              f"TPOT={tpot*1e3:.1f}ms tok/s={n_tok / wall:.1f} mode={mode}")
    else:
        # a single token never enters the decode loop — timing it would
        # divide by ~0 and print inf/garbage tok/s, so report TTFT only
        print(f"[launch.serve] batch={args.batch} TTFT={ttft*1e3:.1f}ms "
              f"(prefill only; --tokens 1 has no decode steps) "
              f"mode={mode}")
    st = engine.stats()["engine"]
    evicted = (st["evicted_deadline"] + st["evicted_fault"]
               + st["evicted_abort"])
    print(f"[launch.serve] engine: slots={ecfg.max_slots} "
          f"steps={st['steps']} prefills={st['prefills']} "
          f"buckets={st['compiled_buckets']} done={st['done']} "
          f"timed_out={st['timed_out']} shed={st['shed']} "
          f"evicted={evicted} rejected={st['rejected']} "
          f"governor={engine.governor.state}")
    if expert_store is not None:
        es = expert_store.stats()
        dec_ms = (1e3 * sum(engine.step_decode_s)
                  / max(1, len(engine.step_decode_s)))
        budget = ("inf" if es["budget_bytes"] is None
                  else f"{es['budget_bytes'] / 1e6:.2f}MB")
        print(f"[launch.serve] experts: hits={es['hits']} "
              f"misses={es['misses']} evictions={es['evictions']} "
              f"fetches={es['fetches']} buckets={es['fetch_buckets']} "
              f"resident={es['resident_bytes'] / 1e6:.2f}MB/{budget} "
              f"miss-decode={dec_ms:.2f}ms/step")
    print(_link_line("serve", codec))
    if reqs and reqs[0].tokens:
        print("[launch.serve] seq0:", list(reqs[0].tokens))
    engine.shutdown(deadline_s=30.0)
    print(f"[launch.serve] health={HEALTH.state} ({HEALTH.detail})")


if __name__ == "__main__":
    main()
