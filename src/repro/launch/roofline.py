"""Roofline analysis (deliverable g) over the dry-run artifacts.

Per (arch x shape) cell on the single-pod 16x16 mesh, derive the three
terms (seconds, per chip):

    compute    = HLO_FLOPs / 197e12            (bf16 peak, v5e)
    memory     = HLO_bytes / 819e9              (HBM bandwidth)
    collective = wire_bytes / 50e9              (ICI per-link)

Sources: compiled.cost_analysis() for FLOPs/bytes; collective wire bytes
parsed from the compiled HLO (launch/hlo_stats.py).  The compiled module is
the per-device SPMD program, so all numbers are already per chip.

Corrections (documented; raw + corrected both recorded):
 1. scan-counted-once: for lax.scan layer stacks, cost = p0 + P*(p1 - p2=p0)
    from the 0/1-period lowers (exact for homogeneous stacks).
 2. recurrent time-scan bodies (Mamba / mLSTM / sLSTM state updates) are
    also counted once; we add the analytic per-step FLOPs x (T-1):
      mamba:  6*B*d_inner*d_state        per layer-step
      mlstm:  6*B*H*hd^2                 per layer-step
      slstm:  8*B*D^2 (recurrent matmul) + 16*B*D   per layer-step
    These are <1% for Jamba (projections dominate) and ~15-40% for xLSTM.

MODEL_FLOPS: 6*N*tokens (train, dense), 6*N_active*tokens (train, MoE),
2*N(_active)*tokens (prefill/decode).  The MODEL_FLOPS/HLO_FLOPs ratio
exposes remat/dispatch/redundancy overhead.

Roofline fraction (the §Perf score):
    T_ideal  = max(model_compute_s, model_min_bytes_s)
    fraction = T_ideal / max(compute_s, memory_s, collective_s)
where model_min_bytes is the traffic that MUST move per step (weights once
+ KV/state once for decode; params*3 + 2-pass activations for train).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models.registry import active_param_count, param_count

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
CHIPS = 256                  # single pod

MESH_DATA, MESH_MODEL = 16, 16


def _corrected(entry: dict, key_path, n_periods: int) -> float:
    """cost = p0 + P*(p1 - p0); falls back to full when unrolled."""
    def get(rec):
        v = rec
        for k in key_path:
            v = v.get(k, 0.0) if isinstance(v, dict) else 0.0
        return float(v or 0.0)

    full = get(entry["full"])
    if "p1" not in entry or "p0" not in entry:
        return full
    p1, p0 = get(entry["p1"]), get(entry["p0"])
    body = max(p1 - p0, 0.0)
    return p0 + n_periods * body


def _recurrent_correction_flops(cfg, shape) -> float:
    """Analytic scan-body FLOPs (per device) for SSM/xLSTM time scans."""
    if shape.kind == "decode":
        return 0.0  # single step: counted exactly
    b_dev = max(shape.global_batch // MESH_DATA, 1)
    t = shape.seq_len
    total = 0.0
    if cfg.family == "hybrid":
        d_inner = 2 * cfg.d_model
        n_mamba = cfg.n_layers * 7 // 8
        total += 6.0 * b_dev * d_inner * cfg.ssm_state * t * n_mamba
    if cfg.family == "ssm":
        hd = cfg.d_model // cfg.n_heads
        n_m = cfg.n_layers * 3 // 4
        n_s = cfg.n_layers - n_m
        total += 6.0 * b_dev * cfg.n_heads * hd * hd * t * n_m
        total += (8.0 * b_dev * cfg.d_model * cfg.d_model
                  + 16.0 * b_dev * cfg.d_model) * t * n_s
    if shape.kind == "train":
        total *= 3.0  # fwd + bwd(2x) through the recurrence
    return total


def model_flops_per_device(cfg, shape) -> float:
    n = param_count(cfg)
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / CHIPS
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / CHIPS
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_act * tokens / CHIPS


def model_min_bytes_per_device(cfg, shape, *, weight_ratio: float = 1.0) -> float:
    """Bytes that must cross HBM per step per chip (ideal lower bound).

    weight_ratio > 1 models ENEC-compressed weight residency (the §Perf
    beyond-paper lever: decode reads weights/ratio bytes)."""
    n = param_count(cfg)
    wbytes = 2.0 * n / CHIPS / weight_ratio
    if shape.kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / MESH_DATA
        act = 4.0 * tokens_dev * cfg.d_model * cfg.n_layers / MESH_MODEL
        return 12.0 * n / CHIPS + act            # p+g+opt r/w (bf16+f32)
    if shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / MESH_DATA
        kv = (2.0 * tokens_dev * cfg.n_kv_heads * cfg.head_dim_() * 2
              * cfg.n_layers / MESH_MODEL)
        return wbytes + kv
    # decode: weights once + full KV/state read once
    if cfg.family in ("ssm",):
        kv_bytes = 0.0
    else:
        attn_layers = (cfg.n_layers // 8 if cfg.family == "hybrid"
                       else cfg.n_layers)
        kv_elems = (shape.global_batch * shape.seq_len * cfg.n_kv_heads
                    * cfg.head_dim_() * 2 * attn_layers)
        kv_bytes = 2.0 * kv_elems / CHIPS
    return wbytes + kv_bytes


def analyze_cell(rec: dict, *, weight_ratio: float = 1.0) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    entry = rec.get("single", {})
    if rec.get("status") == "skipped":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": "skipped", "reason": rec.get("reason", "")}
    if entry.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": "failed",
                "error": entry.get("error", "missing")}

    n_p = rec.get("n_periods", 1)
    flops = _corrected(entry, ("cost", "flops"), n_p)
    bytes_ = _corrected(entry, ("cost", "bytes accessed"), n_p)
    wire = _corrected(entry, ("collectives", "total_wire_bytes"), n_p)
    rec_fl = _recurrent_correction_flops(cfg, shape)
    flops_corr = flops + rec_fl

    compute_s = flops_corr / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    coll_s = wire / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops_per_device(cfg, shape)
    ideal = max(mf / PEAK_FLOPS,
                model_min_bytes_per_device(cfg, shape,
                                           weight_ratio=weight_ratio)
                / HBM_BW)
    frac = ideal / max(terms.values()) if max(terms.values()) else 0.0

    suggestions = {
        ("compute_s", "train"): "reduce remat recompute / larger microbatch",
        ("compute_s", "prefill"): "fuse attention chunks; drop f32 upcasts",
        ("compute_s", "decode"): "decode is tiny-FLOP; check for replicated compute",
        ("memory_s", "train"): "tighter remat policy; fuse optimizer update",
        ("memory_s", "prefill"): "avoid score materialization; bf16 intermediates",
        ("memory_s", "decode"): "ENEC-compressed weight residency (+fused decode-GEMM)",
        ("collective_s", "train"): "overlap FSDP all-gathers; reduce-scatter grads",
        ("collective_s", "prefill"): "resharding copies (SPMD warnings) — align KV layouts",
        ("collective_s", "decode"): "shard KV seq axis; combine EP all-reduce into a2a",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "layers_mode": rec.get("layers_mode"),
        "flops_hlo": flops, "flops_recurrent_corr": rec_fl,
        "flops": flops_corr, "bytes": bytes_, "wire_bytes": wire,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / flops_corr if flops_corr else 0.0,
        "roofline_fraction": round(frac, 4),
        "suggestion": suggestions[(dominant, shape.kind)],
        "multi_pod_ok": rec.get("multi", {}).get("status") == "ok",
        "peak_hbm_gb": round(entry["full"]["memory"]
                             .get("peak_memory_in_bytes", 0) / 2**30, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--weight-ratio", type=float, default=1.0,
                    help="ENEC weight-residency ratio for the ideal bound")
    args = ap.parse_args()

    rows = []
    for path in sorted(Path(args.dryrun_dir).glob("*.json")):
        rec = json.loads(path.read_text())
        # §Perf variant artifacts (…__streamed.json etc.) are compared in
        # EXPERIMENTS.md §Perf; the baseline table stays variant-free.
        if rec.get("variant", "baseline") != "baseline" \
                or "__mesh" in path.stem or len(path.stem.split("__")) > 2:
            continue
        rows.append(analyze_cell(rec, weight_ratio=args.weight_ratio))
    Path(args.out).write_text(json.dumps(rows, indent=1))

    # markdown table
    md = ["| arch | shape | mode | compute_s | memory_s | collective_s | "
          "dominant | MODEL/HLO | roofline_frac | peakHBM(GB) | multi-pod |",
          "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            md.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                      f"— | — | — | {r['reason']} |")
            continue
        if r["status"] == "failed":
            md.append(f"| {r['arch']} | {r['shape']} | FAILED | — | — | — |"
                      f" — | — | — | — | {r['error'][:60]} |")
            continue
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['layers_mode']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant'][:-2]}** "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {r['peak_hbm_gb']} | {'Y' if r['multi_pod_ok'] else 'N'} |")
    table = "\n".join(md)
    Path(args.out).with_suffix(".md").write_text(table)
    print(table)


if __name__ == "__main__":
    main()
