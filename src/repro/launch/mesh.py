"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-mesh / tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def largest_model_axis(n: int, cap=None) -> int:
    """Largest divisor of ``n`` not exceeding ``cap`` (default ``n``) — the
    biggest tensor-parallel axis a ``(data, model)`` factorization of ``n``
    local devices supports."""
    cap = n if cap is None else max(1, min(int(cap), n))
    for m in range(cap, 0, -1):
        if n % m == 0:
            return m
    return 1


def make_host_mesh(*, model=None, max_model=None):
    """Whatever devices exist locally, as an examples/tests mesh.

    Default: the historical 1-D ``("data",)`` mesh.  ``model`` asks for a
    2-D ``(data, model)`` factorization instead — an int names the model
    (TP) axis size exactly (must divide the local device count), ``"max"``
    picks the largest divisor (optionally capped by ``max_model``).  Eight
    host CPU devices (``--xla_force_host_platform_device_count=8``) then
    give e.g. ``model=4`` -> a (2, 4) ``(data, model)`` mesh for exercising
    sharded compressed serving without an accelerator.
    """
    n = len(jax.devices())
    if model is None and max_model is None:
        return jax.make_mesh((n,), ("data",))
    if model in (None, "max"):
        model = largest_model_axis(n, max_model)
    model = int(model)
    if model < 1 or n % model:
        raise ValueError(
            f"model axis {model} does not divide the {n} local devices")
    return jax.make_mesh((n // model, model), ("data", "model"))
