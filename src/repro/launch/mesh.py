"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-mesh / tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
