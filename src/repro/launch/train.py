"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
        --steps 1000 --ckpt /data/ckpt --mesh 16x16

On a real fleet each host runs this after jax.distributed.initialize();
here it sizes the mesh to whatever devices exist (elastic.best_mesh_for),
shards params/optimizer with the production rules, and runs the
fault-tolerant loop (ENEC checkpoints, straggler watchdog, resume).
"""
from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import elastic, sharding
from repro.runtime.steps import build_train_step
from repro.runtime.train_loop import TrainLoopConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 (default: auto)")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, scan_layers=True)
    if args.mesh:
        from repro.launch.mesh import make_mesh
        shape = tuple(int(v) for v in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = elastic.best_mesh_for(cfg)
    print(f"[launch.train] {cfg.name} on mesh {dict(mesh.shape)}")

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_state = adamw.init(params)
    pspecs = sharding.param_pspecs(params, mesh, mode="train")

    def named(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))

    params = jax.device_put(params, named(pspecs))
    opt_specs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
    opt_state = jax.device_put(opt_state, named(opt_specs))

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, schedule=adamw.warmup_cosine(20, args.steps))
    step_fn = jax.jit(build_train_step(model, opt_cfg),
                      donate_argnums=(0, 1))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.global_batch)
    out = run(model, opt_cfg, data_cfg,
              TrainLoopConfig(total_steps=args.steps, ckpt_every=50,
                              log_every=10),
              ckpt=CheckpointManager(Path(args.ckpt)),
              train_step=step_fn, params=params, opt_state=opt_state,
              on_metrics=lambda r: print(f"  step {r['step']} "
                                         f"loss {r['loss']:.4f}"))
    print(f"[launch.train] done: {out['history'][-1]}")


if __name__ == "__main__":
    main()
