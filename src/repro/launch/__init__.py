"""Subpackage."""
