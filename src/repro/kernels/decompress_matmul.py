"""Fused ENEC-decompress + GEMM Pallas kernel (beyond-paper, DESIGN.md §8).

Decode-phase LLM inference is weight-bandwidth bound: every step streams the
full weight matrix HBM -> VMEM for a tiny number of MACs.  Storing weights
ENEC-compressed in HBM and decompressing *inside* the matmul kernel's VMEM
tiles raises effective HBM bandwidth by the compression ratio (~1.35x for
BF16) — the TPU analogue of the paper's CPU->NPU transfer win, one level
down the memory hierarchy.  Decompressed weights never exist in HBM.

Tiling: the weight matrix (K, N) is cut into 128x128 tiles; one tile
(16,384 elements) == exactly one ENEC block, so the paper's preferred block
size doubles as the MXU-aligned tile.  Grid (N/128, K/128), K innermost;
each step decodes one block into VMEM and feeds the MXU, accumulating into
the (M, 128) output tile.

Oracle: decompress-then-matmul in pure jnp (ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import codec
from repro.core.api import CompressedTensor
from repro.core.dtypes import FloatFormat, from_bits
from repro.core.params import EnecParams

from .enec_decode import decode_block_body

TILE = 128
BLOCK_ELEMS = TILE * TILE  # one ENEC block == one MXU weight tile


def tile_weights_for_fusion(w, p: EnecParams) -> CompressedTensor:
    """Compress a (K, N) weight matrix tile-wise for the fused kernel.

    Block t = (n_tile * K/128 + k_tile) holds that 128x128 tile row-major.
    """
    from repro.core.api import compress_array  # local to avoid cycle
    k, n = w.shape
    assert k % TILE == 0 and n % TILE == 0, (k, n)
    tiles = w.reshape(k // TILE, TILE, n // TILE, TILE)
    # (n_tiles, k_tiles, TILE(k), TILE(n)) then flatten per tile row-major
    tiles = tiles.transpose(2, 0, 1, 3).reshape(-1)
    ct = compress_array(tiles, p, block_elems=BLOCK_ELEMS)
    assert ct.mode == "enec", "fused kernel requires enec mode"
    return ct


def _fused_kernel(mask_ref, low_ref, high_ref, raw_ref, x_ref, o_ref, *,
                  fmt, p, k_tiles):
    k = pl.program_id(1)
    bits = decode_block_body(
        mask_ref[0], low_ref[0], high_ref[0], raw_ref[0],
        n_elems=BLOCK_ELEMS, fmt=fmt, p=p)
    w_tile = from_bits(bits, fmt).reshape(TILE, TILE).astype(jnp.float32)
    part = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_tile,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


def decompress_matmul(x, ct: CompressedTensor, k: int, n: int, *,
                      interpret: bool = True):
    """out = x @ W where W (k, n) is stored only in ENEC-compressed form."""
    m = x.shape[0]
    assert x.shape[1] == k and k % TILE == 0 and n % TILE == 0
    k_tiles, n_tiles = k // TILE, n // TILE
    fmt, p = ct.fmt, ct.params
    widths = codec.stream_shapes(BLOCK_ELEMS, fmt, p)
    s = ct.streams

    def wspec(nbytes):
        # weight-stream tile t = n_tile * k_tiles + k_tile
        return pl.BlockSpec((1, nbytes), lambda ni, ki: (ni * k_tiles + ki, 0))

    fn = pl.pallas_call(
        functools.partial(_fused_kernel, fmt=fmt, p=p, k_tiles=k_tiles),
        grid=(n_tiles, k_tiles),
        in_specs=[
            wspec(widths["mask"]), wspec(widths["low"]),
            wspec(widths["high"]), wspec(widths["raw"]),
            pl.BlockSpec((m, TILE), lambda ni, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((m, TILE), lambda ni, ki: (0, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )
    return fn(s.mask, s.low, s.high, s.raw, x)
