"""Fused ENEC-decompress + GEMM Pallas kernel (beyond-paper, DESIGN.md §8).

Decode-phase LLM inference is weight-bandwidth bound: every step streams the
full weight matrix HBM -> VMEM for a tiny number of MACs.  Storing weights
ENEC-compressed in HBM and decompressing *inside* the matmul kernel's VMEM
tiles raises effective HBM bandwidth by the compression ratio (~1.35x for
BF16) — the TPU analogue of the paper's CPU->NPU transfer win, one level
down the memory hierarchy.  Decompressed weights never exist in HBM.

Tiling: the weight matrix (K, N) is cut into 128x128 tiles; one tile
(16,384 elements) == exactly one ENEC block, so the paper's preferred block
size doubles as the MXU-aligned tile.  Grid (N/128, K/128), K innermost;
each step decodes one block into VMEM and feeds the MXU, accumulating into
the (M, 128) output tile.  Ragged K/N ride the zero-padded tile layout of
``core.api.matmul_tiles``.

The grid schedule (tile order + f32 accumulation) is the *numeric contract*
of the serving stack: ``kernels.ref.tiled_matmul_ref`` realizes the same
schedule in pure jnp, and every weight-execution mode (runtime/weights.py)
routes its matmuls through one of the two — which is what makes dense /
stream / fused serve logits bit-identical.

Oracle: decompress-untile-then-tiled-matmul in pure jnp (ref.py), exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import codec
from repro.core.api import (MATMUL_TILE, CompressedTensor,  # noqa: F401
                            tile_weights_for_fusion,
                            tile_weights_for_fusion_many,
                            untile_matmul_weight)
from repro.core.dtypes import from_bits

from .enec_decode import decode_block_body

TILE = MATMUL_TILE
BLOCK_ELEMS = TILE * TILE  # one ENEC block == one MXU weight tile


def _fused_kernel(mask_ref, low_ref, high_ref, raw_ref, x_ref, o_ref, *,
                  fmt, p, k_tiles):
    k = pl.program_id(1)
    bits = decode_block_body(
        mask_ref[0], low_ref[0], high_ref[0], raw_ref[0],
        n_elems=BLOCK_ELEMS, fmt=fmt, p=p)
    w_tile = from_bits(bits, fmt).reshape(TILE, TILE).astype(jnp.float32)
    part = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_tile,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


def decompress_matmul(x, ct: CompressedTensor, k: int, n: int, *,
                      interpret: bool = True):
    """out = x @ W where W (k, n) is stored only in ENEC tile streams.

    ``x``: (M, K) activations — M is B*T tokens (prefill) or B (decode);
    the serving layers flatten (B, T, K) to (B*T, K) before calling in.
    ``ct``: per-layer tile streams (leading dim = tiles).  A stacked
    ``(L, ...)`` tensor from :func:`tile_weights_for_fusion` must be sliced
    to one layer first — ``lax.scan`` does exactly that when the streams
    ride the scanned params, so the kernel works unmodified inside the
    decode scan.  Ragged k/n are handled by the zero-padded tile layout:
    x is zero-padded to the tile multiple and the output sliced back.

    TP-sharded tile streams (``ct.shards > 1``, layout ``(S, B/S, w)``) are
    accepted: the flat tile order is n-major (``t = n_tile * k_tiles +
    k_tile``) and the shard split is a contiguous partition of that flat
    axis, so collapsing the shard dim restores the exact unsharded layout —
    no data movement, just a reshape.  The streams must be gathered
    (replicated) before the call; ``FusedWeight.matmul`` does this through
    ``collectives.maybe_gather_ct`` under an ambient serving mesh.
    """
    m = x.shape[0]
    assert x.shape[1] == k, (x.shape, k)
    assert ct.mode == "enec", "fused kernel requires enec tile streams"
    kp, np_ = -(-k // TILE) * TILE, -(-n // TILE) * TILE
    k_tiles, n_tiles = kp // TILE, np_ // TILE
    s = ct.streams
    assert s.mask.ndim == (3 if ct.shards > 1 else 2), \
        "stacked streams: slice one layer first"
    if ct.shards > 1:
        # (S, B/S, ...) -> (B, ...): contiguous shard ranges of the n-major
        # flat tile axis — the encode split (stacked_blocks) never pads a
        # fused stream (enforced by tile_weights_for_fusion_many /
        # streaming.fused_shards), so this is the bit-exact inverse
        s = codec.flatten_blocks(s)
    assert s.mask.shape[0] == k_tiles * n_tiles, \
        (s.mask.shape, k_tiles, n_tiles)
    if kp != k:
        x = jnp.pad(x, ((0, 0), (0, kp - k)))
    fmt, p = ct.fmt, ct.params
    widths = codec.stream_shapes(BLOCK_ELEMS, fmt, p)
    high, high_w = s.high, widths["high"]
    if high_w == 0:  # m == n: no high stream; feed a dummy byte
        high = jnp.zeros((s.mask.shape[0], 1), jnp.uint8)
        high_w = 1

    def wspec(nbytes):
        # weight-stream tile t = n_tile * k_tiles + k_tile
        return pl.BlockSpec((1, nbytes), lambda ni, ki: (ni * k_tiles + ki, 0))

    fn = pl.pallas_call(
        functools.partial(_fused_kernel, fmt=fmt, p=p, k_tiles=k_tiles),
        grid=(n_tiles, k_tiles),
        in_specs=[
            wspec(widths["mask"]), wspec(widths["low"]),
            wspec(high_w), wspec(widths["raw"]),
            pl.BlockSpec((m, TILE), lambda ni, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((m, TILE), lambda ni, ki: (0, ni)),
        out_shape=jax.ShapeDtypeStruct((m, np_), jnp.float32),
        interpret=interpret,
    )
    out = fn(s.mask, s.low, high, s.raw, x)
    return out[:, :n] if np_ != n else out
