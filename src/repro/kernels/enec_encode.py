"""ENEC block compression as a Pallas TPU kernel.

One block per grid step.  Mirrors ``codec.encode_blocks``:

  split fields -> branch-free linear map -> group OR (replaces reduction
  max, §V-B) -> anomaly mask -> IDD-Scan ranks -> one-hot MXU *scatter* of
  anomalous groups' high bits into rank order -> hierarchical halving pack.

The scatter is the transpose of the decode gather: S[r, g] = 1 iff group g
is the r-th anomalous group, high_dense = S @ y_high.  Same 128-slab
chunking keeps the one-hot tile at (128, G) f32 in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitio, codec, transform
from repro.core.dtypes import FloatFormat, split_fields
from repro.core.params import EnecParams

from .enec_decode import _exclusive_rank, _mask_to_bits  # shared helpers
from .idd_scan import scan_2d

SCATTER_CHUNK = 128


def _onehot_scatter(y_high_f32, rank, anom_i32, g: int, l: int):
    """high_dense[r] = y_high[g] where rank[g] == r and anom[g] — on the MXU."""
    chunk = min(SCATTER_CHUNK, g)
    g_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    outs = []
    for c in range(0, g, chunk):
        # S[r - c, g'] = (rank[g'] == r) & anom[g']
        onehot = ((rank[None, :] == (g_iota + c)) &
                  (anom_i32[None, :] > 0)).astype(jnp.float32)
        outs.append(jax.lax.dot_general(
            onehot, y_high_f32, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
    return jnp.concatenate(outs, axis=0)  # (G, L) rank-ordered, zero padded


def encode_block_body(bits, *, n_elems: int, fmt: FloatFormat, p: EnecParams):
    """bits: (n_elems,) uint view -> (mask, low, high, high_len, raw) slices."""
    g = n_elems // p.L
    exp, raw = split_fields(bits, fmt)
    y = transform.forward(exp.astype(jnp.uint16), p.b, p.n)

    yg = y.reshape(g, p.L)
    gor = jax.lax.reduce(yg, jnp.uint16(0), jnp.bitwise_or, (1,))
    anom = ((gor >> p.m) != 0)
    anom_i32 = anom.astype(jnp.int32)

    mask = bitio.pack_bool_mask(anom[None, :])[0]
    low = bitio.pack_fixed((y & jnp.uint16((1 << p.m) - 1))[None, :], p.m)[0]

    if p.n > p.m:
        rank = _exclusive_rank(anom_i32, g)
        y_high = (yg >> p.m).astype(jnp.float32)
        high_dense = _onehot_scatter(y_high, rank, anom_i32, g, p.L)
        high_dense = high_dense.astype(jnp.uint16).reshape(n_elems)
        high = bitio.pack_fixed(high_dense[None, :], p.n - p.m)[0]
        high_len = jnp.sum(anom_i32) * (p.L * (p.n - p.m))
    else:
        high = jnp.zeros((0,), jnp.uint8)
        high_len = jnp.int32(0)

    rawp = bitio.pack_fixed(raw[None, :], fmt.raw_bits)[0]
    return mask, low, high, high_len, rawp


def _encode_kernel(bits_ref, mask_ref, low_ref, high_ref, hlen_ref, raw_ref,
                   *, n_elems, fmt, p):
    mask, low, high, high_len, rawp = encode_block_body(
        bits_ref[0], n_elems=n_elems, fmt=fmt, p=p)
    mask_ref[0] = mask
    low_ref[0] = low
    if p.n > p.m:
        high_ref[0] = high
    else:
        high_ref[0] = jnp.zeros_like(high_ref[0])
    hlen_ref[0, 0] = high_len
    raw_ref[0] = rawp


def encode_blocks_pallas(bits, fmt: FloatFormat, p: EnecParams, *,
                         interpret: bool = True) -> codec.BlockStreams:
    """Pallas counterpart of ``codec.encode_blocks`` (same layout)."""
    nblocks, n_elems = bits.shape
    widths = codec.stream_shapes(n_elems, fmt, p)

    def spec(nbytes):
        return pl.BlockSpec((1, max(nbytes, 1)), lambda i: (i, 0))

    out_shape = (
        jax.ShapeDtypeStruct((nblocks, widths["mask"]), jnp.uint8),
        jax.ShapeDtypeStruct((nblocks, widths["low"]), jnp.uint8),
        jax.ShapeDtypeStruct((nblocks, max(widths["high"], 1)), jnp.uint8),
        jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
        jax.ShapeDtypeStruct((nblocks, widths["raw"]), jnp.uint8),
    )
    fn = pl.pallas_call(
        functools.partial(_encode_kernel, n_elems=n_elems, fmt=fmt, p=p),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, n_elems), lambda i: (i, 0))],
        out_specs=(spec(widths["mask"]), spec(widths["low"]),
                   spec(widths["high"]), pl.BlockSpec((1, 1), lambda i: (i, 0)),
                   spec(widths["raw"])),
        out_shape=out_shape,
        interpret=interpret,
    )
    mask, low, high, hlen, raw = fn(bits)
    return codec.BlockStreams(
        mask=mask, low=low, high=high[:, :widths["high"]],
        high_len=hlen[:, 0], raw=raw)
