"""Fused decode attention over an ENEC-compressed KV cache (beyond paper).

§Perf hillclimb 1 found that at decode_32k x batch-128 the dominant HBM
traffic is the KV cache (2.1 GB/device/step), not the weights the paper
streams.  KV activations have the same skewed-exponent statistics as
weights (§III applies; cf. the paper's citation [23] on K/V compression),
so ENEC's codec carries over — *if* decompression happens in VMEM on the
attention's critical path, never materializing the dense cache in HBM.

Layout: the frozen prefix of the cache is compressed per (batch, kv_head,
128-token chunk); with head_dim=128 one chunk = 128x128 = 16,384 elements
= exactly one ENEC block (the paper's preferred block size doubles as the
attention tile).  The kernel runs a flash-decoding pass: grid
(batch*kv_head, chunk); each step ENEC-decodes one K tile and one V tile
into VMEM, updates running (m, l, acc) in scratch, and emits o = acc/l at
the last chunk.  HBM reads: compressed streams (~1/1.35 of dense) + q.
The decode step's in-flight tail (tokens since the last seal) stays raw
and is handled by the caller in plain JAX.

Oracle: decompress-then-attend in ref.py; tests sweep shapes and GQA
group sizes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import codec
from repro.core.codec import BlockStreams
from repro.core.dtypes import BF16, from_bits
from repro.core.params import EnecParams

from .enec_decode import decode_block_body

TOK = 128          # tokens per compressed chunk
HD = 128           # head_dim (chunk = TOK*HD = one ENEC block)
BLOCK_ELEMS = TOK * HD


def compress_kv_prefix(kv, p: EnecParams):
    """kv: (B, S, KV, hd) bf16, S % 128 == 0, hd == 128 ->
    BlockStreams with leading dims (B, KV, S/128).

    NOTE: ``p`` must cover BOTH the K and V tensors' exponent ranges
    (search on a concatenated sample, or use ``widen_for_range``) — this
    low-level path does not auto-widen like ``compress_array``."""
    from repro.core import encode_blocks

    b, s, n_kv, hd = kv.shape
    assert hd == HD and s % TOK == 0, (s, hd)
    tiles = kv.transpose(0, 2, 1, 3).reshape(b * n_kv * (s // TOK),
                                             BLOCK_ELEMS)
    bits = tiles.view(BF16.uint_dtype)
    streams = codec.encode_blocks(bits, BF16, p)
    return jax.tree.map(
        lambda a: a.reshape((b, n_kv, s // TOK) + a.shape[1:]), streams)


def _kernel(qr, km, kl, kh, kr, vm, vl, vh, vr, o_ref, acc, m_sc, l_sc, *,
            p, grp, scale):
    c = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_sc[...] = jnp.full_like(m_sc, -1e30)
        l_sc[...] = jnp.zeros_like(l_sc)

    k_bits = decode_block_body(km[0, 0, 0], kl[0, 0, 0], kh[0, 0, 0],
                               kr[0, 0, 0], n_elems=BLOCK_ELEMS, fmt=BF16,
                               p=p)
    v_bits = decode_block_body(vm[0, 0, 0], vl[0, 0, 0], vh[0, 0, 0],
                               vr[0, 0, 0], n_elems=BLOCK_ELEMS, fmt=BF16,
                               p=p)
    k_tile = from_bits(k_bits, BF16).reshape(TOK, HD)
    v_tile = from_bits(v_bits, BF16).reshape(TOK, HD)

    q = qr[0, 0]                                       # (grp, hd)
    scores = jax.lax.dot_general(
        q.astype(jnp.float32), k_tile.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (grp, TOK)
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    prob = jnp.exp(scores - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + prob.sum(axis=-1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot_general(
        prob, v_tile.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(c == n_c - 1)
    def _emit():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_sc[...], 1e-30)
                       ).astype(o_ref.dtype)


def decode_attention_kv_enec(q, k_streams: BlockStreams,
                             v_streams: BlockStreams, p: EnecParams, *,
                             interpret: bool = True):
    """q: (B, KV, grp, hd) -> o (B, KV, grp, hd).

    K/V prefix supplied as ENEC BlockStreams of shape (B, KV, C, bytes)
    from :func:`compress_kv_prefix`.  Attention over the full prefix
    (flash-decoding streaming softmax)."""
    b, n_kv, grp, hd = q.shape
    n_chunks = k_streams.mask.shape[2]
    widths = codec.stream_shapes(BLOCK_ELEMS, BF16, p)
    scale = 1.0 / math.sqrt(hd)

    def sspec(nbytes):
        return pl.BlockSpec((1, 1, 1, max(nbytes, 1)),
                            lambda i, c: (i // n_kv, i % n_kv, c, 0))

    def strm_specs():
        return [sspec(widths["mask"]), sspec(widths["low"]),
                sspec(widths["high"]), sspec(widths["raw"])]

    qspec = pl.BlockSpec((1, 1, grp, hd),
                         lambda i, c: (i // n_kv, i % n_kv, 0, 0))

    def pad_high(s):
        if widths["high"] == 0:
            z = jnp.zeros(s.mask.shape[:3] + (1,), jnp.uint8)
            return s._replace(high=z)
        return s

    ks, vs = pad_high(k_streams), pad_high(v_streams)
    fn = pl.pallas_call(
        functools.partial(_kernel, p=p, grp=grp, scale=scale),
        grid=(b * n_kv, n_chunks),
        in_specs=[qspec] + strm_specs() + strm_specs(),
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, grp, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((grp, hd), jnp.float32),   # acc
            pltpu.VMEM((grp, 1), jnp.float32),    # running max
            pltpu.VMEM((grp, 1), jnp.float32),    # running sum
        ],
        interpret=interpret,
    )
    return fn(q, ks.mask, ks.low, ks.high, ks.raw,
              vs.mask, vs.low, vs.high, vs.raw)
