"""IDD-Scan: intra-segment dependency-decoupled prefix sum (paper §V-D), TPU.

The paper's problem: Ascend AIV forbids SIMD ops between elements inside one
32-byte segment, so a flat prefix sum is "locked".  Their fix: transpose so
intra-row dependencies become inter-row ones, log-step scan, transpose back,
then propagate row offsets hierarchically.

TPU VPU has the same shape of constraint — cross-LANE shifts inside a vreg
are expensive, while full-register ops and the MXU are cheap.  The adaptation
(DESIGN.md §2): move the lane-axis dependency into the *matrix unit*:

  stage 1 (intra-row):  row_incl = M @ U, with U the (128,128) upper-
                        triangular ones matrix — a single MXU op replaces
                        log2(128) cross-lane shuffles.
  stage 2 (inter-row):  log-step scan over the sublane axis (cheap full-
                        register adds, identical to the paper's stage 2),
                        broadcast the exclusive row offsets, add.

Values are exact in f32 for sums < 2**24 — our masks sum to <= G <= 4096.

Kernel: ``idd_scan`` computes inclusive prefix sums along the flattened
(rows*128) axis for every batch row, tiled one batch element per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _upper_triangular(k: int, dtype=jnp.float32):
    r = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    return (r <= c).astype(dtype)


def scan_2d(mat):
    """Inclusive prefix sum of a (rows, LANE) f32 matrix flattened row-major.

    Pure jnp building block, shared by the standalone kernel and the ENEC
    decode kernel body (both trace it inside Pallas).
    """
    rows, lane = mat.shape
    # stage 1: intra-row inclusive scan on the MXU
    u = _upper_triangular(lane, mat.dtype)
    row_incl = jax.lax.dot_general(
        mat, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # stage 2: hierarchical inter-row propagation (paper stage 2, log2(rows))
    totals = row_incl[:, lane - 1 :]  # (rows, 1) inclusive row sums
    offs = totals
    k = 1
    while k < rows:
        shifted = jnp.pad(offs, ((k, 0), (0, 0)))[:rows]
        offs = offs + shifted
        k *= 2
    excl = jnp.pad(offs, ((1, 0), (0, 0)))[:rows]  # exclusive row offsets
    return row_incl + excl


def exclusive_from_inclusive(incl, orig):
    return incl - orig


def _idd_scan_kernel(x_ref, o_ref, *, rows):
    mat = x_ref[0].astype(jnp.float32).reshape(rows, LANE)
    o_ref[0] = scan_2d(mat).reshape(rows * LANE).astype(o_ref.dtype)


def idd_scan(x, *, interpret=None):
    """Batched inclusive prefix sum: x (B, N) -> (B, N) int32, N % 128 == 0.

    One batch row per grid step; the (rows, 128) working set lives in VMEM.
    ``interpret=None`` resolves like every other kernel entry: native on
    TPU, interpreter mode elsewhere (the seed hard-defaulted to ``True``,
    which silently ran the interpreter on TPU too).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n = x.shape
    assert n % LANE == 0, n
    rows = n // LANE
    fn = pl.pallas_call(
        functools.partial(_idd_scan_kernel, rows=rows),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )
    return fn(x.astype(jnp.int32) if x.dtype == jnp.bool_ else x)
