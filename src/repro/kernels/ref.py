"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

Each function mirrors the exact signature/layout of its kernel counterpart
in ops.py; tests sweep shapes/dtypes/params and assert element-exact
equality (these are *lossless* codecs — allclose with atol=0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.api import CompressedTensor
from repro.core.dtypes import FloatFormat
from repro.core.params import EnecParams


def idd_scan_ref(x):
    """Inclusive prefix sum along the last axis, int32."""
    return jnp.cumsum(x.astype(jnp.int32), axis=-1, dtype=jnp.int32)


def encode_blocks_ref(bits, fmt: FloatFormat, p: EnecParams):
    return codec.encode_blocks(bits, fmt, p)


def decode_blocks_ref(streams, n_elems: int, fmt: FloatFormat, p: EnecParams):
    return codec.decode_blocks(streams, n_elems, fmt, p)


def tiled_matmul_ref(x, w):
    """Canonical serve matmul: x (M, K) @ w (K, N) -> (M, N) f32 realizing
    the fused kernel's exact schedule — 128x128 weight tiles, zero-padded
    ragged edges, k-major f32 accumulation per output strip.

    This is the numeric contract of the weight-execution abstraction
    (runtime/weights.py): every mode's ``matmul`` is either this function on
    a materialized weight or the Pallas kernel on compressed tiles, and the
    two are bit-identical by construction (same dot shapes, same values,
    same accumulation order) — which is what makes dense / stream / fused
    serve logits bit-identical.
    """
    from .decompress_matmul import TILE
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    kp, np_ = -(-k // TILE) * TILE, -(-n // TILE) * TILE
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if kp != k:
        xf = jnp.pad(xf, ((0, 0), (0, kp - k)))
    if (kp, np_) != (k, n):
        wf = jnp.pad(wf, ((0, kp - k), (0, np_ - n)))
    strips = []
    for ni in range(np_ // TILE):
        acc = None
        for ki in range(kp // TILE):
            part = jax.lax.dot_general(  # the exact dot the kernel issues
                xf[:, ki * TILE:(ki + 1) * TILE],
                wf[ki * TILE:(ki + 1) * TILE, ni * TILE:(ni + 1) * TILE],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            acc = part if acc is None else acc + part
        strips.append(acc)
    out = jnp.concatenate(strips, axis=1)
    return out[:, :n] if np_ != n else out


def decompress_matmul_ref(x, ct: CompressedTensor, k: int, n: int):
    """Decompress-untile-then-matmul: the fused kernel must match this
    *bit-exactly* (both sides realize :func:`tiled_matmul_ref`)."""
    from repro.core.codec_api import current_codec
    return tiled_matmul_ref(x, current_codec().untile_matmul_weight(ct, k, n))
