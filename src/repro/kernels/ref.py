"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

Each function mirrors the exact signature/layout of its kernel counterpart
in ops.py; tests sweep shapes/dtypes/params and assert element-exact
equality (these are *lossless* codecs — allclose with atol=0).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import codec
from repro.core.api import CompressedTensor, decompress_array
from repro.core.dtypes import FloatFormat
from repro.core.params import EnecParams


def idd_scan_ref(x):
    """Inclusive prefix sum along the last axis, int32."""
    return jnp.cumsum(x.astype(jnp.int32), axis=-1, dtype=jnp.int32)


def encode_blocks_ref(bits, fmt: FloatFormat, p: EnecParams):
    return codec.encode_blocks(bits, fmt, p)


def decode_blocks_ref(streams, n_elems: int, fmt: FloatFormat, p: EnecParams):
    return codec.decode_blocks(streams, n_elems, fmt, p)


def decompress_matmul_ref(x, ct: CompressedTensor, k: int, n: int):
    """Decompress-then-matmul, the semantic the fused kernel must match."""
    from .decompress_matmul import TILE
    k_tiles, n_tiles = k // TILE, n // TILE
    flat = decompress_array(ct)
    tiles = flat.reshape(n_tiles, k_tiles, TILE, TILE)
    w = tiles.transpose(1, 2, 0, 3).reshape(k, n)
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
