"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else they run under
``interpret=True`` (Pallas executes the kernel body in Python/XLA on CPU),
which is how this container validates them.  ``use_pallas=False`` falls back
to the pure-jnp reference path — the serving runtime uses that switch so the
same model code runs on any backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.api import CompressedTensor
from repro.core.dtypes import FloatFormat
from repro.core.params import EnecParams

from . import ref
from .decompress_matmul import decompress_matmul as _fused
from .decompress_matmul import tile_weights_for_fusion  # re-export  # noqa: F401
from .enec_decode import decode_blocks_pallas
from .enec_encode import encode_blocks_pallas
from .idd_scan import idd_scan as _idd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _idd_scan_jit(x, use_pallas: bool):
    if not use_pallas:
        return ref.idd_scan_ref(x)
    return _idd_scan(x, interpret=_interpret())


def idd_scan(x, use_pallas=None):
    """Batched inclusive prefix sum (B, N) -> (B, N) int32.

    ``use_pallas=None`` (default) defers to the ambient codec's encode
    backend (``repro.core.current_codec()``), like the codec entries the
    batched pipeline caches — the seed hard-defaulted to the Pallas path in
    interpreter mode regardless of backend.
    """
    if use_pallas is None:
        from repro.core.codec_api import current_codec  # lazy: avoids cycle
        use_pallas = current_codec().config.encode_backend == "pallas"
    return _idd_scan_jit(x, use_pallas)


def encode_blocks(bits, fmt: FloatFormat, p: EnecParams,
                  use_pallas: bool = True) -> codec.BlockStreams:
    if not use_pallas:
        return ref.encode_blocks_ref(bits, fmt, p)
    return encode_blocks_pallas(bits, fmt, p, interpret=_interpret())


def pipeline_encoder(fmt: FloatFormat, p: EnecParams, use_pallas: bool = True):
    """Encoder callable for the batched compression pipeline (core.api).

    ``core.api`` jit-caches the result per (fmt, params, block-count bucket),
    so the Pallas kernel drives the stacked single-dispatch encode path the
    same way the reference codec does.
    """
    return jax.jit(functools.partial(encode_blocks, fmt=fmt, p=p,
                                     use_pallas=use_pallas))


def decode_blocks(streams: codec.BlockStreams, n_elems: int,
                  fmt: FloatFormat, p: EnecParams,
                  use_pallas: bool = True):
    if not use_pallas:
        return ref.decode_blocks_ref(streams, n_elems, fmt, p)
    return decode_blocks_pallas(streams, n_elems, fmt, p,
                                interpret=_interpret())


def pipeline_decoder(fmt: FloatFormat, p: EnecParams, n_elems: int,
                     use_pallas: bool = True):
    """Decoder callable for the batched decompression pipeline (core.api).

    Mirror of :func:`pipeline_encoder`: ``core.api`` jit-caches the result
    per (fmt, params, block-count bucket), so the Pallas kernel drives the
    stacked single-dispatch decode path the same way the reference codec
    does.  The kernel accepts the stacked ``(L, B)`` stream layout directly
    (flattened on entry) and bakes ``(b, l)`` in statically, so the cache
    keys the full param tuple on this backend.
    """
    return jax.jit(functools.partial(decode_blocks, n_elems=n_elems,
                                     fmt=fmt, p=p, use_pallas=use_pallas))


def decompress_matmul(x, ct: CompressedTensor, k: int, n: int,
                      use_pallas: bool = True):
    """x @ W with W resident only in ENEC-compressed form."""
    if not use_pallas:
        return ref.decompress_matmul_ref(x, ct, k, n)
    return _fused(x, ct, k, n, interpret=_interpret())
