"""Pallas TPU kernels for ENEC hot spots (validated via interpret=True).

enec_encode / enec_decode : the block codec (paper §IV-B + §V)
idd_scan                  : prefix sum, MXU triangular-matmul adaptation (§V-D)
decompress_matmul         : fused decompress+GEMM (beyond paper, DESIGN.md §8)
"""
from . import ops, ref  # noqa: F401
from .decode_attention_kv import (compress_kv_prefix,
                                  decode_attention_kv_enec)
from .ops import (decode_blocks, decompress_matmul, encode_blocks, idd_scan,
                  tile_weights_for_fusion)

__all__ = ["ops", "ref", "decode_blocks", "decompress_matmul",
           "encode_blocks", "idd_scan", "tile_weights_for_fusion",
           "compress_kv_prefix", "decode_attention_kv_enec"]
