"""ENEC block decompression as a Pallas TPU kernel.

One 16,384-element block per grid step; every stream tile lives in VMEM
(mask 128 B + low N·m/8 + high N·(n-m)/8 + raw N·r/8 ≈ 30 KB for BF16 at
(n=6, m=3) — comfortably double-buffered by Pallas against the ~16 MB VMEM).

TPU adaptations inside the body (DESIGN.md §2):
  * prefix sum over the anomaly mask  -> IDD-Scan (MXU triangular matmul)
  * reverse gather of anomalous high bits -> one-hot MXU matmul, chunked in
    128-group slabs so the one-hot slab is a (128, G) f32 tile (512 KB max)
    instead of a (G, G) monolith
  * exponent inverse mapping -> branch-free linear transform (VPU add/and)
  * bit-unpacking -> static unrolled halving un-fold (slices + shift + or)

The pure-jnp oracle is ``repro.core.codec.decode_blocks`` (see ref.py); the
kernel is verified element-exact against it across shape/dtype/param sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitio, codec, transform
from repro.core.dtypes import FloatFormat, combine_fields
from repro.core.params import EnecParams

from .idd_scan import scan_2d

GATHER_CHUNK = 128


def _mask_to_bits(mask_bytes, g: int):
    """(Gb,) u8 -> (G,) int32 bits, little endian (matches pack_bool_mask)."""
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (mask_bytes.shape[0], 8), 1)
    bits = (mask_bytes[:, None] >> shifts) & jnp.uint8(1)
    return bits.reshape(g).astype(jnp.int32)


def _exclusive_rank(anom_i32, g: int):
    """Exclusive prefix sum of the anomaly bits via IDD-Scan."""
    lane = 128 if g % 128 == 0 else g
    mat = anom_i32.astype(jnp.float32).reshape(g // lane, lane)
    incl = scan_2d(mat).reshape(g)
    return incl.astype(jnp.int32) - anom_i32


def _onehot_gather(high_dense_f32, rank, anom_i32, g: int, l: int):
    """gathered[gr] = high_dense[rank[gr]] if anom[gr] else 0 — on the MXU."""
    chunk = min(GATHER_CHUNK, g)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, g), 1)
    outs = []
    for c in range(0, g, chunk):
        rk = jax.lax.dynamic_slice_in_dim(rank, c, chunk)
        am = jax.lax.dynamic_slice_in_dim(anom_i32, c, chunk)
        onehot = ((rk[:, None] == r_iota) & (am[:, None] > 0)).astype(jnp.float32)
        outs.append(jax.lax.dot_general(
            onehot, high_dense_f32, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
    return jnp.concatenate(outs, axis=0)  # (G, L) f32, exact (< 2**m values)


def decode_block_body(mask_b, low_b, high_b, raw_b, *, n_elems: int,
                      fmt: FloatFormat, p: EnecParams):
    """Decode one block. 1-D uint8 stream slices -> (n_elems,) uint bits."""
    g = n_elems // p.L
    anom = _mask_to_bits(mask_b, g)
    rank = _exclusive_rank(anom, g)

    y_low = bitio.unpack_fixed(low_b[None, :], n_elems, p.m)[0]
    y = y_low
    if p.n > p.m:
        high_dense = bitio.unpack_fixed(high_b[None, :], n_elems, p.n - p.m)[0]
        high_dense = high_dense.reshape(g, p.L).astype(jnp.float32)
        gathered = _onehot_gather(high_dense, rank, anom, g, p.L)
        gathered = gathered.astype(jnp.uint16).reshape(n_elems)
        y = y_low | (gathered << p.m)

    exp = transform.inverse(y, p.b, p.n, p.l)
    raw = bitio.unpack_fixed(raw_b[None, :], n_elems, fmt.raw_bits,
                             out_dtype=fmt.uint_dtype)[0]
    return combine_fields(exp.astype(fmt.uint_dtype), raw, fmt)


def _decode_kernel(mask_ref, low_ref, high_ref, raw_ref, out_ref, *,
                   n_elems, fmt, p):
    out_ref[0] = decode_block_body(
        mask_ref[0], low_ref[0], high_ref[0], raw_ref[0],
        n_elems=n_elems, fmt=fmt, p=p)


def decode_blocks_pallas(streams: codec.BlockStreams, n_elems: int,
                         fmt: FloatFormat, p: EnecParams, *,
                         interpret: bool = True):
    """Pallas counterpart of ``codec.decode_blocks`` (same signature/layout)."""
    nblocks = streams.mask.shape[0]
    widths = codec.stream_shapes(n_elems, fmt, p)

    def spec(nbytes):
        return pl.BlockSpec((1, max(nbytes, 1)), lambda i: (i, 0))

    high = streams.high
    if widths["high"] == 0:  # m == n: no high stream; feed a dummy byte
        high = jnp.zeros((nblocks, 1), jnp.uint8)

    fn = pl.pallas_call(
        functools.partial(_decode_kernel, n_elems=n_elems, fmt=fmt, p=p),
        grid=(nblocks,),
        in_specs=[spec(widths["mask"]), spec(widths["low"]),
                  spec(widths["high"]), spec(widths["raw"])],
        out_specs=pl.BlockSpec((1, n_elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, n_elems), fmt.uint_dtype),
        interpret=interpret,
    )
    return fn(streams.mask, streams.low, high, streams.raw)
