"""ENEC block decompression as a Pallas TPU kernel.

Up to ``_STEP_ELEM_BUDGET`` block elements per grid step (multiple blocks
for small block sizes — amortizes grid overhead on small tensors); every
stream tile lives in VMEM (mask 128 B + low N·m/8 + high N·(n-m)/8 +
raw N·r/8 ≈ 30 KB for BF16 at (n=6, m=3) — comfortably double-buffered by
Pallas against the ~16 MB VMEM).

TPU adaptations inside the body (DESIGN.md §2):
  * prefix sum over the anomaly mask  -> IDD-Scan (MXU triangular matmul)
  * reverse gather of anomalous high bits -> segment-local one-hot MXU
    matmul: destination segment s only ever reads the 128 rank-ordered rows
    starting at its exclusive anomaly offset (the IDD-scan's stage-2 row
    offset), so each segment is one (128, 128) one-hot matmul — O(G·128·L)
    MXU FLOPs instead of the chunked (128, G) one-hot's O(G²·L)
  * exponent inverse mapping -> branch-free linear transform (VPU add/and)
  * bit-unpacking -> static unrolled halving un-fold (slices + shift + or)

The pure-jnp oracle is ``repro.core.codec.decode_blocks`` (see ref.py); the
kernel is verified element-exact against it across shape/dtype/param sweeps
(including all-anomaly, zero-anomaly, and tail-padded blocks).

Streams may carry the batched pipeline's stacked ``(L, [shards,] B, ...)``
leading layout — it is flattened to one block axis on entry, so
``kernels.ops.pipeline_decoder`` drives whole-stack decode exactly like
``pipeline_encoder`` does for encode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitio, codec, transform
from repro.core.dtypes import FloatFormat, combine_fields
from repro.core.params import EnecParams

from .idd_scan import scan_2d

GATHER_SEG = 128
# elements decoded per grid step: one 16,384-element block, or up to 8
# smaller blocks unrolled in one step so tiny tensors don't pay one grid
# step (and its stream-tile DMA round) per block
_STEP_ELEM_BUDGET = 16384
_MAX_BLOCKS_PER_STEP = 8


def _mask_to_bits(mask_bytes, g: int):
    """(Gb,) u8 -> (G,) int32 bits, little endian (matches pack_bool_mask)."""
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (mask_bytes.shape[0], 8), 1)
    bits = (mask_bytes[:, None] >> shifts) & jnp.uint8(1)
    return bits.reshape(g).astype(jnp.int32)


def _exclusive_rank(anom_i32, g: int):
    """Exclusive prefix sum of the anomaly bits via IDD-Scan."""
    lane = 128 if g % 128 == 0 else g
    mat = anom_i32.astype(jnp.float32).reshape(g // lane, lane)
    incl = scan_2d(mat).reshape(g)
    return incl.astype(jnp.int32) - anom_i32


def _segment_gather(high_dense_f32, rank, anom_i32, g: int, l: int):
    """gathered[gr] = high_dense[rank[gr]] if anom[gr] else 0 — on the MXU.

    The exclusive ranks of the groups in segment ``s`` (128 destinations)
    all lie in ``[start, start + 127]`` with ``start = rank[s * 128]`` —
    the segment's exclusive anomaly offset, which the IDD-scan's stage-2
    row propagation already materialized.  One dynamic 128-row slice of the
    rank-ordered source plus one (128, 128) one-hot matmul therefore covers
    every destination in the segment, and MXU work scales with the group
    count instead of its square.  ``start <= s * 128`` (at most one anomaly
    per preceding group), so the slice never runs off the end.
    """
    seg = min(GATHER_SEG, g)
    iota = jax.lax.broadcasted_iota(jnp.int32, (seg, seg), 1)
    outs = []
    for c in range(0, g, seg):
        rk = jax.lax.dynamic_slice_in_dim(rank, c, seg)
        am = jax.lax.dynamic_slice_in_dim(anom_i32, c, seg)
        start = rk[0]
        src = jax.lax.dynamic_slice_in_dim(high_dense_f32, start, seg)
        onehot = (((rk - start)[:, None] == iota) &
                  (am[:, None] > 0)).astype(jnp.float32)
        outs.append(jax.lax.dot_general(
            onehot, src, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
    return jnp.concatenate(outs, axis=0)  # (G, L) f32, exact (< 2**m values)


def decode_block_body(mask_b, low_b, high_b, raw_b, *, n_elems: int,
                      fmt: FloatFormat, p: EnecParams):
    """Decode one block. 1-D uint8 stream slices -> (n_elems,) uint bits."""
    g = n_elems // p.L
    anom = _mask_to_bits(mask_b, g)
    rank = _exclusive_rank(anom, g)

    y_low = bitio.unpack_fixed(low_b[None, :], n_elems, p.m)[0]
    y = y_low
    if p.n > p.m:
        high_dense = bitio.unpack_fixed(high_b[None, :], n_elems, p.n - p.m)[0]
        high_dense = high_dense.reshape(g, p.L).astype(jnp.float32)
        gathered = _segment_gather(high_dense, rank, anom, g, p.L)
        gathered = gathered.astype(jnp.uint16).reshape(n_elems)
        y = y_low | (gathered << p.m)

    exp = transform.inverse(y, p.b, p.n, p.l)
    raw = bitio.unpack_fixed(raw_b[None, :], n_elems, fmt.raw_bits,
                             out_dtype=fmt.uint_dtype)[0]
    return combine_fields(exp.astype(fmt.uint_dtype), raw, fmt)


def _decode_kernel(mask_ref, low_ref, high_ref, raw_ref, out_ref, *,
                   n_elems, fmt, p, block_step):
    for j in range(block_step):
        out_ref[j] = decode_block_body(
            mask_ref[j], low_ref[j], high_ref[j], raw_ref[j],
            n_elems=n_elems, fmt=fmt, p=p)


def blocks_per_step(nblocks: int, n_elems: int) -> int:
    """Largest power-of-two block count per grid step that divides the
    total, stays within the per-step element budget, and bounds the body
    unroll — the batched pipeline's bucketed counts (pow2 / 64-multiples)
    always divide cleanly."""
    bs = 1
    while (bs * 2 <= _MAX_BLOCKS_PER_STEP and nblocks % (bs * 2) == 0
           and bs * 2 * n_elems <= _STEP_ELEM_BUDGET):
        bs *= 2
    return bs


def decode_blocks_pallas(streams: codec.BlockStreams, n_elems: int,
                         fmt: FloatFormat, p: EnecParams, *,
                         interpret=None):
    """Pallas counterpart of ``codec.decode_blocks`` (same layout).

    Accepts flat ``(B, ...)`` streams or the stacked ``(L, [shards,] B,
    ...)`` pipeline layout (flattened on entry); returns ``(total_blocks,
    n_elems)`` decoded bits either way.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if streams.mask.ndim > 2:  # stacked pipeline layout: flatten block dims
        streams = codec.flatten_blocks(streams)
    nblocks = streams.mask.shape[0]
    widths = codec.stream_shapes(n_elems, fmt, p)
    bs = blocks_per_step(nblocks, n_elems)

    def spec(nbytes):
        return pl.BlockSpec((bs, max(nbytes, 1)), lambda i: (i, 0))

    high = streams.high
    if widths["high"] == 0:  # m == n: no high stream; feed a dummy byte
        high = jnp.zeros((nblocks, 1), jnp.uint8)

    fn = pl.pallas_call(
        functools.partial(_decode_kernel, n_elems=n_elems, fmt=fmt, p=p,
                          block_step=bs),
        grid=(nblocks // bs,),
        in_specs=[spec(widths["mask"]), spec(widths["low"]),
                  spec(widths["high"]), spec(widths["raw"])],
        out_specs=pl.BlockSpec((bs, n_elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, n_elems), fmt.uint_dtype),
        interpret=interpret,
    )
    return fn(streams.mask, streams.low, high, streams.raw)
