"""ENEC-compressed, fault-tolerant checkpointing (enec-v2 container).

Layout (one directory per step):
    <root>/step_000001230/
        manifest.json          tree structure + per-record (pack, offset,
                               length) index, shapes, dtypes, ENEC stats
        pack-00000.bin ...     per-shard pack files: concatenated framed
                               wire records (length + CRC32 per record)
    <root>/LATEST              atomic pointer file (rename-committed)

Properties needed at 1000+ nodes:
  * atomicity — write to ``.tmp-`` dir, fsync every pack AND the manifest
    AND the directory entries, rename, fsync the parent; LATEST updated
    last; a crash mid-save never corrupts the previous checkpoint and never
    commits a step whose manifest is missing or truncated;
  * async     — saves run on a background thread over host copies, training
    continues; a failed async save re-raises from ``wait()`` and from the
    next ``save()`` instead of vanishing in a daemon thread;
  * parallel  — records are serialized by a thread pool (``writers``) and
    streamed round-robin to the per-shard pack files (peak host memory
    never holds the whole checkpoint);
  * verified  — every record is framed (explicit length + CRC32), so
    ``load()`` rejects truncated or bit-flipped records with a clear error
    instead of silently misdecoding;
  * partial   — records are indexed by name, so serving restores ONLY the
    weight records (optimizer state is never read, let alone inflated);
  * elastic   — load() reshards to ANY mesh via device_put with the target
    NamedShardings (topology can shrink/grow between runs);
  * ~1.35x fewer bytes to the storage system via ENEC (per-tensor searched
    params; raw escape keeps incompressible leaves at 1.0x, never worse);
  * keep-last-k retention + stale-tmp-dir GC (crashed saves leak nothing).

``serving_layout="stream"|"fused"`` additionally stores every
policy-eligible weight in its exact *serving* stream layout (the same
bundles ``runtime.streaming.assign_weight_modes`` would build), which is
what lets :meth:`CheckpointManager.load_for_serving` deserialize records
straight into ``StreamedWeight`` / ``FusedWeight`` handles — compressed
bytes flow disk -> HBM and the dense tensor never exists on the host.
``load()`` still restores the bit-exact dense training tree from the same
records (docs/CHECKPOINT.md).

Reliability (docs/RELIABILITY.md): every pack/manifest read and pack write
funnels through the manager's :class:`RetryPolicy` (transient I/O errors
are retried with backoff+jitter) and the fault-injection hooks of
``runtime/faults.py``.  Under ``policy="degraded"``, a record that still
fails — corrupt frame, exhausted retries, decode failure — is quarantined
on a :class:`RestoreReport` and restored per record from the newest
earlier step with an intact copy, while the surviving records keep the
batched O(#buckets) decode path.  ``policy="strict"`` (the default)
preserves the historical abort-on-first-error contract.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire as enec_wire
from repro.core.api import SUPPORTED_FLOAT_DTYPES, slice_stacked
from repro.core.codec_api import Codec, current_codec
from repro.runtime import experts as rt_experts
from repro.runtime import faults as rt_faults
from repro.runtime import streaming as rt_streaming
from repro.runtime.retry import RetryPolicy
from repro.runtime.weights import (DenseWeight, finish_materialize,
                                   handle_from_spec, handle_spec, is_handle)

_ENEC_DTYPES = SUPPORTED_FLOAT_DTYPES

MANIFEST_FORMAT = "enec-v2"

# tree roots that hold optimizer state under the {"params", "opt"} saving
# convention: their leaves mirror the weight paths (so the serving-layout
# eligibility heuristic would match them) but can never be served — they
# stay plain records instead of paying the tile/moveaxis re-layout
_NON_SERVING_ROOTS = frozenset({"opt", "opt_state", "optimizer"})


class CheckpointError(RuntimeError):
    """A checkpoint could not be saved or restored."""


RESTORE_POLICIES = ("strict", "degraded")


@dataclasses.dataclass
class _ExpertPart:
    """One per-expert record queued for the batched decode of a training
    ``load()``: its manifest handle spec (parent / layer / expert grid
    coordinates) plus the device-resident compressed tensor.  The decode
    pass reassembles every part of a parent into the dense ``(L, E, ...)``
    stack — still O(#buckets) dispatches, since all parts of a leaf share
    one searched param set and therefore one decoder bucket."""
    spec: dict
    ct: object


@dataclasses.dataclass
class QuarantinedRecord:
    """One record a restore could not use: its coordinates (name, pack,
    byte offset, length), why it was rejected, and — once the per-record
    fallback succeeds — where the replacement bytes came from."""
    name: str
    pack: str
    offset: int
    length: int
    cause: str
    fallback: str = ""

    def describe(self) -> str:
        line = (f"{self.name} [{self.pack} @ {self.offset}, "
                f"{self.length}B]: {self.cause}")
        if self.fallback:
            line += f" -> {self.fallback}"
        return line


@dataclasses.dataclass
class RestoreReport:
    """What a restore survived (docs/RELIABILITY.md): the quarantined
    records with cause and fallback, plus the manager's retry-policy
    counters — surfaced next to the codec cache stats so reliability is
    observable, not folklore.  Every ``load``/``load_for_serving`` stashes
    its report on ``CheckpointManager.last_restore_report``; an empty
    quarantine list means the restore was clean."""
    step: int
    policy: str
    quarantined: list = dataclasses.field(default_factory=list)
    retry: dict = dataclasses.field(default_factory=dict)

    @property
    def degraded(self) -> bool:
        return bool(self.quarantined)

    def summary(self) -> str:
        head = (f"RestoreReport(step={self.step}, policy={self.policy}, "
                f"quarantined={len(self.quarantined)}, retry={self.retry})")
        return "\n".join([head] + ["  " + q.describe()
                                   for q in self.quarantined])


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_handle)
    names = ["/".join(str(getattr(k, "key", getattr(k, "name",
             getattr(k, "idx", k)))) for k in path) for path, _ in flat]
    return names, [l for _, l in flat], treedef


def _fsync_path(path) -> None:
    """fsync a file or directory by path (directories need it too: the
    rename-commit is only durable once the parent's entries are)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_nbytes(shape, dtype_str: str) -> int:
    return int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype_str).itemsize


@dataclasses.dataclass
class CheckpointManager:
    root: Path
    keep_last: int = 3
    compress: bool = True
    writers: int = 4                       # pack shards == writer threads
    serving_layout: Optional[str] = None   # None | "stream" | "fused"
    serving_min_bytes: int = rt_streaming.MIN_STREAM_BYTES
    serving_shards: int = 1
    # serving_layout extension: save (L, E, ...) MoE expert stacks as
    # PER-EXPERT wire records so load_for_serving can restore them into an
    # ExpertStore without inflating cold experts (docs/MOE.md).  Opt-in:
    # trees that never install an expert store keep monolithic records.
    expert_records: bool = False
    codec: Optional[Codec] = None          # default: ambient codec at init
    retry: Optional[RetryPolicy] = None    # default: RetryPolicy()
    _thread: Optional[threading.Thread] = None
    _exc: Optional[BaseException] = None

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.last_decode_plan = None   # DecodePlan of the latest load
        self.last_restore_report = None   # RestoreReport of the latest load
        self.last_expert_store = None  # ExpertStore of the latest serving load
        if self.retry is None:
            # one policy per manager: its attempt counters aggregate every
            # pack/manifest read and pack write this manager performs
            self.retry = RetryPolicy()
        if self.codec is None:
            # captured once — every save/load of this manager encodes and
            # decodes through ONE codec instance (caches, counters)
            self.codec = current_codec()
        if self.serving_layout is not None and \
                self.serving_layout not in ("stream", "fused"):
            raise ValueError(
                f"serving_layout must be None, 'stream' or 'fused', "
                f"got {self.serving_layout!r}")

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        self.wait()    # also re-raises a previous async failure
        names, leaves, _ = _tree_paths(tree)
        # compression runs device-resident BEFORE any host transfer: only
        # compressed streams (and the raw non-float leaves) ever cross to the
        # host, and repeated (shape, dtype) float leaves share one stacked
        # encode dispatch (docs/PIPELINE.md)
        payload, dense_specs = self._prepare(names, leaves)
        if blocking:
            self._save_host(step, names, payload, dense_specs)
            return
        self._thread = threading.Thread(
            target=self._save_guarded, args=(step, names, payload,
                                             dense_specs),
            daemon=True)
        self._thread.start()

    def _save_guarded(self, step, names, payload, dense_specs):
        try:
            self._save_host(step, names, payload, dense_specs)
        except BaseException as e:  # noqa: BLE001 — surfaced via wait()
            self._exc = e

    def wait(self):
        """Join the in-flight async save.  If it failed, re-raise its
        exception here (and therefore also from the next ``save()``, which
        waits first) — an async save error must never report success."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise CheckpointError(
                f"async checkpoint save failed: {exc}") from exc

    def _prepare(self, names, leaves):
        """Per-leaf record plan:
             ("np",  host_array)            raw host bytes (non-float)
             ("ct",  CompressedTensor)      plain enec/raw/const record
             ("hct", ct, spec, raw_bytes)   stacked serving-layout record
             ("xct", meta, records)         per-expert record group (MoE)
        """
        payload: list = [None] * len(leaves)
        float_slots, other_slots, serve_jobs = [], [], []
        dense_specs: dict = {}   # slot -> handle spec for fallback leaves
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            if is_handle(leaf):
                if isinstance(leaf, rt_experts.ExpertRef):
                    # the store already holds the exact per-expert wire
                    # bodies — re-emit them verbatim, no re-encode
                    payload[i] = ("xct", leaf.store.meta(leaf.name),
                                  leaf.store.records_for(leaf.name))
                    continue
                if isinstance(leaf, DenseWeight):
                    leaf = leaf.w       # stored dense; re-wrapped on restore
                    dense_specs[i] = {"kind": "dense"}
                    leaves[i] = leaf
                else:
                    spec = handle_spec(leaf)
                    raw = _leaf_nbytes(
                        (leaf.ct.streams.mask.shape[0],)
                        + tuple(spec.get("layer_shape")
                                or (spec["k"], spec["n"])), spec["dtype"])
                    payload[i] = ("hct", leaf.ct, spec, raw)
                    continue
            dt = getattr(leaf, "dtype", None)   # dtype check without a copy
            if not (self.compress and dt is not None
                    and jnp.dtype(dt) in _ENEC_DTYPES):
                other_slots.append(i)
                continue
            if self.serving_layout is not None and i not in dense_specs \
                    and name.split("/", 1)[0] not in _NON_SERVING_ROOTS:
                if (self.expert_records
                        and rt_experts.is_expert_leaf(name, leaf)
                        and _leaf_nbytes(leaf.shape, str(jnp.dtype(
                            leaf.dtype))) >= self.serving_min_bytes):
                    enc = rt_experts.encode_expert_leaf(
                        name, jnp.asarray(leaf), self.codec)
                    if enc is not None:
                        meta, records = enc
                        payload[i] = ("xct", meta, records)
                        continue
                    # escape (const / incompressible): monolithic record
                # (slots unwrapped from a DenseWeight stay dense records —
                # the policy that built the tree already decided against
                # compressing them)
                job = rt_streaming.serving_job(name, jnp.asarray(leaf),
                                               self.serving_layout,
                                               self.serving_min_bytes)
                if job is not None:
                    job["slot"] = i
                    serve_jobs.append(job)
                    continue
            float_slots.append(i)
        if other_slots:   # one batched transfer for all uncompressed leaves
            hosts = jax.device_get([leaves[i] for i in other_slots])
            for i, h in zip(other_slots, hosts):
                payload[i] = ("np", np.asarray(h))

        # serving-layout leaves: compress the exact stream bundles the
        # weight-execution policy would build (shared serving_job /
        # build_serving_handle code path), so load_for_serving can
        # deserialize them straight into handles
        if serve_jobs:
            # per-job shard width, mirroring assign_weight_modes: fused tile
            # streams shard only when the tile-block count divides (pad
            # blocks would corrupt the kernel's flat tile order), stream
            # bundles always take the manager's width
            cts = [None] * len(serve_jobs)
            by_shards: dict = {}
            for j_ix, job in enumerate(serve_jobs):
                job_shards = (rt_streaming.fused_shards(
                    job["k"], job["n"], self.serving_shards)
                    if job["kind"] == "fused" else self.serving_shards)
                by_shards.setdefault(job_shards, []).append(j_ix)
            for job_shards, idxs in sorted(by_shards.items()):
                group = self.codec.compress_stacked_many(
                    [serve_jobs[j]["arr"] for j in idxs], shards=job_shards)
                for j, ct in zip(idxs, group):
                    cts[j] = ct
            for job, ct in zip(serve_jobs, cts):
                i = job["slot"]
                handle = rt_streaming.build_serving_handle(job, ct)
                if is_handle(handle) and not isinstance(handle, DenseWeight):
                    spec = handle_spec(handle)
                    payload[i] = ("hct", handle.ct, spec,
                                  job["leaf"].size * job["leaf"].dtype.itemsize)
                else:
                    # const / incompressible escape: plain dense record,
                    # re-wrapped as DenseWeight by the restore policy
                    if job["matmul_pos"]:
                        dense_specs[i] = {"kind": "dense"}
                    float_slots.append(i)

        # every remaining float leaf rides the batched pipeline as its own
        # L=1 stack: per-leaf searched params (ratio parity with the seed —
        # unrelated same-shape tensors like weights vs Adam moments must NOT
        # share params), no jnp.stack duplicate on device, while statistics,
        # the never-worse wire check, and encode dispatches all stay batched
        # — leaves whose (n, m, L) coincide share one concatenated dispatch
        # via the encoder's dynamic-b bucketing.
        float_slots.sort()
        cts = self.codec.compress_stacked_many(
            [jnp.asarray(leaves[i])[None] for i in float_slots])
        for i, ct in zip(float_slots, cts):
            if ct is None:
                # const / incompressible / empty: per-leaf escape path.
                payload[i] = ("ct",
                              self.codec.compress_array(
                                  jnp.asarray(leaves[i])))
            else:
                payload[i] = ("ct", slice_stacked(ct, 0))
        return payload, dense_specs

    # -- record building / pack writing ----------------------------------

    def _build_record(self, index, name, item, dense_specs):
        """List of (manifest entry sans pack/offset, framed blob, raw
        bytes) — one element for ordinary leaves, one PER EXPERT for an
        ``xct`` record group."""
        tag = item[0]
        if tag == "xct":
            _, meta, records = item
            eshape = [int(s) for s in meta["expert_shape"]]
            per_raw = _leaf_nbytes(eshape, meta["dtype"])
            out = []
            for l, j, body in records:
                entry = {"name": f"{name}::x{l:04d}.{j:04d}",
                         "index": index, "shape": eshape,
                         "dtype": meta["dtype"], "mode": "enec",
                         "handle": {"kind": "expert", "parent": name,
                                    "layer": int(l), "expert": int(j),
                                    "n_layers": int(meta["n_layers"]),
                                    "n_experts": int(meta["n_experts"]),
                                    "expert_shape": eshape,
                                    "dtype": meta["dtype"]},
                         "bytes": len(body)}
                out.append((entry, enec_wire.frame(body), per_raw))
            return out
        if tag == "np":
            leaf = item[1]
            entry = {"name": name, "index": index, "shape": list(leaf.shape),
                     "dtype": str(leaf.dtype), "mode": "npraw"}
            blob = b"RAW0" + leaf.tobytes()
            raw = leaf.nbytes
        elif tag == "ct":
            ct = item[1]
            entry = {"name": name, "index": index, "shape": list(ct.shape),
                     "dtype": ct.dtype_str, "mode": ct.mode}
            if ct.params is not None:
                entry["params"] = list(ct.params.astuple())
            blob = enec_wire.to_wire(ct)   # moves compressed bytes only
            raw = ct.nbytes_raw()
        else:   # "hct": stacked serving-layout record
            _, ct, spec, raw = item
            entry = {"name": name, "index": index,
                     "shape": list(ct.shape), "dtype": ct.dtype_str,
                     "mode": ct.mode, "handle": spec,
                     "stack": int(ct.streams.mask.shape[0]),
                     "params": list(ct.params.astuple())}
            blob = enec_wire.to_wire(ct, stacked=True)
        spec = dense_specs.get(index)
        if spec is not None and "handle" not in entry:
            entry["handle"] = spec
        entry["bytes"] = len(blob)
        return [(entry, enec_wire.frame(blob), raw)]

    def _save_host(self, step: int, names, payload, dense_specs) -> None:
        t0 = time.time()
        final = self.root / f"step_{step:012d}"
        tmp = self.root / f".tmp-step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        n_packs = max(1, min(self.writers, len(payload) or 1))
        manifest = {"format": MANIFEST_FORMAT, "step": step,
                    "packs": [f"pack-{i:05d}.bin" for i in range(n_packs)],
                    "leaves": []}
        if self.serving_layout is not None:
            manifest["serving_layout"] = {
                "mode": self.serving_layout,
                "min_bytes": self.serving_min_bytes,
                # requested width; fused records narrow per record via
                # rt_streaming.fused_shards (each record's ct stores its
                # actual shard count)
                "shards": self.serving_shards}
        raw_total = comp_total = 0
        offsets = [0] * n_packs
        # records are serialized by the thread pool and STREAMED round-robin
        # to the pack shards; submission is bounded (a sliding window of
        # in-flight builds), so peak host memory holds a few frames — never
        # the whole checkpoint — even when the filesystem writes slowly
        files = [open(tmp / name, "wb") for name in manifest["packs"]]
        workers = max(self.writers, 1)
        pending: deque = deque()

        def drain_one():
            nonlocal raw_total, comp_total
            i, fut = pending.popleft()
            pack = i % n_packs
            for entry, framed, raw in fut.result():
                entry["pack"] = pack
                entry["offset"] = offsets[pack]
                entry["length"] = len(framed)

                def write_framed(f=files[pack], pos=offsets[pack],
                                 fr=framed, name=manifest["packs"][pack]):
                    # seek back to the record's committed offset on every
                    # attempt, so a retried write after a partial one lays
                    # the frame down exactly once
                    rt_faults.check_write(name)
                    f.seek(pos)
                    f.write(fr)

                self.retry.call(write_framed,
                                describe=manifest["packs"][pack])
                offsets[pack] += len(framed)
                raw_total += raw
                comp_total += entry["bytes"]
                manifest["leaves"].append(entry)

        try:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                for i, (n, it) in enumerate(zip(names, payload)):
                    pending.append((i, ex.submit(
                        self._build_record, i, n, it, dense_specs)))
                    if len(pending) >= 2 * workers:
                        drain_one()
                while pending:
                    drain_one()
            for f in files:
                f.flush()
                os.fsync(f.fileno())
        finally:
            for f in files:
                f.close()

        manifest["raw_bytes"] = raw_total
        manifest["compressed_bytes"] = comp_total
        manifest["ratio"] = raw_total / max(comp_total, 1)
        manifest["save_s"] = round(time.time() - t0, 3)
        # fsync the manifest AND the tmp directory entries BEFORE the
        # rename: otherwise a crash can commit a step directory whose
        # manifest is missing or truncated
        with open(tmp / "manifest.json", "w") as f:
            f.write(json.dumps(manifest, indent=1))
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic commit
        _fsync_path(self.root)                  # …made durable
        latest_tmp = self.root / ".LATEST.tmp"
        with open(latest_tmp, "w") as f:
            f.write(final.name)
            f.flush()
            os.fsync(f.fileno())
        latest_tmp.rename(self.root / "LATEST")
        _fsync_path(self.root)
        self._gc()

    def _gc(self):
        # never GC by name alone: a step whose manifest does not parse
        # might hold the only intact copy of a record a degraded restore
        # still needs — retention counts and deletes only steps it can
        # actually read
        steps = sorted(p for p in self.root.glob("step_*") if p.is_dir())
        intact = [p for p in steps if self._try_manifest(p) is not None]
        for old in intact[: max(0, len(intact) - self.keep_last)]:
            shutil.rmtree(old, ignore_errors=True)
        # stale tmp dirs from crashed saves would otherwise leak forever
        # (our own tmp has already been renamed away by the time GC runs)
        for stale in self.root.glob(".tmp-step_*"):
            shutil.rmtree(stale, ignore_errors=True)

    # -- load ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        """Step named by the LATEST pointer, or None when the pointer is
        missing, unreadable, or garbage (default-step resolution then
        falls back to the newest step directory with an intact manifest
        instead of dying on the pointer file)."""
        ptr = self.root / "LATEST"
        try:
            text = ptr.read_text()
        except OSError:
            return None
        try:
            return int(text.strip().split("_")[-1])
        except ValueError:
            return None

    def manifest(self, step: Optional[int] = None) -> dict:
        """The manifest of ``step`` (default: latest) without reading any
        record bytes — launchers use it to sniff the name prefix and the
        stored serving layout."""
        return self._step_dir(step)[1]

    def _try_manifest(self, cdir) -> Optional[dict]:
        """Parse a step dir's manifest, or None if it is missing, corrupt,
        or unreadable (reads go through the retry policy, so a transient
        error does not misclassify an intact step)."""
        path = cdir / "manifest.json"
        try:
            raw = self.retry.call(lambda: rt_faults.read_file(path),
                                  describe=str(path))
            return json.loads(raw.decode())
        except (OSError, ValueError):
            # ValueError covers JSONDecodeError and UnicodeDecodeError
            return None

    def _step_candidates(self) -> list:
        """Step numbers to try for ``step=None``: LATEST's target first,
        then every committed step directory, newest first."""
        out = []
        s = self.latest_step()
        if s is not None:
            out.append(s)
        for p in sorted(self.root.glob("step_*"), reverse=True):
            if not p.is_dir():
                continue
            try:
                c = int(p.name.split("_")[-1])
            except ValueError:
                continue
            if c not in out:
                out.append(c)
        return out

    def _step_dir(self, step: Optional[int]) -> tuple:
        """Resolve ``(cdir, manifest)``.  An EXPLICIT step must be intact
        (a corrupt manifest raises).  ``step=None`` resolves LATEST and —
        when the pointer dangles or its manifest is corrupt — falls back
        to the newest earlier step whose manifest parses, so one damaged
        file never makes the whole root unrestorable."""
        if step is not None:
            cdir = self.root / f"step_{step:012d}"
            manifest_path = cdir / "manifest.json"
            if not manifest_path.exists():
                raise CheckpointError(f"{cdir} has no manifest.json")
            try:
                return cdir, json.loads(manifest_path.read_text())
            except (json.JSONDecodeError, UnicodeDecodeError) as e:
                raise CheckpointError(
                    f"{manifest_path} is corrupt: {e}") from e
        candidates = self._step_candidates()
        if not candidates:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        causes = []
        for c in candidates:
            try:
                return self._step_dir(c)
            except CheckpointError as e:
                causes.append(str(e))
        raise CheckpointError(
            "no step with an intact manifest under "
            f"{self.root}: " + "; ".join(causes))

    @staticmethod
    def _require_records(names, by_name, cdir, what="records", groups=None):
        missing = [n for n in names
                   if n not in by_name and not (groups and n in groups)]
        if missing:
            raise CheckpointError(
                f"checkpoint {cdir.name} lacks {what} for {missing[:5]}"
                + ("…" if len(missing) > 5 else ""))

    @staticmethod
    def _expert_groups(manifest) -> dict:
        """parent leaf name -> its per-expert record entries (the
        ``expert_records`` save layout splits one ``(L, E, ...)`` leaf
        into ``L*E`` sub-records named ``{parent}::x{l:04d}.{j:04d}``)."""
        groups: dict = {}
        for e in manifest["leaves"]:
            spec = e.get("handle")
            if spec is not None and spec.get("kind") == "expert":
                groups.setdefault(spec["parent"], []).append(e)
        return groups

    @staticmethod
    def _expand_entries(names, by_name, groups):
        """Record entries to read for ``names``, expert groups inlined."""
        out = []
        for n in names:
            if n in by_name:
                out.append(by_name[n])
            else:
                out.extend(groups[n])
        return out

    @staticmethod
    def _check_leaf(name, shape, like, dtype=None):
        if tuple(shape) != tuple(like.shape):
            raise CheckpointError(f"{name}: ckpt {tuple(shape)} vs model "
                                  f"{tuple(like.shape)}")
        if dtype is not None and dtype != str(jnp.dtype(like.dtype)):
            raise CheckpointError(f"{name}: ckpt dtype {dtype} vs model "
                                  f"{jnp.dtype(like.dtype)}")

    def _quarantine(self, report, e, manifest, cause) -> QuarantinedRecord:
        """Record one failed record on ``report`` with its coordinates."""
        packs = manifest.get("packs")
        pack = (packs[e["pack"]] if packs is not None and "pack" in e
                else f"t_{e.get('index', 0):05d}.enec")
        q = QuarantinedRecord(
            name=e["name"], pack=pack, offset=int(e.get("offset", 0)),
            length=int(e.get("length", e.get("bytes", -1))), cause=cause)
        report.quarantined.append(q)
        return q

    def _iter_records(self, cdir, manifest, entries, report=None):
        """Yield ``(entry, payload_bytes)`` for ``entries``, validated
        (frame length + CRC for v2 packs; declared blob size for v1
        per-leaf files), one record at a time in pack/offset order — the
        caller stages each record to device as it goes, so peak host
        memory holds one record's compressed bytes, never the whole
        checkpoint (decoding is deferred into one batched pass).  Only the
        requested records are read (partial load never touches the rest of
        the pack).

        Every read funnels through the manager's retry policy and the
        fault-injection hooks (runtime/faults.py) — transient I/O errors
        are absorbed here.  With ``report=None`` (strict) the first record
        that still fails raises; with a :class:`RestoreReport` the record
        is quarantined and skipped, and the caller arranges a per-record
        fallback afterwards."""
        fmt = manifest.get("format", "enec-v1")
        if fmt == "enec-v1":
            for e in entries:
                path = cdir / f"t_{e['index']:05d}.enec"
                try:
                    blob = self.retry.call(
                        lambda p=path: rt_faults.read_file(p),
                        describe=path.name)
                    if "bytes" in e and len(blob) != e["bytes"]:
                        raise CheckpointError(
                            f"{path.name}: {len(blob)} bytes on disk, "
                            f"manifest declares {e['bytes']} — truncated "
                            f"or corrupt")
                except OSError as err:
                    if report is None:
                        raise CheckpointError(
                            f"{path.name} ({e['name']}): {err}") from err
                    self._quarantine(report, e, manifest, str(err))
                    continue
                except CheckpointError as err:
                    if report is None:
                        raise
                    self._quarantine(report, e, manifest, str(err))
                    continue
                self.codec.count_link("disk", len(blob),
                                      dense=e.get("mode") == "npraw")
                yield e, blob
            return
        if fmt != MANIFEST_FORMAT:
            raise CheckpointError(f"unknown checkpoint format {fmt!r}")
        by_pack: dict = {}
        for e in entries:
            by_pack.setdefault(e["pack"], []).append(e)
        for pack, es in sorted(by_pack.items()):
            path = cdir / manifest["packs"][pack]
            for e in sorted(es, key=lambda e: e["offset"]):
                try:
                    buf = self.retry.call(
                        lambda e=e: rt_faults.read_range(
                            path, e["offset"], e["length"]),
                        describe=f"{path.name}@{e['offset']}")
                    payload, end = enec_wire.read_frame(
                        buf, record=e["name"], pack=path.name,
                        base_offset=e["offset"])
                    if end != len(buf):
                        raise enec_wire.WireError(
                            f"frame length {end} != indexed {len(buf)}",
                            record=e["name"], pack=path.name,
                            offset=e["offset"])
                except (OSError, enec_wire.WireError) as err:
                    if isinstance(err, enec_wire.WireError):
                        # satellite: both except sites attach (leaf name,
                        # pack file, byte offset) to the WireError
                        err.with_context(record=e["name"], pack=path.name,
                                         offset=e["offset"])
                    if report is None:
                        raise CheckpointError(
                            f"{path.name} @ {e['offset']} ({e['name']}): "
                            f"{err}") from err
                    self._quarantine(report, e, manifest, str(err))
                    continue
                # the disk link of the per-link ledger: compressed record
                # payloads vs raw (npraw) bytes actually read off storage
                self.codec.count_link("disk", len(payload),
                                      dense=e.get("mode") == "npraw")
                yield e, payload

    def _decode_npraw(self, e, blob):
        blob = bytes(blob)
        if blob[:4] != b"RAW0":
            raise CheckpointError(f"corrupt raw blob for {e['name']}")
        arr = np.frombuffer(blob[4:], dtype=np.dtype(e["dtype"]))
        if arr.size != int(np.prod(e["shape"], dtype=np.int64)):
            raise CheckpointError(
                f"{e['name']}: raw payload holds {arr.size} elements, "
                f"manifest declares shape {e['shape']}")
        # counted on this manager's codec like every other record upload —
        # these are DENSE bytes on the h2d link (the raw escape)
        return enec_wire.h2d(arr.reshape(e["shape"]), self.codec,
                             dense=True)

    def _record_ct(self, e, blob, packs=None, stream_place=None):
        """Deserialize one compressed record's payload — the compressed
        streams move to device here (counted on this manager's codec);
        nothing is decoded yet.  ``stream_place`` (a
        ``collectives.stream_placer`` hook) uploads each stream leaf with
        its TP-shard dim on the target mesh axis, so a shard's pack bytes
        reach the owning devices only.  Any :class:`WireError` leaves with
        the record's (leaf name, pack file, byte offset) attached."""
        pack = packs[e["pack"]] if packs is not None and "pack" in e \
            else None
        try:
            return enec_wire.from_wire(blob, codec=self.codec,
                                       record=e["name"], pack=pack,
                                       offset=e.get("offset"),
                                       stream_place=stream_place)
        except enec_wire.WireError as err:
            err.with_context(record=e["name"], pack=pack,
                             offset=e.get("offset"))
            raise CheckpointError(f"{e['name']}: {err}") from err

    def _queue_record(self, e, blob, pending, vals, like, packs=None):
        """One record -> either an eagerly decoded host value (``npraw``)
        or a device-resident compressed object queued on ``pending`` for
        the batched decode pass (serving-layout records become handles;
        plain enec/raw/const records stay CompressedTensors)."""
        name = e["name"]
        if e["mode"] == "npraw":
            val = self._decode_npraw(e, blob)
            self._check_leaf(name, val.shape, like)
            vals[name] = val.astype(like.dtype)
            return
        ct = self._record_ct(e, blob, packs=packs)
        spec = e.get("handle")
        if spec is not None and spec.get("kind") == "expert":
            # one slice of a per-expert record group: queued as a part,
            # reassembled into the dense parent stack after the batched
            # decode (_decode_pending)
            pending.append((name, like, _ExpertPart(spec, ct)))
            return
        obj = (handle_from_spec(e["handle"], ct)
               if "handle" in e and e.get("stack") else ct)
        pending.append((name, like, obj))

    def _decode_pending(self, pending, vals):
        """Decode every queued compressed record in ONE batched pipeline
        pass: records sharing a decoder bucket — serving-layout handle
        records and plain enec records alike — share a concatenated decode
        dispatch (``core.api.decompress_stacked_many``), so restoring a
        model costs O(#buckets) decode dispatches instead of one per
        record.  The decode runs where the streams live (device); outputs
        are bit-identical to the retired per-record path.  The executed
        :class:`repro.core.DecodePlan` is kept on ``last_decode_plan`` so
        callers (benches, CI) can assert the restore cost
        ``len(plan.buckets)`` dispatches."""
        plan = self.codec.plan_decode(
            [obj.ct if (is_handle(obj) or isinstance(obj, _ExpertPart))
             else obj for _, _, obj in pending])
        decs = self.codec.execute(plan)
        # keep only the inspectable summary: the execution-state fields
        # hold the full compressed streams on device and would pin them
        # until the next load
        self.last_decode_plan = dataclasses.replace(
            plan, _treedef=None, _groups=[], _passthrough={}, _leaves=[])
        parents: dict = {}
        for (name, like, obj), dec in zip(pending, decs):
            if isinstance(obj, _ExpertPart):
                g = parents.setdefault(
                    obj.spec["parent"],
                    {"like": like, "spec": obj.spec, "decs": {}})
                g["decs"][(int(obj.spec["layer"]),
                           int(obj.spec["expert"]))] = dec
                continue
            val = finish_materialize(obj, dec) if is_handle(obj) else dec
            self._check_leaf(name, val.shape, like)
            vals[name] = val.astype(like.dtype)
        for parent, g in parents.items():
            sp = g["spec"]
            nl, ne = int(sp["n_layers"]), int(sp["n_experts"])
            eshape = tuple(int(s) for s in sp["expert_shape"])
            self._check_leaf(parent, (nl, ne) + eshape, g["like"])
            buf = np.empty((nl, ne) + eshape, jnp.dtype(sp["dtype"]))
            for l in range(nl):
                for j in range(ne):
                    dec = g["decs"].get((l, j))
                    if dec is None:
                        raise CheckpointError(
                            f"{parent}: expert record grid incomplete — "
                            f"missing layer {l} expert {j}")
                    buf[l, j] = np.asarray(dec)
            vals[parent] = jnp.asarray(buf).astype(g["like"].dtype)

    def _apply_decode_faults(self, pending, manifest, by_name, report):
        """Fault-injection hook for the decode dispatch: records matched
        by an active "decode" fault are dropped from the batched plan
        BEFORE it is built — quarantined (degraded) or fatal (strict) —
        so the surviving records still decode in one replanned batched
        pass.  No-op without an active injector."""
        if rt_faults.active() is None:
            return pending
        out = []
        for item in pending:
            name = item[0]
            try:
                rt_faults.check_decode(name)
            except rt_faults.InjectedFault as err:
                if report is None:
                    raise CheckpointError(
                        f"decode failed for {name}: {err}") from err
                self._quarantine(report, by_name.get(name, {"name": name}),
                                 manifest, f"decode failed: {err}")
                continue
            out.append(item)
        return out

    def _intact_steps(self, before: Optional[int] = None) -> list:
        """``(step, cdir, manifest)`` for every committed step whose
        manifest parses, newest first; ``before`` excludes that step and
        anything newer (fallback never reads forward in time)."""
        out = []
        for p in sorted(self.root.glob("step_*"), reverse=True):
            if not p.is_dir():
                continue
            try:
                s = int(p.name.split("_")[-1])
            except ValueError:
                continue
            if before is not None and s >= before:
                continue
            man = self._try_manifest(p)
            if man is not None:
                out.append((s, p, man))
        return out

    def _fallback_restore(self, report, manifest, like_by_name, vals,
                          pending, process=None):
        """Per-record fallback for quarantined records: walk earlier steps
        (newest first, intact manifests only) and restore the first intact
        copy of each record — read, validated, shape/dtype-checked, and
        decode-fault-checked exactly like a first-class record, so an
        injected decode failure cannot sneak back in through the fallback.
        ``process`` overrides how a recovered record is staged (the
        serving restore passes its adopt-or-queue closure).  A record with
        no intact source anywhere raises: a degraded restore never
        fabricates weights."""
        steps = self._intact_steps(before=manifest.get("step"))
        for q in report.quarantined:
            if q.fallback or q.name not in like_by_name:
                continue
            like = like_by_name[q.name]
            for s, fcdir, fman in steps:
                fe = next((e for e in fman["leaves"]
                           if e["name"] == q.name), None)
                if fe is None:
                    continue
                n_pend = len(pending)
                try:
                    got = False
                    for e2, payload in self._iter_records(fcdir, fman,
                                                          [fe]):
                        if process is not None:
                            process(e2, payload, like, fman, pending, vals)
                        else:
                            self._queue_record(e2, payload, pending, vals,
                                               like,
                                               packs=fman.get("packs"))
                        got = True
                    if not got:
                        raise CheckpointError(
                            f"{q.name}: record unreadable at step {s}")
                    new = pending[n_pend:]
                    if new:
                        pending[n_pend:] = self._apply_decode_faults(
                            new, fman, {q.name: fe}, None)
                except (OSError, CheckpointError,
                        enec_wire.WireError):
                    # this step can't supply the record — roll back any
                    # partial staging and walk further back in time
                    del pending[n_pend:]
                    vals.pop(q.name, None)
                    continue
                kind = ((fe.get("handle") or {}).get("kind")
                        or fe.get("mode", "?"))
                q.fallback = f"step {s} ({kind} record)"
                break
            if not q.fallback:
                raise CheckpointError(
                    "restore failed — no intact source for quarantined "
                    "record(s):\n" + report.summary())

    def _begin_report(self, policy, manifest) -> RestoreReport:
        if policy not in RESTORE_POLICIES:
            raise ValueError(f"unknown restore policy {policy!r}; "
                             f"expected one of {RESTORE_POLICIES}")
        return RestoreReport(step=int(manifest.get("step", -1)),
                             policy=policy)

    def _finish_report(self, report) -> None:
        report.retry = self.retry.stats()
        self.last_restore_report = report

    def load(self, like_tree, step: Optional[int] = None,
             shardings=None, *, policy: str = "strict"):
        """Restore into the structure of ``like_tree``; reshard to
        ``shardings`` (elastic: any mesh) or keep host arrays.

        ``policy="strict"`` (default) aborts on the first bad record —
        bit-exactness or nothing, the right contract for training resume.
        ``policy="degraded"`` quarantines records that fail I/O,
        validation, or decode and falls back per record to the newest
        earlier step holding an intact copy; the :class:`RestoreReport`
        (returned on ``last_restore_report``) enumerates every quarantined
        record with cause and fallback.  A record with no intact source
        anywhere still raises — degraded mode trades freshness, never
        correctness."""
        cdir, manifest = self._step_dir(step)
        report = self._begin_report(policy, manifest)
        rep = report if policy == "degraded" else None
        names, leaves, treedef = _tree_paths(like_tree)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        groups = self._expert_groups(manifest)
        self._require_records(names, by_name, cdir, groups=groups)
        like_by_name = dict(zip(names, leaves))
        for parent in groups:
            if parent in like_by_name:
                # sub-records validate (and fall back) against the parent
                for e in groups[parent]:
                    like_by_name[e["name"]] = like_by_name[parent]
        packs = manifest.get("packs")
        vals = {}
        pending: list = []
        for e, payload in self._iter_records(
                cdir, manifest, self._expand_entries(names, by_name, groups),
                report=rep):
            try:
                self._queue_record(e, payload, pending, vals,
                                   like_by_name[e["name"]], packs=packs)
            except CheckpointError as err:
                if rep is None:
                    raise
                self._quarantine(rep, e, manifest, str(err))
        pending = self._apply_decode_faults(pending, manifest, by_name, rep)
        if rep is not None and rep.quarantined:
            self._fallback_restore(rep, manifest, like_by_name, vals,
                                   pending)
        self._decode_pending(pending, vals)
        self._finish_report(report)
        tree = jax.tree_util.tree_unflatten(treedef,
                                            [vals.pop(n) for n in names])
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest

    # -- restore straight into serving handles ----------------------------

    @staticmethod
    def _spec_serves_mode(spec: dict, mode: str,
                          degraded: bool = False) -> bool:
        """Can a stored serving-layout record be adopted as-is under the
        requested weight-execution mode?

        ``degraded`` relaxes the answer for a quarantined record's
        fallback copy: any compressed handle kind executes the canonical
        contraction bit-identically, so a damaged fused bundle may adopt a
        prior step's stream record (and vice versa) — a capacity/latency
        downgrade, never a numeric one.  The main pass keeps the strict
        answer: asking for fused on an undamaged stream-layout checkpoint
        should re-layout for fused speed, not silently keep stream
        execution."""
        kind = spec.get("kind")
        if mode == "fused":
            return kind == "fused" or (
                kind == "stream"
                and (degraded
                     or spec.get("execution", "materialize")
                     == "materialize"))
        if mode == "stream":
            return kind == "stream" or (degraded and kind == "fused")
        return False

    def load_for_serving(self, like_params, *, mode: str = "fused",
                         step: Optional[int] = None, prefix: str = "",
                         min_bytes: int = rt_streaming.MIN_STREAM_BYTES,
                         shards: int = rt_streaming.STREAM_SHARDS,
                         policy: str = "strict", mesh=None,
                         expert_store=None):
        """Restore ONLY the weight records into a serving handle tree.

        ``like_params`` is the (dense) params structure — ShapeDtypeStructs
        are fine, nothing is allocated from it.  ``prefix`` namespaces the
        record names ("params" when the checkpoint was saved as
        ``{"params": ..., "opt": ...}``); optimizer records are never read.

        Records stored in a matching serving layout deserialize DIRECTLY
        into ``StreamedWeight`` / ``FusedWeight`` handles — disk -> HBM with
        no dense tensor on the host (``wire.transfer_stats()`` proves it).
        Everything else (plain v1/v2 records, or a layout mismatch) is
        decompressed on device and handed to ``assign_weight_modes``, which
        passes existing handles through untouched.

        ``policy="degraded"`` keeps serving through damage: a record that
        fails I/O, validation, or decode is quarantined and restored from
        the newest earlier step holding an intact copy — adopted as a
        handle when its layout serves ``mode`` (a damaged fused bundle
        degrades to the prior step's stream or dense record), decoded and
        re-assigned by the policy otherwise.  The rest of the tree restores
        batched exactly as before (the DecodePlan replans only the
        surviving buckets); logits stay bit-identical because every handle
        mode executes the same canonical contraction.  The
        :class:`RestoreReport` on ``last_restore_report`` enumerates each
        quarantined record's cause and fallback.

        ``mesh`` restores straight onto a serving mesh: adopted records'
        stream shards upload to their OWNING devices only (the per-shard
        pack bytes never fan out over h2d — ``collectives.stream_placer``),
        and the finished tree is placed per ``collectives.serving_pspecs``
        (stream shards on the "model" axis, dense math replicated).

        Checkpoints saved with ``expert_records=True`` hold MoE expert
        stacks as per-expert wire records: those restore straight into an
        :class:`~repro.runtime.experts.ExpertStore` (``expert_store``, or
        a fresh unbounded one — also stashed on ``last_expert_store``)
        WITHOUT inflating or uploading a single cold expert; the tree gets
        an ``ExpertRef`` handle per stack and routed experts decode
        on demand through the store's LRU cache (docs/MOE.md)."""
        if mode not in rt_streaming.WEIGHT_MODES:
            raise ValueError(f"unknown weight mode {mode!r}")
        from repro.runtime import collectives as rt_collectives
        stream_place = (None if mesh is None
                        else rt_collectives.stream_placer(mesh))
        cdir, manifest = self._step_dir(step)
        report = self._begin_report(policy, manifest)
        rep = report if policy == "degraded" else None
        names, leaves, treedef = _tree_paths(like_params)
        full = [f"{prefix}/{n}" if prefix else n for n in names]
        by_name = {e["name"]: e for e in manifest["leaves"]}
        groups = {p: es for p, es in self._expert_groups(manifest).items()
                  if p in set(full)}
        if groups and mesh is not None:
            raise CheckpointError(
                "expert-record checkpoints cannot restore onto a serving "
                "mesh yet: the expert store decodes host-side per step "
                "(see docs/MOE.md) — load with mesh=None")
        est = expert_store
        if est is None and groups:
            est = rt_experts.ExpertStore(codec=self.codec)
        self.last_expert_store = est if groups else None
        self._require_records(full, by_name, cdir, what="weight records",
                              groups=groups)
        like_by_name = dict(zip(full, leaves))
        for parent, es in groups.items():
            for e in es:
                like_by_name[e["name"]] = like_by_name[parent]
        vals = {}
        pending: list = []

        def serve_record(e, payload, like, man, pending, vals):
            """Adopt a matching serving-layout record as a handle, else
            queue it for the batched decode — shared by the main pass and
            the per-record step fallback so a recovered record takes
            exactly the path it would have taken undamaged."""
            name = e["name"]
            spec = e.get("handle")
            if spec is not None and spec.get("kind") == "expert":
                # per-expert record: the compressed bytes go STRAIGHT into
                # the expert store — no upload, no decode; cold experts
                # stay wire records until routing asks for them
                est.add_meta(spec["parent"],
                             n_layers=spec["n_layers"],
                             n_experts=spec["n_experts"],
                             expert_shape=spec["expert_shape"],
                             dtype=spec["dtype"])
                est.add_record(spec["parent"], spec["layer"],
                               spec["expert"], bytes(payload))
                return
            # a record arriving here while already quarantined is the
            # FALLBACK copy from an earlier step — adoption relaxes to any
            # bit-identical handle kind (see _spec_serves_mode)
            is_fallback = rep is not None and any(
                q.name == name for q in rep.quarantined)
            if spec and spec["kind"] != "dense" and e.get("stack") \
                    and mode != "dense" \
                    and self._spec_serves_mode(spec, mode,
                                               degraded=is_fallback):
                if spec["kind"] == "stream":
                    # flat records are L=1 stacks of plain 2-D leaves:
                    # layer_shape IS the leaf shape (no stack prefix)
                    leaf_shape = tuple(spec["layer_shape"]) \
                        if spec.get("flat") \
                        else (int(e["stack"]),) + tuple(spec["layer_shape"])
                else:
                    leaf_shape = (int(e["stack"]),
                                  int(spec["k"]), int(spec["n"]))
                self._check_leaf(name, leaf_shape, like,
                                 dtype=spec["dtype"])
                ct = self._record_ct(e, payload, packs=man.get("packs"),
                                     stream_place=stream_place)
                # adopt only when the stored stream layout matches the
                # width assign_weight_modes would pick for this record —
                # fused tile streams narrow per record when the tile-block
                # count doesn't divide; a mismatch joins the batched
                # decode + device re-layout below instead of silently
                # keeping the ckpt's sharding
                req_shards = (rt_streaming.fused_shards(
                    int(spec["k"]), int(spec["n"]), shards)
                    if spec["kind"] == "fused" else shards)
                # a fallback copy adopts at whatever width the older step
                # stored — any width executes bit-identically, and the
                # damaged record must not lose its handle to a re-layout
                if ct.shards == req_shards or is_fallback:
                    vals[name] = handle_from_spec(spec, ct)
                    return
                pending.append((name, like, handle_from_spec(spec, ct)))
                return
            self._queue_record(e, payload, pending, vals, like,
                               packs=man.get("packs"))

        for e, payload in self._iter_records(
                cdir, manifest, self._expand_entries(full, by_name, groups),
                report=rep):
            try:
                serve_record(e, payload, like_by_name[e["name"]], manifest,
                             pending, vals)
            except CheckpointError as err:
                if rep is None:
                    raise
                self._quarantine(rep, e, manifest, str(err))
        pending = self._apply_decode_faults(pending, manifest, by_name, rep)
        if rep is not None and rep.quarantined:
            self._fallback_restore(rep, manifest, like_by_name, vals,
                                   pending, process=serve_record)
        self._decode_pending(pending, vals)
        for parent in groups:
            m = est.meta(parent)
            self._check_leaf(
                parent,
                (m["n_layers"], m["n_experts"]) + tuple(m["expert_shape"]),
                like_by_name[parent], dtype=m["dtype"])
            miss = est.missing(parent)
            if miss:
                raise CheckpointError(
                    f"{parent}: expert record grid incomplete — missing "
                    f"{miss[:5]}" + ("…" if len(miss) > 5 else ""))
            vals[parent] = est.ref(parent)
        self._finish_report(report)
        tree = jax.tree_util.tree_unflatten(treedef,
                                            [vals.pop(n) for n in full])
        tree = rt_streaming.assign_weight_modes(
            tree, mode=mode, min_bytes=min_bytes, shards=shards,
            codec=self.codec)
        if mesh is not None:
            # records re-laid-out by the policy (and every replicated
            # upload) land on their final serving placement: stream shards
            # on the "model" axis, everything else replicated
            tree = rt_collectives.place_serving_tree(tree, mesh)
        return tree, manifest
