"""ENEC-compressed, fault-tolerant checkpointing.

Layout (one directory per step):
    <root>/step_000001230/
        manifest.json          tree structure, shapes, dtypes, ENEC stats
        t_<idx>.enec           one wire-format blob per tensor leaf
    <root>/LATEST              atomic pointer file (rename-committed)

Properties needed at 1000+ nodes:
  * atomicity — write to ``.tmp-`` dir, fsync, rename; LATEST updated last;
    a crash mid-save never corrupts the previous checkpoint;
  * async     — saves run on a background thread over host copies, training
    continues (wait() joins before the next save or at exit);
  * elastic   — load() reshards to ANY mesh via device_put with the target
    NamedShardings (topology can shrink/grow between runs);
  * ~1.35x fewer bytes to the storage system via ENEC (per-tensor searched
    params; raw escape keeps incompressible leaves at 1.0x, never worse);
  * keep-last-k retention + best-effort corruption detection on load.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as enec_api
from repro.core import wire as enec_wire

_ENEC_DTYPES = enec_api.SUPPORTED_FLOAT_DTYPES


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "name",
             getattr(k, "idx", k)))) for k in path) for path, _ in flat]
    return names, [l for _, l in flat], treedef


@dataclasses.dataclass
class CheckpointManager:
    root: Path
    keep_last: int = 3
    compress: bool = True
    _thread: Optional[threading.Thread] = None

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        self.wait()
        names, leaves, _ = _tree_paths(tree)
        # compression runs device-resident BEFORE any host transfer: only
        # compressed streams (and the raw non-float leaves) ever cross to the
        # host, and repeated (shape, dtype) float leaves share one stacked
        # encode dispatch (docs/PIPELINE.md)
        payload = self._prepare(leaves)
        if blocking:
            self._save_host(step, names, payload)
            return
        self._thread = threading.Thread(
            target=self._save_host, args=(step, names, payload), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prepare(self, leaves):
        """Per-leaf ("ct", CompressedTensor) or ("np", host array) payload."""
        payload: list = [None] * len(leaves)
        float_slots, other_slots = [], []
        for i, leaf in enumerate(leaves):
            dt = getattr(leaf, "dtype", None)   # dtype check without a copy
            if (self.compress and dt is not None
                    and jnp.dtype(dt) in _ENEC_DTYPES):
                float_slots.append(i)
            else:
                other_slots.append(i)
        if other_slots:   # one batched transfer for all uncompressed leaves
            hosts = jax.device_get([leaves[i] for i in other_slots])
            for i, h in zip(other_slots, hosts):
                payload[i] = ("np", np.asarray(h))
        # every float leaf rides the batched pipeline as its own L=1 stack:
        # per-leaf searched params (ratio parity with the seed — unrelated
        # same-shape tensors like weights vs Adam moments must NOT share
        # params), no jnp.stack duplicate on device, while statistics, the
        # never-worse wire check, and encode dispatches all stay batched —
        # leaves whose (n, m, L) coincide share one concatenated dispatch
        # via the encoder's dynamic-b bucketing.
        cts = enec_api.compress_stacked_many(
            [jnp.asarray(leaves[i])[None] for i in float_slots])
        for i, ct in zip(float_slots, cts):
            if ct is None:
                # const / incompressible / empty: per-leaf escape path.
                # compress_array repeats the stats pass (and, for the rare
                # incompressible leaf, the encode) — accepted so the stacked
                # API keeps its simple Optional contract; const leaves
                # short-circuit before encoding.
                payload[i] = ("ct",
                              enec_api.compress_array(jnp.asarray(leaves[i])))
            else:
                payload[i] = ("ct", enec_api.slice_stacked(ct, 0))
        return payload

    def _save_host(self, step: int, names, payload) -> None:
        t0 = time.time()
        final = self.root / f"step_{step:012d}"
        tmp = self.root / f".tmp-step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": [], "format": "enec-v1"}
        raw_total = comp_total = 0
        for i, (name, (tag, obj)) in enumerate(zip(names, payload)):
            blob_path = tmp / f"t_{i:05d}.enec"
            if tag == "ct":
                ct = obj
                entry = {"name": name, "index": i, "shape": list(ct.shape),
                         "dtype": ct.dtype_str}
                blob = enec_wire.to_wire(ct)   # moves compressed bytes only
                entry["mode"] = ct.mode
                if ct.params is not None:
                    entry["params"] = list(ct.params.astuple())
                raw_total += ct.nbytes_raw()
            else:
                leaf = obj
                entry = {"name": name, "index": i, "shape": list(leaf.shape),
                         "dtype": str(leaf.dtype)}
                blob = b"RAW0" + leaf.tobytes()
                entry["mode"] = "npraw"
                raw_total += leaf.nbytes
            comp_total += len(blob)
            entry["bytes"] = len(blob)
            with open(blob_path, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(entry)
        manifest["raw_bytes"] = raw_total
        manifest["compressed_bytes"] = comp_total
        manifest["ratio"] = raw_total / max(comp_total, 1)
        manifest["save_s"] = round(time.time() - t0, 3)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic commit
        latest_tmp = self.root / ".LATEST.tmp"
        latest_tmp.write_text(final.name)
        latest_tmp.rename(self.root / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.root.glob("step_*") if p.is_dir())
        for old in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(old, ignore_errors=True)

    # -- load ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if not ptr.exists():
            return None
        return int(ptr.read_text().strip().split("_")[-1])

    def load(self, like_tree, step: Optional[int] = None,
             shardings=None):
        """Restore into the structure of ``like_tree``; reshard to
        ``shardings`` (elastic: any mesh) or keep host arrays."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.root}")
        cdir = self.root / f"step_{step:012d}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        names, leaves, treedef = _tree_paths(like_tree)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        out = []
        for name, like in zip(names, leaves):
            e = by_name[name]
            blob = (cdir / f"t_{e['index']:05d}.enec").read_bytes()
            if e["mode"] == "npraw":
                assert blob[:4] == b"RAW0", f"corrupt blob for {name}"
                arr = np.frombuffer(blob[4:], dtype=np.dtype(e["dtype"]))
                arr = arr.reshape(e["shape"])
                val = jax.numpy.asarray(arr)
            else:
                ct = enec_wire.from_wire(blob)
                val = enec_api.decompress_array(ct)
            assert tuple(val.shape) == tuple(like.shape), \
                f"{name}: ckpt {val.shape} vs model {like.shape}"
            out.append(val.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest
