"""ENEC-compressed, fault-tolerant checkpointing (enec-v2 container).

Layout (one directory per step):
    <root>/step_000001230/
        manifest.json          tree structure + per-record (pack, offset,
                               length) index, shapes, dtypes, ENEC stats
        pack-00000.bin ...     per-shard pack files: concatenated framed
                               wire records (length + CRC32 per record)
    <root>/LATEST              atomic pointer file (rename-committed)

Properties needed at 1000+ nodes:
  * atomicity — write to ``.tmp-`` dir, fsync every pack AND the manifest
    AND the directory entries, rename, fsync the parent; LATEST updated
    last; a crash mid-save never corrupts the previous checkpoint and never
    commits a step whose manifest is missing or truncated;
  * async     — saves run on a background thread over host copies, training
    continues; a failed async save re-raises from ``wait()`` and from the
    next ``save()`` instead of vanishing in a daemon thread;
  * parallel  — records are serialized by a thread pool (``writers``) and
    streamed round-robin to the per-shard pack files (peak host memory
    never holds the whole checkpoint);
  * verified  — every record is framed (explicit length + CRC32), so
    ``load()`` rejects truncated or bit-flipped records with a clear error
    instead of silently misdecoding;
  * partial   — records are indexed by name, so serving restores ONLY the
    weight records (optimizer state is never read, let alone inflated);
  * elastic   — load() reshards to ANY mesh via device_put with the target
    NamedShardings (topology can shrink/grow between runs);
  * ~1.35x fewer bytes to the storage system via ENEC (per-tensor searched
    params; raw escape keeps incompressible leaves at 1.0x, never worse);
  * keep-last-k retention + stale-tmp-dir GC (crashed saves leak nothing).

``serving_layout="stream"|"fused"`` additionally stores every
policy-eligible weight in its exact *serving* stream layout (the same
bundles ``runtime.streaming.assign_weight_modes`` would build), which is
what lets :meth:`CheckpointManager.load_for_serving` deserialize records
straight into ``StreamedWeight`` / ``FusedWeight`` handles — compressed
bytes flow disk -> HBM and the dense tensor never exists on the host.
``load()`` still restores the bit-exact dense training tree from the same
records (docs/CHECKPOINT.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire as enec_wire
from repro.core.api import SUPPORTED_FLOAT_DTYPES, slice_stacked
from repro.core.codec_api import Codec, current_codec
from repro.runtime import streaming as rt_streaming
from repro.runtime.weights import (DenseWeight, finish_materialize,
                                   handle_from_spec, handle_spec, is_handle)

_ENEC_DTYPES = SUPPORTED_FLOAT_DTYPES

MANIFEST_FORMAT = "enec-v2"

# tree roots that hold optimizer state under the {"params", "opt"} saving
# convention: their leaves mirror the weight paths (so the serving-layout
# eligibility heuristic would match them) but can never be served — they
# stay plain records instead of paying the tile/moveaxis re-layout
_NON_SERVING_ROOTS = frozenset({"opt", "opt_state", "optimizer"})


class CheckpointError(RuntimeError):
    """A checkpoint could not be saved or restored."""


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_handle)
    names = ["/".join(str(getattr(k, "key", getattr(k, "name",
             getattr(k, "idx", k)))) for k in path) for path, _ in flat]
    return names, [l for _, l in flat], treedef


def _fsync_path(path) -> None:
    """fsync a file or directory by path (directories need it too: the
    rename-commit is only durable once the parent's entries are)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_nbytes(shape, dtype_str: str) -> int:
    return int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype_str).itemsize


@dataclasses.dataclass
class CheckpointManager:
    root: Path
    keep_last: int = 3
    compress: bool = True
    writers: int = 4                       # pack shards == writer threads
    serving_layout: Optional[str] = None   # None | "stream" | "fused"
    serving_min_bytes: int = rt_streaming.MIN_STREAM_BYTES
    serving_shards: int = 1
    codec: Optional[Codec] = None          # default: ambient codec at init
    _thread: Optional[threading.Thread] = None
    _exc: Optional[BaseException] = None

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.last_decode_plan = None   # DecodePlan of the latest load
        if self.codec is None:
            # captured once — every save/load of this manager encodes and
            # decodes through ONE codec instance (caches, counters)
            self.codec = current_codec()
        if self.serving_layout is not None and \
                self.serving_layout not in ("stream", "fused"):
            raise ValueError(
                f"serving_layout must be None, 'stream' or 'fused', "
                f"got {self.serving_layout!r}")

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        self.wait()    # also re-raises a previous async failure
        names, leaves, _ = _tree_paths(tree)
        # compression runs device-resident BEFORE any host transfer: only
        # compressed streams (and the raw non-float leaves) ever cross to the
        # host, and repeated (shape, dtype) float leaves share one stacked
        # encode dispatch (docs/PIPELINE.md)
        payload, dense_specs = self._prepare(names, leaves)
        if blocking:
            self._save_host(step, names, payload, dense_specs)
            return
        self._thread = threading.Thread(
            target=self._save_guarded, args=(step, names, payload,
                                             dense_specs),
            daemon=True)
        self._thread.start()

    def _save_guarded(self, step, names, payload, dense_specs):
        try:
            self._save_host(step, names, payload, dense_specs)
        except BaseException as e:  # noqa: BLE001 — surfaced via wait()
            self._exc = e

    def wait(self):
        """Join the in-flight async save.  If it failed, re-raise its
        exception here (and therefore also from the next ``save()``, which
        waits first) — an async save error must never report success."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise CheckpointError(
                f"async checkpoint save failed: {exc}") from exc

    def _prepare(self, names, leaves):
        """Per-leaf record plan:
             ("np",  host_array)            raw host bytes (non-float)
             ("ct",  CompressedTensor)      plain enec/raw/const record
             ("hct", ct, spec, raw_bytes)   stacked serving-layout record
        """
        payload: list = [None] * len(leaves)
        float_slots, other_slots, serve_jobs = [], [], []
        dense_specs: dict = {}   # slot -> handle spec for fallback leaves
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            if is_handle(leaf):
                if isinstance(leaf, DenseWeight):
                    leaf = leaf.w       # stored dense; re-wrapped on restore
                    dense_specs[i] = {"kind": "dense"}
                    leaves[i] = leaf
                else:
                    spec = handle_spec(leaf)
                    raw = _leaf_nbytes(
                        (leaf.ct.streams.mask.shape[0],)
                        + tuple(spec.get("layer_shape")
                                or (spec["k"], spec["n"])), spec["dtype"])
                    payload[i] = ("hct", leaf.ct, spec, raw)
                    continue
            dt = getattr(leaf, "dtype", None)   # dtype check without a copy
            if not (self.compress and dt is not None
                    and jnp.dtype(dt) in _ENEC_DTYPES):
                other_slots.append(i)
                continue
            if self.serving_layout is not None and i not in dense_specs \
                    and name.split("/", 1)[0] not in _NON_SERVING_ROOTS:
                # (slots unwrapped from a DenseWeight stay dense records —
                # the policy that built the tree already decided against
                # compressing them)
                job = rt_streaming.serving_job(name, jnp.asarray(leaf),
                                               self.serving_layout,
                                               self.serving_min_bytes)
                if job is not None:
                    job["slot"] = i
                    serve_jobs.append(job)
                    continue
            float_slots.append(i)
        if other_slots:   # one batched transfer for all uncompressed leaves
            hosts = jax.device_get([leaves[i] for i in other_slots])
            for i, h in zip(other_slots, hosts):
                payload[i] = ("np", np.asarray(h))

        # serving-layout leaves: compress the exact stream bundles the
        # weight-execution policy would build (shared serving_job /
        # build_serving_handle code path), so load_for_serving can
        # deserialize them straight into handles
        if serve_jobs:
            shards = 1 if self.serving_layout == "fused" \
                else self.serving_shards
            cts = self.codec.compress_stacked_many(
                [j["arr"] for j in serve_jobs], shards=shards)
            for job, ct in zip(serve_jobs, cts):
                i = job["slot"]
                handle = rt_streaming.build_serving_handle(job, ct)
                if is_handle(handle) and not isinstance(handle, DenseWeight):
                    spec = handle_spec(handle)
                    payload[i] = ("hct", handle.ct, spec,
                                  job["leaf"].size * job["leaf"].dtype.itemsize)
                else:
                    # const / incompressible escape: plain dense record,
                    # re-wrapped as DenseWeight by the restore policy
                    if job["matmul_pos"]:
                        dense_specs[i] = {"kind": "dense"}
                    float_slots.append(i)

        # every remaining float leaf rides the batched pipeline as its own
        # L=1 stack: per-leaf searched params (ratio parity with the seed —
        # unrelated same-shape tensors like weights vs Adam moments must NOT
        # share params), no jnp.stack duplicate on device, while statistics,
        # the never-worse wire check, and encode dispatches all stay batched
        # — leaves whose (n, m, L) coincide share one concatenated dispatch
        # via the encoder's dynamic-b bucketing.
        float_slots.sort()
        cts = self.codec.compress_stacked_many(
            [jnp.asarray(leaves[i])[None] for i in float_slots])
        for i, ct in zip(float_slots, cts):
            if ct is None:
                # const / incompressible / empty: per-leaf escape path.
                payload[i] = ("ct",
                              self.codec.compress_array(
                                  jnp.asarray(leaves[i])))
            else:
                payload[i] = ("ct", slice_stacked(ct, 0))
        return payload, dense_specs

    # -- record building / pack writing ----------------------------------

    def _build_record(self, index, name, item, dense_specs):
        """(manifest entry sans pack/offset, framed blob bytes)."""
        tag = item[0]
        if tag == "np":
            leaf = item[1]
            entry = {"name": name, "index": index, "shape": list(leaf.shape),
                     "dtype": str(leaf.dtype), "mode": "npraw"}
            blob = b"RAW0" + leaf.tobytes()
            raw = leaf.nbytes
        elif tag == "ct":
            ct = item[1]
            entry = {"name": name, "index": index, "shape": list(ct.shape),
                     "dtype": ct.dtype_str, "mode": ct.mode}
            if ct.params is not None:
                entry["params"] = list(ct.params.astuple())
            blob = enec_wire.to_wire(ct)   # moves compressed bytes only
            raw = ct.nbytes_raw()
        else:   # "hct": stacked serving-layout record
            _, ct, spec, raw = item
            entry = {"name": name, "index": index,
                     "shape": list(ct.shape), "dtype": ct.dtype_str,
                     "mode": ct.mode, "handle": spec,
                     "stack": int(ct.streams.mask.shape[0]),
                     "params": list(ct.params.astuple())}
            blob = enec_wire.to_wire(ct, stacked=True)
        spec = dense_specs.get(index)
        if spec is not None and "handle" not in entry:
            entry["handle"] = spec
        entry["bytes"] = len(blob)
        return entry, enec_wire.frame(blob), raw

    def _save_host(self, step: int, names, payload, dense_specs) -> None:
        t0 = time.time()
        final = self.root / f"step_{step:012d}"
        tmp = self.root / f".tmp-step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        n_packs = max(1, min(self.writers, len(payload) or 1))
        manifest = {"format": MANIFEST_FORMAT, "step": step,
                    "packs": [f"pack-{i:05d}.bin" for i in range(n_packs)],
                    "leaves": []}
        if self.serving_layout is not None:
            manifest["serving_layout"] = {
                "mode": self.serving_layout,
                "min_bytes": self.serving_min_bytes,
                "shards": (1 if self.serving_layout == "fused"
                           else self.serving_shards)}
        raw_total = comp_total = 0
        offsets = [0] * n_packs
        # records are serialized by the thread pool and STREAMED round-robin
        # to the pack shards; submission is bounded (a sliding window of
        # in-flight builds), so peak host memory holds a few frames — never
        # the whole checkpoint — even when the filesystem writes slowly
        files = [open(tmp / name, "wb") for name in manifest["packs"]]
        workers = max(self.writers, 1)
        pending: deque = deque()

        def drain_one():
            nonlocal raw_total, comp_total
            i, fut = pending.popleft()
            entry, framed, raw = fut.result()
            pack = i % n_packs
            entry["pack"] = pack
            entry["offset"] = offsets[pack]
            entry["length"] = len(framed)
            offsets[pack] += len(framed)
            files[pack].write(framed)
            raw_total += raw
            comp_total += entry["bytes"]
            manifest["leaves"].append(entry)

        try:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                for i, (n, it) in enumerate(zip(names, payload)):
                    pending.append((i, ex.submit(
                        self._build_record, i, n, it, dense_specs)))
                    if len(pending) >= 2 * workers:
                        drain_one()
                while pending:
                    drain_one()
            for f in files:
                f.flush()
                os.fsync(f.fileno())
        finally:
            for f in files:
                f.close()

        manifest["raw_bytes"] = raw_total
        manifest["compressed_bytes"] = comp_total
        manifest["ratio"] = raw_total / max(comp_total, 1)
        manifest["save_s"] = round(time.time() - t0, 3)
        # fsync the manifest AND the tmp directory entries BEFORE the
        # rename: otherwise a crash can commit a step directory whose
        # manifest is missing or truncated
        with open(tmp / "manifest.json", "w") as f:
            f.write(json.dumps(manifest, indent=1))
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic commit
        _fsync_path(self.root)                  # …made durable
        latest_tmp = self.root / ".LATEST.tmp"
        with open(latest_tmp, "w") as f:
            f.write(final.name)
            f.flush()
            os.fsync(f.fileno())
        latest_tmp.rename(self.root / "LATEST")
        _fsync_path(self.root)
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.root.glob("step_*") if p.is_dir())
        for old in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(old, ignore_errors=True)
        # stale tmp dirs from crashed saves would otherwise leak forever
        # (our own tmp has already been renamed away by the time GC runs)
        for stale in self.root.glob(".tmp-step_*"):
            shutil.rmtree(stale, ignore_errors=True)

    # -- load ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if not ptr.exists():
            return None
        return int(ptr.read_text().strip().split("_")[-1])

    def manifest(self, step: Optional[int] = None) -> dict:
        """The manifest of ``step`` (default: latest) without reading any
        record bytes — launchers use it to sniff the name prefix and the
        stored serving layout."""
        return self._step_dir(step)[1]

    def _step_dir(self, step: Optional[int]) -> tuple:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.root}")
        cdir = self.root / f"step_{step:012d}"
        manifest_path = cdir / "manifest.json"
        if not manifest_path.exists():
            raise CheckpointError(f"{cdir} has no manifest.json")
        try:
            return cdir, json.loads(manifest_path.read_text())
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CheckpointError(
                f"{manifest_path} is corrupt: {e}") from e

    @staticmethod
    def _require_records(names, by_name, cdir, what="records"):
        missing = [n for n in names if n not in by_name]
        if missing:
            raise CheckpointError(
                f"checkpoint {cdir.name} lacks {what} for {missing[:5]}"
                + ("…" if len(missing) > 5 else ""))

    @staticmethod
    def _check_leaf(name, shape, like, dtype=None):
        if tuple(shape) != tuple(like.shape):
            raise CheckpointError(f"{name}: ckpt {tuple(shape)} vs model "
                                  f"{tuple(like.shape)}")
        if dtype is not None and dtype != str(jnp.dtype(like.dtype)):
            raise CheckpointError(f"{name}: ckpt dtype {dtype} vs model "
                                  f"{jnp.dtype(like.dtype)}")

    def _iter_records(self, cdir, manifest, entries):
        """Yield ``(entry, payload_bytes)`` for ``entries``, validated
        (frame length + CRC for v2 packs; declared blob size for v1
        per-leaf files), one record at a time in pack/offset order — the
        caller stages each record to device as it goes, so peak host
        memory holds one record's compressed bytes, never the whole
        checkpoint (decoding is deferred into one batched pass).  Only the
        requested records are read (partial load never touches the rest of
        the pack)."""
        fmt = manifest.get("format", "enec-v1")
        if fmt == "enec-v1":
            for e in entries:
                path = cdir / f"t_{e['index']:05d}.enec"
                blob = path.read_bytes()
                if "bytes" in e and len(blob) != e["bytes"]:
                    raise CheckpointError(
                        f"{path.name}: {len(blob)} bytes on disk, manifest "
                        f"declares {e['bytes']} — truncated or corrupt")
                yield e, blob
            return
        if fmt != MANIFEST_FORMAT:
            raise CheckpointError(f"unknown checkpoint format {fmt!r}")
        by_pack: dict = {}
        for e in entries:
            by_pack.setdefault(e["pack"], []).append(e)
        for pack, es in sorted(by_pack.items()):
            path = cdir / manifest["packs"][pack]
            with open(path, "rb") as f:
                for e in sorted(es, key=lambda e: e["offset"]):
                    f.seek(e["offset"])
                    buf = f.read(e["length"])
                    try:
                        payload, end = enec_wire.read_frame(buf)
                    except enec_wire.WireError as err:
                        raise CheckpointError(
                            f"{path.name} @ {e['offset']} ({e['name']}): "
                            f"{err}") from err
                    if end != len(buf):
                        raise CheckpointError(
                            f"{path.name} @ {e['offset']} ({e['name']}): "
                            f"frame length {end} != indexed {len(buf)}")
                    yield e, payload

    def _decode_npraw(self, e, blob):
        blob = bytes(blob)
        if blob[:4] != b"RAW0":
            raise CheckpointError(f"corrupt raw blob for {e['name']}")
        arr = np.frombuffer(blob[4:], dtype=np.dtype(e["dtype"]))
        if arr.size != int(np.prod(e["shape"], dtype=np.int64)):
            raise CheckpointError(
                f"{e['name']}: raw payload holds {arr.size} elements, "
                f"manifest declares shape {e['shape']}")
        # counted on this manager's codec like every other record upload
        return enec_wire.h2d(arr.reshape(e["shape"]), self.codec)

    def _record_ct(self, e, blob):
        """Deserialize one compressed record's payload — the compressed
        streams move to device here (counted on this manager's codec);
        nothing is decoded yet."""
        try:
            return enec_wire.from_wire(blob, codec=self.codec)
        except enec_wire.WireError as err:
            raise CheckpointError(f"{e['name']}: {err}") from err

    def _queue_record(self, e, blob, pending, vals, like):
        """One record -> either an eagerly decoded host value (``npraw``)
        or a device-resident compressed object queued on ``pending`` for
        the batched decode pass (serving-layout records become handles;
        plain enec/raw/const records stay CompressedTensors)."""
        name = e["name"]
        if e["mode"] == "npraw":
            val = self._decode_npraw(e, blob)
            self._check_leaf(name, val.shape, like)
            vals[name] = val.astype(like.dtype)
            return
        ct = self._record_ct(e, blob)
        obj = (handle_from_spec(e["handle"], ct)
               if "handle" in e and e.get("stack") else ct)
        pending.append((name, like, obj))

    def _decode_pending(self, pending, vals):
        """Decode every queued compressed record in ONE batched pipeline
        pass: records sharing a decoder bucket — serving-layout handle
        records and plain enec records alike — share a concatenated decode
        dispatch (``core.api.decompress_stacked_many``), so restoring a
        model costs O(#buckets) decode dispatches instead of one per
        record.  The decode runs where the streams live (device); outputs
        are bit-identical to the retired per-record path.  The executed
        :class:`repro.core.DecodePlan` is kept on ``last_decode_plan`` so
        callers (benches, CI) can assert the restore cost
        ``len(plan.buckets)`` dispatches."""
        plan = self.codec.plan_decode(
            [obj.ct if is_handle(obj) else obj for _, _, obj in pending])
        decs = self.codec.execute(plan)
        # keep only the inspectable summary: the execution-state fields
        # hold the full compressed streams on device and would pin them
        # until the next load
        self.last_decode_plan = dataclasses.replace(
            plan, _treedef=None, _groups=[], _passthrough={}, _leaves=[])
        for (name, like, obj), dec in zip(pending, decs):
            val = finish_materialize(obj, dec) if is_handle(obj) else dec
            self._check_leaf(name, val.shape, like)
            vals[name] = val.astype(like.dtype)

    def load(self, like_tree, step: Optional[int] = None,
             shardings=None):
        """Restore into the structure of ``like_tree``; reshard to
        ``shardings`` (elastic: any mesh) or keep host arrays."""
        cdir, manifest = self._step_dir(step)
        names, leaves, treedef = _tree_paths(like_tree)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        self._require_records(names, by_name, cdir)
        like_by_name = dict(zip(names, leaves))
        vals = {}
        pending: list = []
        for e, payload in self._iter_records(cdir, manifest,
                                             [by_name[n] for n in names]):
            self._queue_record(e, payload, pending, vals,
                               like_by_name[e["name"]])
        self._decode_pending(pending, vals)
        tree = jax.tree_util.tree_unflatten(treedef,
                                            [vals.pop(n) for n in names])
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest

    # -- restore straight into serving handles ----------------------------

    @staticmethod
    def _spec_serves_mode(spec: dict, mode: str) -> bool:
        """Can a stored serving-layout record be adopted as-is under the
        requested weight-execution mode?"""
        kind = spec.get("kind")
        if mode == "fused":
            return kind == "fused" or (
                kind == "stream"
                and spec.get("execution", "materialize") == "materialize")
        if mode == "stream":
            return kind == "stream"
        return False

    def load_for_serving(self, like_params, *, mode: str = "fused",
                         step: Optional[int] = None, prefix: str = "",
                         min_bytes: int = rt_streaming.MIN_STREAM_BYTES,
                         shards: int = rt_streaming.STREAM_SHARDS):
        """Restore ONLY the weight records into a serving handle tree.

        ``like_params`` is the (dense) params structure — ShapeDtypeStructs
        are fine, nothing is allocated from it.  ``prefix`` namespaces the
        record names ("params" when the checkpoint was saved as
        ``{"params": ..., "opt": ...}``); optimizer records are never read.

        Records stored in a matching serving layout deserialize DIRECTLY
        into ``StreamedWeight`` / ``FusedWeight`` handles — disk -> HBM with
        no dense tensor on the host (``wire.transfer_stats()`` proves it).
        Everything else (plain v1/v2 records, or a layout mismatch) is
        decompressed on device and handed to ``assign_weight_modes``, which
        passes existing handles through untouched.
        """
        if mode not in rt_streaming.WEIGHT_MODES:
            raise ValueError(f"unknown weight mode {mode!r}")
        cdir, manifest = self._step_dir(step)
        names, leaves, treedef = _tree_paths(like_params)
        full = [f"{prefix}/{n}" if prefix else n for n in names]
        by_name = {e["name"]: e for e in manifest["leaves"]}
        self._require_records(full, by_name, cdir, what="weight records")
        like_by_name = dict(zip(full, leaves))
        vals = {}
        pending: list = []
        for e, payload in self._iter_records(cdir, manifest,
                                             [by_name[n] for n in full]):
            name, like = e["name"], like_by_name[e["name"]]
            spec = e.get("handle")
            if spec and spec["kind"] != "dense" and e.get("stack") \
                    and mode != "dense" and self._spec_serves_mode(spec, mode):
                leaf_shape = (int(e["stack"]),) + (
                    tuple(spec["layer_shape"]) if spec["kind"] == "stream"
                    else (int(spec["k"]), int(spec["n"])))
                self._check_leaf(name, leaf_shape, like, dtype=spec["dtype"])
                ct = self._record_ct(e, payload)
                # adopt only when the stored stream layout matches the
                # requested TP width (fused mode forces shards=1) — a
                # mismatch joins the batched decode + device re-layout
                # below instead of silently keeping the ckpt's sharding
                req_shards = 1 if mode == "fused" else shards
                if ct.shards == req_shards:
                    vals[name] = handle_from_spec(spec, ct)
                    continue
                pending.append((name, like, handle_from_spec(spec, ct)))
                continue
            self._queue_record(e, payload, pending, vals, like)
        self._decode_pending(pending, vals)
        tree = jax.tree_util.tree_unflatten(treedef,
                                            [vals.pop(n) for n in full])
        tree = rt_streaming.assign_weight_modes(
            tree, mode=mode, min_bytes=min_bytes, shards=shards,
            codec=self.codec)
        return tree, manifest
