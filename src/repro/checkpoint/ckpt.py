"""ENEC-compressed, fault-tolerant checkpointing.

Layout (one directory per step):
    <root>/step_000001230/
        manifest.json          tree structure, shapes, dtypes, ENEC stats
        t_<idx>.enec           one wire-format blob per tensor leaf
    <root>/LATEST              atomic pointer file (rename-committed)

Properties needed at 1000+ nodes:
  * atomicity — write to ``.tmp-`` dir, fsync, rename; LATEST updated last;
    a crash mid-save never corrupts the previous checkpoint;
  * async     — saves run on a background thread over host copies, training
    continues (wait() joins before the next save or at exit);
  * elastic   — load() reshards to ANY mesh via device_put with the target
    NamedShardings (topology can shrink/grow between runs);
  * ~1.35x fewer bytes to the storage system via ENEC (per-tensor searched
    params; raw escape keeps incompressible leaves at 1.0x, never worse);
  * keep-last-k retention + best-effort corruption detection on load.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.core import api as enec_api
from repro.core import wire as enec_wire


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "name",
             getattr(k, "idx", k)))) for k in path) for path, _ in flat]
    return names, [l for _, l in flat], treedef


@dataclasses.dataclass
class CheckpointManager:
    root: Path
    keep_last: int = 3
    compress: bool = True
    _thread: Optional[threading.Thread] = None

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = False) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        if blocking:
            self._save_host(step, host_tree)
            return
        self._thread = threading.Thread(
            target=self._save_host, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_host(self, step: int, host_tree) -> None:
        t0 = time.time()
        names, leaves, treedef = _tree_paths(host_tree)
        final = self.root / f"step_{step:012d}"
        tmp = self.root / f".tmp-step_{step:012d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": [], "format": "enec-v1"}
        raw_total = comp_total = 0
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            leaf = np.asarray(leaf)
            entry = {"name": name, "index": i, "shape": list(leaf.shape),
                     "dtype": str(leaf.dtype)}
            blob_path = tmp / f"t_{i:05d}.enec"
            is_float = (leaf.dtype in (np.float32, np.float16)
                        or str(leaf.dtype) == "bfloat16")
            if self.compress and is_float:
                ct = enec_api.compress_array(jax.numpy.asarray(leaf))
                blob = enec_wire.to_wire(ct)
                entry["mode"] = ct.mode
                if ct.params is not None:
                    entry["params"] = list(ct.params.astuple())
            else:
                blob = b"RAW0" + leaf.tobytes()
                entry["mode"] = "npraw"
            raw_total += leaf.nbytes
            comp_total += len(blob)
            entry["bytes"] = len(blob)
            with open(blob_path, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(entry)
        manifest["raw_bytes"] = raw_total
        manifest["compressed_bytes"] = comp_total
        manifest["ratio"] = raw_total / max(comp_total, 1)
        manifest["save_s"] = round(time.time() - t0, 3)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic commit
        latest_tmp = self.root / ".LATEST.tmp"
        latest_tmp.write_text(final.name)
        latest_tmp.rename(self.root / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.root.glob("step_*") if p.is_dir())
        for old in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(old, ignore_errors=True)

    # -- load ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if not ptr.exists():
            return None
        return int(ptr.read_text().strip().split("_")[-1])

    def load(self, like_tree, step: Optional[int] = None,
             shardings=None):
        """Restore into the structure of ``like_tree``; reshard to
        ``shardings`` (elastic: any mesh) or keep host arrays."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.root}")
        cdir = self.root / f"step_{step:012d}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        names, leaves, treedef = _tree_paths(like_tree)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        out = []
        for name, like in zip(names, leaves):
            e = by_name[name]
            blob = (cdir / f"t_{e['index']:05d}.enec").read_bytes()
            if e["mode"] == "npraw":
                assert blob[:4] == b"RAW0", f"corrupt blob for {name}"
                arr = np.frombuffer(blob[4:], dtype=np.dtype(e["dtype"]))
                arr = arr.reshape(e["shape"])
                val = jax.numpy.asarray(arr)
            else:
                ct = enec_wire.from_wire(blob)
                val = enec_api.decompress_array(ct)
            assert tuple(val.shape) == tuple(like.shape), \
                f"{name}: ckpt {val.shape} vs model {like.shape}"
            out.append(val.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest
