"""Subpackage."""
