"""Retry/backoff-with-jitter policy for checkpoint I/O.

One :class:`RetryPolicy` instance rides on each ``CheckpointManager``: the
writer pool's pack writes and every pack/manifest read funnel through
``call()``, so a transient filesystem error (or an injected one —
runtime/faults.py raises ``OSError`` subclasses on purpose) is absorbed by
exponential backoff instead of killing the save/restore.  The policy is
deterministic: jitter draws from a ``random.Random(seed)`` owned by the
instance, and the attempt counters (``stats()``) are exact — restore code
surfaces them next to the codec cache stats (``RestoreReport.retry``,
``launch/serve.py``) so "the retry layer saved this restore" is observable,
not folklore.

Only ``OSError``-class failures retry by default.  Validation failures
(frame CRC, WireError) are NOT retried — re-reading deterministic corrupt
bytes cannot heal them; they go to quarantine/fallback instead
(docs/RELIABILITY.md).
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Tuple, Type


@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff with deterministic jitter.

    ``max_attempts`` counts the first try: the default absorbs up to three
    consecutive transient failures.  ``base_delay_s`` doubles per retry up
    to ``max_delay_s``; each sleep is scaled by ``1 + jitter * U[0, 1)``
    drawn from the instance's seeded RNG (desynchronizes a fleet retrying
    against one storage system without losing reproducibility).

    ``max_elapsed_s`` bounds the TOTAL time a call may spend inside
    ``call()`` (tries + backoff sleeps): once the budget would be exceeded
    by the next backoff, the call gives up immediately and re-raises —
    this is how serving-engine retries respect per-request deadlines
    (runtime/engine.py passes the request's remaining budget per call).
    ``sleep``/``clock`` are injectable so tests never real-sleep through a
    backoff schedule and can drive the elapsed budget from a fake clock.
    """
    max_attempts: int = 4
    base_delay_s: float = 0.002
    max_delay_s: float = 0.25
    jitter: float = 0.5
    seed: int = 0
    retry_on: Tuple[Type[BaseException], ...] = (OSError,)
    max_elapsed_s: float = None   # None = unbounded (attempt-bounded only)
    sleep: Callable = time.sleep
    clock: Callable = time.monotonic

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        self._rng = random.Random(self.seed)
        self._stats = {"calls": 0, "attempts": 0, "retries": 0,
                       "gave_up": 0}

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retrying after failed attempt ``attempt``
        (1-based): exponential in the attempt number, capped, jittered."""
        base = min(self.base_delay_s * (2 ** (attempt - 1)),
                   self.max_delay_s)
        return base * (1.0 + self.jitter * self._rng.random())

    def call(self, fn: Callable, *, describe: str = "io",
             max_elapsed_s: float = None):
        """Run ``fn()`` under this policy.  Exceptions in ``retry_on``
        retry up to ``max_attempts`` total tries; the final failure (and
        any non-retryable exception) propagates to the caller, which
        decides between abort and quarantine.

        ``max_elapsed_s`` overrides the instance budget for this call
        (the tighter of the two applies): when the elapsed time plus the
        next backoff sleep would exceed it, the call gives up NOW rather
        than sleeping through a deadline the caller already missed."""
        self._stats["calls"] += 1
        budgets = [b for b in (self.max_elapsed_s, max_elapsed_s)
                   if b is not None]
        budget = min(budgets) if budgets else None
        t0 = self.clock() if budget is not None else None
        attempt = 0
        while True:
            attempt += 1
            self._stats["attempts"] += 1
            try:
                return fn()
            except self.retry_on:
                if attempt >= self.max_attempts:
                    self._stats["gave_up"] += 1
                    raise
                delay = self.backoff_s(attempt)
                if budget is not None and \
                        (self.clock() - t0) + delay > budget:
                    self._stats["gave_up"] += 1
                    raise
                self._stats["retries"] += 1
                self.sleep(delay)

    def stats(self) -> dict:
        """Exact counters: calls entered, attempts made, retries slept
        through, and calls that exhausted every attempt."""
        return dict(self._stats)

    def reset_stats(self) -> None:
        for k in self._stats:
            self._stats[k] = 0
