"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
async ENEC checkpointing, deterministic data resume.

The loop is single-controller JAX: on a real multi-pod fleet each host runs
this same loop (jax.distributed), data is sharded by host id, and restart
after any node failure is: reschedule job -> load LATEST -> resume at the
recorded step with the same data stream (pipeline is a pure function of the
step).  Elastic restarts may change the mesh: CheckpointManager.load
reshards via device_put.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.data import pipeline as data_pipeline
from repro.optim import adamw


@dataclasses.dataclass
class WatchdogConfig:
    """EMA step-time straggler detection.

    On a fleet, a step time far above the EMA means a slow/failing host
    (every host runs the same SPMD program, so one straggler stalls all).
    We flag, log, and after ``max_strikes`` trigger the on_straggler hook
    (production: checkpoint + evict host + elastic restart)."""
    factor: float = 2.5
    ema: float = 0.9
    max_strikes: int = 3
    warmup_steps: int = 3


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 200
    log_every: int = 10
    watchdog: WatchdogConfig = dataclasses.field(default_factory=WatchdogConfig)


def run(model, opt_cfg: adamw.AdamWConfig, data_cfg, loop_cfg: TrainLoopConfig,
        *, ckpt: Optional[CheckpointManager] = None, train_step=None,
        params=None, opt_state=None, on_metrics: Optional[Callable] = None,
        on_straggler: Optional[Callable] = None) -> dict:
    """Run (or resume) training. Returns final state + stats."""
    from repro.runtime.steps import build_train_step

    if train_step is None:
        train_step = jax.jit(build_train_step(model, opt_cfg),
                             donate_argnums=(0, 1))
    if params is None:
        params = model.init(jax.random.key(data_cfg.seed))
    if opt_state is None:
        opt_state = adamw.init(params)

    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state = {"params": params, "opt": opt_state}
        state, manifest = ckpt.load(state)
        params, opt_state = state["params"], state["opt"]
        start_step = int(manifest["step"])
        print(f"[train] resumed from step {start_step} "
              f"(ckpt ratio {manifest['ratio']:.3f}x)")

    it = data_pipeline.Prefetcher(data_cfg, start_step)
    ema_dt, strikes = None, 0
    history = []
    t_loop = time.time()
    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0

            wd = loop_cfg.watchdog
            if step - start_step >= wd.warmup_steps:
                if ema_dt is not None and dt > wd.factor * ema_dt:
                    strikes += 1
                    print(f"[watchdog] step {step} took {dt:.3f}s "
                          f"(EMA {ema_dt:.3f}s) — strike {strikes}")
                    if strikes >= wd.max_strikes:
                        if on_straggler is not None:
                            on_straggler(step)
                        if ckpt is not None:
                            ckpt.save(step, {"params": params,
                                             "opt": opt_state})
                        strikes = 0
                else:
                    strikes = max(0, strikes - 1)
                ema_dt = dt if ema_dt is None else \
                    wd.ema * ema_dt + (1 - wd.ema) * dt
            else:
                ema_dt = dt

            if step % loop_cfg.log_every == 0:
                row = {"step": step, "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "dt_s": round(dt, 4)}
                history.append(row)
                if on_metrics is not None:
                    on_metrics(row)
            if ckpt is not None and step and step % loop_cfg.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
    finally:
        it.close()
        if ckpt is not None:
            ckpt.wait()
    if ckpt is not None:
        ckpt.save(loop_cfg.total_steps, {"params": params, "opt": opt_state},
                  blocking=True)
    return {"params": params, "opt_state": opt_state, "history": history,
            "wall_s": time.time() - t_loop}
