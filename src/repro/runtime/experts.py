"""Compressed MoE expert streaming with a byte-budgeted LRU decode cache.

MoE serving touches only ``k`` of ``E`` experts per token; the rest are
dead weight in HBM.  This module keeps every expert as a *per-expert*
compressed wire record in host RAM (or restored from the enec-v2 pack
files, see checkpoint/ckpt.py) and materializes routed experts on demand:

  :class:`ExpertStore`   per-(leaf, layer, expert) wire records + a
                         byte-budgeted LRU cache of decoded expert arrays
                         with hit/miss/eviction/resident-bytes counters
  :class:`ExpertRef`     the weight-execution handle (kind "expert") that
                         replaces an ``(L, E, ...)`` expert stack in the
                         params tree; carries only a tiny ``(L,)``
                         layer-id vector on device
  :func:`routed_expert_stacks`
                         the jit-safe fetch: an ordered ``io_callback``
                         from inside ``models.moe.moe_block`` that hands
                         the routing step's expert ids to the store and
                         gets back full ``(E, ...)`` stacks with zeros in
                         unrouted slots (bit-identity: see moe.py)

Record layout: each ``(L, E, ...)`` stack is compressed as ONE stacked
encode over ``L*E`` slices (all experts of a leaf share one searched
param set), then sliced per expert (``core.api.slice_stacked``) into
independent wire records.  Because every record of a leaf shares params
and block geometry, a fetch that misses R experts across the three MoE
leaves decodes them in O(#buckets) vectorized dispatches (at most one
bucket per distinct leaf geometry), not O(R) — the same grouping contract
as the codec's ``plan_decode``, mirrored host-side by
``core.host_decode.decode_many``.  The decode itself is the PURE-NUMPY
port of the codec kernels: the fetch callback runs while the jitted step
program owns the device, so reentrant device compute would deadlock
(see core/host_decode.py).

Eviction: all of a fetch's experts are inserted/touched first and the LRU
is trimmed to the byte budget afterwards, so the *current* step's working
set is always intact when the einsum runs (a budget smaller than one
step's working set evicts after use and misses again next step —
``budget_bytes=0`` caches nothing).  Decoded cache entries live on the
host; HBM holds only the routed stacks for the duration of a step.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.core import host_decode
from repro.core import wire as enec_wire
from repro.core.api import slice_stacked
from repro.core.codec_api import current_codec
from repro.runtime.weights import WeightHandle

# the MoE expert-stack leaves of models/moe.py, shaped (L, E, D, F) in the
# layer-stacked params tree (period trees stack L periods on axis 0)
EXPERT_LEAF_NAMES = frozenset({"e_gate", "e_up", "e_down"})


class ExpertStoreError(RuntimeError):
    """An expert record is missing or inconsistent."""


def is_expert_leaf(name: str, leaf) -> bool:
    """Is this params-tree leaf an ``(L, E, ...)`` MoE expert stack?"""
    short = name.rsplit("/", 1)[-1]
    return (short in EXPERT_LEAF_NAMES
            and getattr(leaf, "ndim", 0) == 4)


def _expert_block_elems(codec, n_elems: int) -> int:
    """Encode block size for per-expert records.  Each record is its own
    L=1 "layer" in the stacked encode, and layers pad to whole blocks — a
    small expert (fewer elements than the codec's block size) would pad
    to ``block_elems`` and trip the never-worse escape.  Pick the largest
    128-multiple divisor of the expert size instead (zero padding);
    experts at or above the default block size keep it."""
    be = int(codec.config.block_elems)
    if n_elems >= be:
        return be
    for cand in range(n_elems - n_elems % 128, 0, -128):
        if n_elems % cand == 0:
            return cand
    return be


def encode_expert_leaf(name: str, leaf, codec=None):
    """Compress one ``(L, E, ...)`` expert stack into per-expert wire
    records: ONE stacked encode over the ``L*E`` expert slices (shared
    searched params -> shared decode bucket), then one sliced wire record
    per expert.  Returns ``(meta, [(layer, expert, body_bytes), ...])`` or
    ``None`` when the stack escapes compression (const / incompressible —
    the caller keeps the dense leaf)."""
    codec = codec or current_codec()
    arr = jnp.asarray(leaf)
    n_layers, n_experts = int(arr.shape[0]), int(arr.shape[1])
    expert_shape = tuple(int(s) for s in arr.shape[2:])
    n_elems = int(np.prod(expert_shape, dtype=np.int64))
    ct = codec.compress_stacked_many(
        [arr.reshape((n_layers * n_experts,) + expert_shape)],
        block_elems=_expert_block_elems(codec, n_elems))[0]
    if ct is None:
        return None
    meta = {"n_layers": n_layers, "n_experts": n_experts,
            "expert_shape": expert_shape,
            "dtype": str(jnp.dtype(arr.dtype))}
    records = []
    for l in range(n_layers):
        for j in range(n_experts):
            body = enec_wire.to_wire(
                slice_stacked(ct, l * n_experts + j))
            records.append((l, j, body))
    return meta, records


class ExpertStore:
    """Host-side store of per-expert compressed records + the LRU cache.

    Not a dataclass on purpose: equality/hash are identity, so
    :class:`ExpertRef` handles referencing the same store compare equal as
    jit static metadata and trace caches stay warm across steps.
    """

    def __init__(self, *, budget_bytes=None, codec=None):
        self.codec = codec or current_codec()
        self.budget_bytes = budget_bytes     # None = unbounded residency
        self._records = {}                   # (name, layer, expert) -> bytes
        self._meta = {}                      # name -> layout dict
        self._lru = OrderedDict()            # (name, layer, expert) -> np
        self._lock = threading.Lock()
        self.last_fetch = {"records": 0, "buckets": 0}
        self.reset_stats()

    def reset_stats(self):
        self._c = {"hits": 0, "misses": 0, "evictions": 0, "fetches": 0,
                   "fetch_records": 0, "fetch_buckets": 0}
        self._resident_bytes = 0
        for a in self._lru.values():
            self._resident_bytes += a.nbytes
        self._decode_s = 0.0

    # -- population ------------------------------------------------------

    def add_leaf(self, name: str, leaf, *, codec=None) -> bool:
        """Encode one dense ``(L, E, ...)`` stack into the store.  False
        when the stack escapes compression (leaf stays dense)."""
        enc = encode_expert_leaf(name, leaf, codec or self.codec)
        if enc is None:
            return False
        meta, records = enc
        self.add_meta(name, **meta)
        for l, j, body in records:
            self.add_record(name, l, j, body)
        return True

    def add_meta(self, name: str, *, n_layers: int, n_experts: int,
                 expert_shape, dtype: str):
        meta = {"n_layers": int(n_layers), "n_experts": int(n_experts),
                "expert_shape": tuple(int(s) for s in expert_shape),
                "dtype": str(dtype)}
        prev = self._meta.setdefault(name, meta)
        if prev != meta:
            raise ExpertStoreError(f"{name}: conflicting layouts "
                                   f"{prev} vs {meta}")

    def add_record(self, name: str, layer: int, expert: int, body: bytes):
        self._records[(name, int(layer), int(expert))] = bytes(body)

    # -- introspection ---------------------------------------------------

    def names(self):
        return sorted(self._meta)

    def meta(self, name: str) -> dict:
        return dict(self._meta[name])

    def complete(self, name: str) -> bool:
        m = self._meta.get(name)
        if m is None:
            return False
        return all((name, l, j) in self._records
                   for l in range(m["n_layers"])
                   for j in range(m["n_experts"]))

    def missing(self, name: str):
        m = self._meta[name]
        return [(l, j) for l in range(m["n_layers"])
                for j in range(m["n_experts"])
                if (name, l, j) not in self._records]

    def records_for(self, name: str):
        """``[(layer, expert, body_bytes), ...]`` — the checkpoint save
        path re-emits these verbatim (no re-encode)."""
        m = self._meta[name]
        out = []
        for l in range(m["n_layers"]):
            for j in range(m["n_experts"]):
                try:
                    out.append((l, j, self._records[(name, l, j)]))
                except KeyError:
                    raise ExpertStoreError(
                        f"{name}: missing record for layer {l} "
                        f"expert {j}") from None
        return out

    def expert_nbytes(self, name: str) -> int:
        m = self._meta[name]
        return (int(np.prod(m["expert_shape"], dtype=np.int64))
                * jnp.dtype(m["dtype"]).itemsize)

    def total_expert_bytes(self) -> int:
        """Dense bytes of every expert in the store (the 100%-resident
        cache budget)."""
        return sum(self.expert_nbytes(n)
                   * self._meta[n]["n_layers"] * self._meta[n]["n_experts"]
                   for n in self._meta)

    def ref(self, name: str) -> "ExpertRef":
        m = self._meta[name]
        return ExpertRef(
            layer_ids=jnp.arange(m["n_layers"], dtype=jnp.int32),
            name=name, store=self, n_experts=m["n_experts"],
            expert_shape=m["expert_shape"], dtype_str=m["dtype"])

    # -- fetch (the io_callback target) ----------------------------------

    def fetch_step(self, names, layer: int, routed):
        """One routing step's batched fetch: materialize ``routed`` expert
        ids of ``layer`` for every leaf in ``names`` and return full
        ``(E, ...)`` stacks with ZEROS in unrouted slots.  All misses
        across the leaves decode host-side in one batched
        ``host_decode.decode_many`` pass (O(#buckets) vectorized
        dispatches); hits are LRU-touched; the LRU is trimmed to the byte
        budget only after the step's stacks are assembled."""
        layer = int(layer)
        routed = sorted({int(r) for r in np.asarray(routed).ravel()})
        with self._lock:
            keys = [(n, layer, j) for n in names for j in routed]
            missing = []
            for k in keys:
                if k in self._lru:
                    self._lru.move_to_end(k)
                    self._c["hits"] += 1
                else:
                    missing.append(k)
                    self._c["misses"] += 1
            if missing:
                t0 = time.perf_counter()
                recs = []
                for k in missing:
                    try:
                        body = self._records[k]
                    except KeyError:
                        raise ExpertStoreError(
                            f"no record for leaf {k[0]!r} layer {k[1]} "
                            f"expert {k[2]}") from None
                    recs.append(host_decode.parse_record(
                        body, record=f"{k[0]}[{k[1]},{k[2]}]"))
                # pure-host decode: the callback runs while the jitted step
                # program OWNS the device — launching device compute here
                # (eager or nested jit) deadlocks on a single-device
                # backend, so misses decode with the numpy port
                # (bit-exact vs the codec, one vectorized call per bucket)
                decs, n_buckets = host_decode.decode_many(recs)
                self._decode_s += time.perf_counter() - t0
                self._c["fetches"] += 1
                self._c["fetch_records"] += len(missing)
                self._c["fetch_buckets"] += n_buckets
                self.last_fetch = {"records": len(missing),
                                   "buckets": n_buckets}
                for k, dec in zip(missing, decs):
                    a = np.asarray(dec)
                    self._lru[k] = a
                    self._resident_bytes += a.nbytes
            outs = []
            for n in names:
                m = self._meta[n]
                full = np.zeros((m["n_experts"],) + m["expert_shape"],
                                dtype=jnp.dtype(m["dtype"]))
                for j in routed:
                    full[j] = self._lru[(n, layer, j)]
                outs.append(full)
            self._trim()
            return tuple(outs)

    def _trim(self):
        while (self.budget_bytes is not None and self._lru
               and self._resident_bytes > self.budget_bytes):
            _, a = self._lru.popitem(last=False)
            self._resident_bytes -= a.nbytes
            self._c["evictions"] += 1

    # -- whole-leaf materialization (tests, training-restore parity) -----

    def materialize_leaf(self, name: str):
        """Decode EVERY expert of ``name`` into the dense ``(L, E, ...)``
        stack (one batched decode pass; bypasses the LRU)."""
        m = self._meta[name]
        recs = [host_decode.parse_record(body, record=f"{name}[{l},{j}]")
                for l, j, body in self.records_for(name)]
        decs, _ = host_decode.decode_many(recs)
        full = np.empty((m["n_layers"], m["n_experts"]) + m["expert_shape"],
                        dtype=jnp.dtype(m["dtype"]))
        i = 0
        for l in range(m["n_layers"]):
            for j in range(m["n_experts"]):
                full[l, j] = np.asarray(decs[i])
                i += 1
        return full

    # -- observability ---------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            out = dict(self._c)
            out.update(
                records=len(self._records),
                record_bytes=sum(len(b) for b in self._records.values()),
                resident_experts=len(self._lru),
                resident_bytes=self._resident_bytes,
                budget_bytes=self.budget_bytes,
                decode_s=round(self._decode_s, 6),
                leaves=len(self._meta))
            return out

    def decode_seconds(self) -> float:
        """Cumulative cache-miss decode wall time (the engine snapshots
        this per step to expose miss cost in step timing)."""
        with self._lock:
            return self._decode_s


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ExpertRef(WeightHandle):
    """Weight-execution handle (kind "expert") standing in for one
    ``(L, E, ...)`` expert stack.  The only traced child is a tiny
    ``(L,)`` layer-id vector — both layer-loop drivers (``lax.scan`` and
    the unrolled ``tree.map(a[i])``) slice it to the per-layer scalar the
    routed fetch callback needs; everything else is static metadata.
    ``resolve()`` passes expert handles through untouched: the routed
    fetch happens inside ``moe_block`` where the routing ids exist."""
    layer_ids: jax.Array
    name: str = dataclasses.field(metadata=dict(static=True))
    store: ExpertStore = dataclasses.field(metadata=dict(static=True))
    n_experts: int = dataclasses.field(metadata=dict(static=True))
    expert_shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))

    def materialize(self, codec=None):
        """Dense stack for the handle's layer coverage: the full
        ``(L, E, ...)`` leaf for an unsliced handle, one layer's
        ``(E, ...)`` stack after the layer loop sliced ``layer_ids``.
        Host decode — usable only with concrete (non-traced) ids."""
        full = self.store.materialize_leaf(self.name)
        ids = np.asarray(self.layer_ids)
        return jnp.asarray(full[int(ids)] if ids.ndim == 0 else full)

    def raw_nbytes(self) -> int:
        m = self.store.meta(self.name)
        return (m["n_layers"] * m["n_experts"]
                * self.store.expert_nbytes(self.name))


def routed_expert_stacks(refs, topk_i):
    """Fetch one routing step's expert weights through the store.

    ``refs`` are the layer-sliced :class:`ExpertRef` handles of one MoE
    block (``layer_ids`` already a scalar) and ``topk_i`` the
    ``(B, T, k)`` routed expert ids.  Returns one ``(E, ...)`` stack per
    ref, zeros in unrouted slots.  The ordered ``io_callback`` runs the
    LRU + batched numpy decode entirely on the host at step runtime
    (deterministic LRU order even under async dispatch; no device compute
    is launched from inside the callback — see core/host_decode.py)."""
    store = refs[0].store
    names = tuple(r.name for r in refs)
    for r in refs:
        if r.store is not store:
            raise ExpertStoreError(
                "all expert refs of one MoE block must share a store")
    shapes = [jax.ShapeDtypeStruct((r.n_experts,) + tuple(r.expert_shape),
                                   jnp.dtype(r.dtype_str)) for r in refs]

    def host_fetch(layer, ids):
        return store.fetch_step(names, int(layer), np.asarray(ids))

    outs = io_callback(host_fetch, shapes, refs[0].layer_ids, topk_i,
                       ordered=True)
    return tuple(outs)


def install_expert_store(params, *, budget_bytes=None, codec=None,
                         store=None, min_bytes: int = 0):
    """Replace every dense ``(L, E, ...)`` expert stack in ``params`` with
    an :class:`ExpertRef` backed by a (new or given) :class:`ExpertStore`.

    Runs BEFORE ``assign_weight_modes`` (which passes existing handles
    through), so expert streaming composes with any weight-execution mode.
    Leaves smaller than ``min_bytes`` or escaping compression stay dense.
    Returns ``(tree, store)``; ``store`` is None when nothing converted.
    """
    from repro.runtime.weights import is_handle
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_handle)
    names = ["/".join(str(getattr(k, "key", getattr(k, "name",
             getattr(k, "idx", k)))) for k in path) for path, _ in flat]
    est = store
    out = []
    for name, (_, leaf) in zip(names, flat):
        if (not is_handle(leaf) and is_expert_leaf(name, leaf)
                and leaf.size * leaf.dtype.itemsize >= min_bytes):
            if est is None:
                est = ExpertStore(budget_bytes=budget_bytes, codec=codec)
            if est.add_leaf(name, leaf):
                out.append(est.ref(name))
                continue
        out.append(leaf)
    converted = est is not None and bool(est.names())
    return (jax.tree_util.tree_unflatten(treedef, out),
            est if converted else None)
