"""Sharding rules: DP / TP / EP / SP / pod-DP as PartitionSpecs.

Logical layout (single pod 16x16, multi-pod 2x16x16):
  * batch            -> ("pod", "data") when divisible (pure DP across pods)
  * vocab / heads / ffn / experts / d_inner -> "model"  (TP / EP)
  * decode KV-cache sequence -> "model" (+ "pod" for long-context cells)
    — flash-decoding style: XLA turns the sharded-S softmax into local
    softmax + tiny stat all-reduces, so a 550 GB cache cell fits.
  * params replicated across "pod" (weights pure-DP across pods; gradient
    sync over "pod" is where optim/grad_compress.py applies ENEC).

Rules are name/shape driven over pytree paths, so every architecture in the
zoo (heterogeneous Jamba periods included) gets specs without per-model
tables.  Axes are dropped automatically when a dim isn't divisible by the
mesh axis (e.g. xLSTM's 4 heads on a 16-way model axis -> replicate).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def _fits(dim: int, mesh: Mesh, name) -> bool:
    size = _axis_size(mesh, name)
    return size > 1 and dim % size == 0


def _present(mesh: Mesh, name):
    """Drop axis names that don't exist in this mesh; collapse tuples."""
    if name is None:
        return None
    if isinstance(name, (tuple, list)):
        kept = tuple(n for n in name if n in mesh.shape)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return name if name in mesh.shape else None


def _maybe(dim: int, mesh: Mesh, name):
    """axis name if present and divisible, else None (replicate)."""
    name = _present(mesh, name)
    return name if name is not None and _fits(dim, mesh, name) else None


def batch_axis(mesh: Mesh, b: int):
    """Largest of ("pod","data") / "data" / None that divides the batch."""
    full = _present(mesh, ("pod", "data"))
    if full is not None and _fits(b, mesh, full):
        return full
    if _fits(b, mesh, "data"):
        return "data"
    return None


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_pspec(path: str, shape, mesh: Mesh, mode: str = "train") -> P:
    """TP(+EP) rules by leaf name; leading stack dims stay unsharded.

    mode="train": additionally FSDP-shard the non-TP matrix dim over "data"
    (ZeRO-3 style — params+optimizer of a 235B MoE at 10 B/param must spread
    over all 256 chips, not just the 16-way model axis; XLA inserts the
    FSDP all-gathers / reduce-scatters).
    mode="serve": weights TP-only (no per-step weight all-gathers on the
    latency path) except MoE expert stacks, which get expert-TP over "data"
    (E on model x F on data) — a 470 GB expert pool doesn't fit 16-way.
    """
    rank = len(shape)
    lead = (None,) * (rank - 2)
    name = path.rsplit("/", 1)[-1]
    fsdp = "data" if mode == "train" else None

    def last2(a, b):
        return P(*lead, a, b)

    def m(dim, ax):
        return _maybe(dim, mesh, ax)

    # ENEC stream arrays reached as bare path leaves: replicate.  Stream
    # placement is metadata-driven — :func:`param_pspecs` flattens handles
    # and CompressedTensors as leaves and routes them through
    # :func:`handle_pspecs` / :func:`ct_pspecs`, which read the shard
    # layout off the tensor itself.  The old path heuristic here
    # ("/streams/" + hard-coded shard-dim index 1) mis-sharded the flat
    # L=1 perm layout and anything unsharded with a divisible dim 1.
    if "/streams/" in path or "/ct/" in path:
        return P(*((None,) * rank))
    if name == "embed":
        return P(m(shape[0], "model"), m(shape[1], fsdp))
    if name == "head":
        return P(m(shape[0], fsdp), m(shape[1], "model"))
    if rank == 1 or "norm" in name or name in ("conv_b", "dt_bias", "d_skip",
                                               "a_log"):
        return P(*((None,) * rank))
    # expert stacks (..., E, D, F) / (..., E, F, D): EP on model; the big
    # matrix dim spreads over data in BOTH modes (expert-TP / FSDP).
    # mode="serve_ep": shard the CONTRACTING dim on data — expert matmuls
    # become local partial sums + a small output psum instead of XLA
    # all-gathering the dispatched tokens (§Perf hillclimb 2).
    if name in ("e_gate", "e_up", "e_down"):
        if mode == "serve_ep":
            return P(*(None,) * (rank - 3), m(shape[-3], "model"),
                     m(shape[-2], "data"), None)
        if name == "e_down":
            return P(*(None,) * (rank - 3), m(shape[-3], "model"),
                     m(shape[-2], "data"), None)
        return P(*(None,) * (rank - 3), m(shape[-3], "model"), None,
                 m(shape[-1], "data"))
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "x_proj",
                "dt_proj", "w_in", "r_in", "wi", "wf", "wo_gate", "router"):
        return last2(m(shape[-2], fsdp), m(shape[-1], "model"))
    if name in ("wo", "w_down", "out_proj"):
        return last2(m(shape[-2], "model"), m(shape[-1], fsdp))
    if name == "conv_w":
        return last2(None, m(shape[-1], "model"))
    return P(*((None,) * rank))


def _ct_stacked(ct) -> bool:
    """Does the stream layout carry a leading layer-stack dim?  Mirrors
    ``codec_api._stack_dim`` off stream rank alone, so it works on
    ``ShapeDtypeStruct`` trees too."""
    base = 3 if ct.shards > 1 else 2
    return len(ct.streams.mask.shape) == base + 1


def _stream_leaf_rule(ct, mesh: Mesh, axis="model"):
    """Per-leaf PartitionSpec rule for one CompressedTensor's stream arrays,
    derived from the tensor's OWN layout metadata (never from tree paths):
    the TP-shard dim — dim 0 per-layer, dim 1 under a layer stack — goes on
    ``axis`` when ``ct.shards`` divides the mesh axis; everything else
    (const/raw payloads, unsharded streams) replicates."""
    ax = None
    shard_ix = 0
    if ct.mode == "enec" and ct.shards > 1:
        shard_ix = 1 if _ct_stacked(ct) else 0
        ax = _maybe(ct.shards, mesh, axis)

    def rule(a):
        rank = len(a.shape)
        names = [None] * rank
        if ax is not None and rank > shard_ix \
                and a.shape[shard_ix] == ct.shards:
            names[shard_ix] = ax
        return P(*names)

    return rule


def ct_pspecs(ct, mesh: Mesh, axis="model"):
    """PartitionSpec tree (same pytree structure as ``ct``) for one bare
    :class:`CompressedTensor`."""
    return jax.tree.map(_stream_leaf_rule(ct, mesh, axis), ct)


def handle_pspecs(handle, mesh: Mesh, axis="model"):
    """PartitionSpec tree for one serving weight handle, derived from its
    metadata (the satellite fix for the old ``"/streams/"`` path
    heuristic).  Stream/fused handles shard their wire streams' TP dim on
    ``axis``; dense handles replicate (the sharded-serving compute model
    keeps dense math replicated so logits stay bit-identical to
    single-device — see docs/DISTRIBUTED.md)."""
    ct = getattr(handle, "ct", None)
    if ct is None:
        return jax.tree.map(lambda a: P(*((None,) * len(a.shape))), handle)
    return jax.tree.map(_stream_leaf_rule(ct, mesh, axis), handle)


def param_pspecs(params, mesh: Mesh, mode: str = "train"):
    """Whole-tree PartitionSpecs: weight handles and CompressedTensors are
    treated as leaves and get metadata-derived stream specs; plain array
    leaves go through the name/shape rules of :func:`param_pspec`."""
    from repro.core.api import CompressedTensor
    from repro.runtime.weights import is_handle

    def _special(x):
        return is_handle(x) or isinstance(x, CompressedTensor)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=_special)
    specs = []
    for path, leaf in flat:
        if is_handle(leaf):
            specs.append(handle_pspecs(leaf, mesh))
        elif isinstance(leaf, CompressedTensor):
            specs.append(ct_pspecs(leaf, mesh))
        else:
            specs.append(param_pspec(_path_str(path), leaf.shape, mesh, mode))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batches, caches, outputs
# ---------------------------------------------------------------------------

def batch_pspecs(specs: dict, mesh: Mesh, global_batch: int) -> dict:
    ba = batch_axis(mesh, global_batch)
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_pspecs(v, mesh, global_batch)
        else:
            out[k] = P(ba, *((None,) * (len(v.shape) - 1)))
    return out


def cache_pspecs(cache, mesh: Mesh, b: int):
    """KV caches: batch on data(+pod) when divisible; else sequence dim on
    ("pod","model") — the long-context (SP) path.  SSM states: batch, else
    channel on model."""
    ba = batch_axis(mesh, b)

    def spec_for(path, leaf) -> P:
        name = _path_str(path).rsplit("/", 1)[-1]
        shape = leaf.shape
        if name == "lengths":
            return P(ba)
        if name in ("k", "v", "mem_k", "mem_v"):
            # (periods, B, S, KV, hd)
            seq_axes = _maybe(shape[2], mesh, "model") if ba is not None \
                else _maybe(shape[2], mesh, ("pod", "model"))
            return P(None, ba, seq_axes, None, None)
        if name in ("h", "conv"):        # mamba (periods, B, ..., C) / (periods, B, K-1, C)
            ch = _maybe(shape[-1], mesh, "model")
            return P(None, ba, *((None,) * (len(shape) - 3)), ch)
        if name in ("c", "n", "m"):      # mlstm/slstm states
            return P(None, ba, *((None,) * (len(shape) - 2)))
        return P(*((None,) * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def logits_pspec(mesh: Mesh, b: int, vocab: int) -> P:
    return P(batch_axis(mesh, b), _maybe(vocab, mesh, "model"))


def to_named(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P))
