"""Weight-execution policy: ENEC-compressed weights resident in HBM,
decompressed either layer-by-layer inside the serve step (paper §VI-C) or
tile-by-tile inside the matmul kernel itself (fused mode, DESIGN.md §8).

This module decides, per parameter leaf, HOW serve-time weights execute —
the handle classes themselves live in ``runtime.weights``:

  raw      small / non-stacked leaves: untouched arrays
  dense    big matmul weights wrapped in DenseWeight (canonical executor,
           raw bytes in HBM) — the baseline the other modes compare against
  stream   StreamedWeight: per-layer ENEC streams, decompressed inside the
           step; ``lax.scan`` slices the streams so XLA's latency-hiding
           scheduler overlaps layer l+1's stream DMA + decode with layer
           l's matmuls (the paper's pipeline one level down the hierarchy)
  fused    FusedWeight: tile-wise ENEC streams consumed by the fused
           decompress+matmul Pallas kernel — the dense weight never exists
           in HBM, so decode-phase effective HBM bandwidth rises by the
           compression ratio

TP locality (stream mode): a weight whose axis ``k`` is model-sharded is
compressed in a *moveaxis(k -> 0)* layout with the block dimension sharded
on "model".  Decompression is then shard-local (blocks stay on their
device), the un-permute is a metadata transpose, and no resharding
collectives appear on the latency path.  Fused tile streams are n-major
block-ordered; they shard whenever the tile-block count divides the
requested shard width (:func:`fused_shards` — a contiguous shard range of
the flat tile axis re-flattens to the exact kernel layout), falling back
to ``shards=1`` per leaf when pad blocks would corrupt the tile order.

Only leaves >= ``min_bytes`` are compressed (norms/biases stay raw —
negligible bytes, and the decode cost would not amortize).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (MATMUL_TILE, SUPPORTED_FLOAT_DTYPES,
                            CompressedTensor, abstract_compressed,
                            matmul_tiles)
from repro.core.codec_api import current_codec
from repro.core.params import EnecParams
from repro.runtime.overlap import OVERLAP_MODES, \
    overlap_enabled  # noqa: F401  (policy surface re-export)
from repro.runtime.weights import (DenseWeight, FusedWeight,  # noqa: F401
                                   StreamedWeight, WeightHandle, handle_kind,
                                   is_handle, materialize_full_many, resolve)

MIN_STREAM_BYTES = 1 << 20  # 1 MiB
STREAM_SHARDS = 16          # production TP width (divisors also work)

WEIGHT_MODES = ("dense", "stream", "fused")

# Stacked 2-D weights consumed as x @ W by the attention/MLP layers — the
# decode path's dominant weight bytes, and the set the fused kernel (and the
# canonical tiled executor) can take over.  MoE expert stacks / SSM / xLSTM
# params keep the materialize path.
MATMUL_LEAF_NAMES = frozenset(
    {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"})


def _pstr(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "name",
                    getattr(k, "idx", k)))) for k in path)


def stream_eligible(pstr: str, shape, dtype,
                    min_bytes: int = MIN_STREAM_BYTES) -> bool:
    """The ONE streamed-leaf predicate (shared by the concrete policy and
    the abstract dry-run path, which used to carry diverging copies): a
    leaf is compressible iff it is big enough to amortize the in-step
    decode and is either a stacked (L, ...) float stack or a plain 2-D
    float weight (``embed`` / ``lm_head``-style — the biggest single
    tensors in the tree, compressed as L=1 stacks with the same
    never-worse escape)."""
    nbytes = int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    if nbytes < min_bytes or jnp.dtype(dtype) not in SUPPORTED_FLOAT_DTYPES:
        return False
    if len(shape) == 2:
        return True
    stacked = "period" in pstr or "stack" in pstr
    return stacked and len(shape) >= 3


def _tp_axis_for(path: str, shape) -> int:
    """Which axis is model-sharded at serve time (single source of truth
    for both the concrete and abstract streamed trees; mirror of
    sharding.py's name rules)."""
    name = path.rsplit("/", 1)[-1]
    if name == "embed":
        return 0
    if name in ("wo", "w_down", "out_proj"):
        return len(shape) - 2
    if name in ("e_gate", "e_up", "e_down"):
        return len(shape) - 3
    return len(shape) - 1


def fused_shards(k: int, n: int, shards: int) -> int:
    """TP shard count a fused ``(k, n)`` tile stream can actually use:
    ``shards`` when the n-major flat tile-block count divides it evenly —
    each shard then holds a contiguous range of flat tiles and the kernel's
    ``t = n_tile * k_tiles + k_tile`` order survives the shard split — else
    1, because ``stacked_blocks`` would insert pad blocks that corrupt the
    flat tile order (the PR 2 restriction, now per-leaf instead of
    global)."""
    t = MATMUL_TILE
    blocks = (-(-k // t)) * (-(-n // t))
    return shards if shards > 1 and blocks % shards == 0 else 1


def _is_matmul_pos(pstr: str, ndim: int) -> bool:
    """Is this leaf executed through the handle-aware canonical matmul
    (``models.layers.weight_matmul``)?  Name alone is not enough: xLSTM's
    ``mlstm/wq`` shares the ``wq`` name but is consumed by a plain einsum,
    so only the attention/MLP subtrees qualify — everything else must
    materialize before its layer runs."""
    parts = pstr.split("/")
    return (parts[-1] in MATMUL_LEAF_NAMES and ndim == 3
            and len(parts) >= 2 and parts[-2] in ("attn", "mlp"))


# ---------------------------------------------------------------------------
# the policy: params tree -> handle tree
# ---------------------------------------------------------------------------

def serving_job(pstr: str, leaf, mode: str,
                min_bytes: int = MIN_STREAM_BYTES) -> Optional[dict]:
    """The per-leaf compression plan for a compressing mode ("stream" /
    "fused"): which layout to encode (``arr``) and the handle metadata to
    attach.  ``None`` means the leaf is not eligible and stays raw/dense.

    Shared between :func:`assign_weight_modes` and the checkpoint writer's
    ``serving_layout`` path, so a checkpoint stores byte-for-byte the stream
    bundle the policy would build — that is what lets ``load_for_serving``
    deserialize records straight into handles.
    """
    if not stream_eligible(pstr, leaf.shape, leaf.dtype, min_bytes):
        return None
    if leaf.ndim == 2:
        # plain 2-D leaf (embed / lm_head-style): an L=1 stack in the same
        # moveaxis layout; the flat handle squeezes the stack dim back out
        tp_axis = _tp_axis_for(pstr, leaf.shape)
        return dict(kind="stream", leaf=leaf,
                    arr=jnp.moveaxis(leaf, tp_axis, 0)[None],
                    tp_axis=tp_axis, layer_shape=leaf.shape,
                    matmul_pos=False, flat=True)
    matmul_pos = _is_matmul_pos(pstr, leaf.ndim)
    if mode == "fused" and matmul_pos:
        return dict(kind="fused", leaf=leaf, arr=matmul_tiles(leaf),
                    k=leaf.shape[1], n=leaf.shape[2], matmul_pos=True)
    tp_axis = _tp_axis_for(pstr, leaf.shape[1:])
    return dict(kind="stream", leaf=leaf,
                arr=jnp.moveaxis(leaf, 1 + tp_axis, 1),
                tp_axis=tp_axis, layer_shape=leaf.shape[1:],
                matmul_pos=matmul_pos)


def build_serving_handle(job: dict, ct):
    """Handle (or fallback leaf) from a :func:`serving_job` compression
    result.  ``ct=None`` (const / incompressible) falls back to DenseWeight
    at matmul positions — executor and logits never depend on
    compressibility — or to the raw array elsewhere."""
    leaf = job["leaf"]
    if job["kind"] == "fused":
        # tile accounting runs on the zero-padded layout; re-check the
        # escape against the true (unpadded) raw bytes
        if ct is not None and ct.nbytes_wire() >= leaf.size \
                * leaf.dtype.itemsize:
            ct = None
        return (DenseWeight(w=leaf) if ct is None else
                FusedWeight(ct=ct, k=job["k"], n=job["n"],
                            dtype_str=str(leaf.dtype)))
    if ct is None:  # incompressible / const escape
        return DenseWeight(w=leaf) if job["matmul_pos"] else leaf
    return StreamedWeight(
        ct=ct, tp_axis=job["tp_axis"],
        layer_shape=tuple(job["layer_shape"]),
        dtype_str=str(leaf.dtype),
        execution="matmul" if job["matmul_pos"] else "materialize",
        flat=job.get("flat", False))


def assign_weight_modes(params, *, mode: str = "fused",
                        shared_params: Optional[EnecParams] = None,
                        min_bytes: int = MIN_STREAM_BYTES,
                        shards: int = STREAM_SHARDS,
                        codec=None):
    """Assign every leaf a weight-execution mode from its path, shape,
    bytes, and TP constraints; compress everything in ONE batched pipeline
    pass (``compress_stacked_many`` — O(#buckets) encode dispatches).

    mode="dense":  matmul positions wrapped in DenseWeight (canonical
                   executor), everything else raw.
    mode="stream": every eligible leaf becomes StreamedWeight; matmul
                   positions execute the canonical contraction on the
                   just-decompressed weight, the rest materialize.
    mode="fused":  matmul positions become FusedWeight tile streams,
                   TP-sharded per leaf when the tile-block count allows it
                   (:func:`fused_shards`; leaves whose count doesn't divide
                   ``shards`` encode unsharded); other eligible leaves
                   stream as above.

    The never-worse escape is intact in every mode: a leaf whose streams
    would not beat raw bytes falls back to DenseWeight (matmul positions,
    so the executor — and therefore the logits — stay identical) or to the
    raw array.

    Leaves that are ALREADY handles pass through untouched, so the policy
    can finish a tree that ``CheckpointManager.load_for_serving`` partially
    restored straight from wire records.

    ``codec`` selects the :class:`repro.core.Codec` doing the encoding
    (default: the ambient codec) — two models can be assigned under
    different codecs in one process with independent caches/counters.
    """
    if mode not in WEIGHT_MODES:
        raise ValueError(f"unknown weight mode {mode!r}; "
                         f"expected one of {WEIGHT_MODES}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_handle)
    out = [None] * len(flat)
    jobs = []   # serving_job dicts + their slots
    for slot, (path, leaf) in enumerate(flat):
        if is_handle(leaf):
            out[slot] = leaf
            continue
        pstr = _pstr(path)
        if mode == "dense":
            eligible = stream_eligible(pstr, leaf.shape, leaf.dtype,
                                       min_bytes)
            out[slot] = (DenseWeight(w=leaf)
                         if eligible and _is_matmul_pos(pstr, leaf.ndim)
                         else leaf)
            continue
        job = serving_job(pstr, leaf, mode, min_bytes)
        if job is None:
            out[slot] = leaf
            continue
        job["slot"] = slot
        job["shards"] = (fused_shards(job["k"], job["n"], shards)
                         if job["kind"] == "fused" else shards)
        jobs.append(job)
    codec = codec or current_codec()
    # one batched encode per distinct shard width (fused leaves whose tile
    # count doesn't divide `shards` drop to 1; everything else shares one
    # O(#buckets) pass)
    cts = [None] * len(jobs)
    by_shards: dict = {}
    for idx, j in enumerate(jobs):
        by_shards.setdefault(j["shards"], []).append(idx)
    for job_shards, idxs in sorted(by_shards.items()):
        group = codec.compress_stacked_many(
            [jobs[i]["arr"] for i in idxs], p=shared_params,
            shards=job_shards)
        for i, ct in zip(idxs, group):
            cts[i] = ct
    for j, ct in zip(jobs, cts):
        out[j["slot"]] = build_serving_handle(j, ct)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# legacy stream-everything entry points (checkpointing, benches, dry-run)
# ---------------------------------------------------------------------------

def compress_params_for_streaming(params, *,
                                  shared_params: Optional[EnecParams] = None,
                                  min_bytes: int = MIN_STREAM_BYTES,
                                  shards: int = STREAM_SHARDS,
                                  codec=None, plan=None):
    """params tree -> same-structure tree with big stacked leaves replaced
    by materialize-mode StreamedWeight (the §VI-C deployment: every stream
    decompresses to a dense weight inside the step; serve output is
    bit-identical to serving the raw tree).

    Device-resident batched pipeline (docs/PIPELINE.md): every eligible
    ``(L, ...)`` stack is handed to ``Codec.compress_stacked_many``, which
    computes statistics on device (one tiny host transfer for the whole
    tree), runs the histogram search per stack (a layer stack is one
    logical tensor, so every layer shares static codec metadata), and
    encodes each stack in ONE jit dispatch — no per-layer compress loop, no
    full-tensor ``device_get``, no ``jnp.stack`` of stream pytrees.

    ``plan`` accepts the :func:`streaming_encode_plan` built for the SAME
    (params, min_bytes, shards) — planning is not free (stats dispatches +
    host search + block staging), so inspect-then-run callers hand the
    inspected plan back instead of paying for it twice.
    """
    out, treedef, eligible = _stream_jobs(params, min_bytes)
    codec = codec or current_codec()
    if plan is None:
        plan = codec.plan_encode([e[2] for e in eligible], stacked=True,
                                 p=shared_params, shards=shards)
    elif not plan.stacked or plan.n_inputs != len(eligible) \
            or plan.shards != shards:
        raise ValueError(
            f"plan does not match this tree/policy: stacked={plan.stacked} "
            f"n_inputs={plan.n_inputs} (expected {len(eligible)}) "
            f"shards={plan.shards} (expected {shards})")
    cts = codec.execute(plan)
    for (slot, leaf, _, tp_axis, flat2d), ct in zip(eligible, cts):
        if ct is None:
            out[slot] = leaf                            # incompressible/const
            continue
        out[slot] = StreamedWeight(
            ct=ct, tp_axis=tp_axis,
            layer_shape=tuple(leaf.shape if flat2d else leaf.shape[1:]),
            dtype_str=str(leaf.dtype), flat=flat2d)
    return jax.tree_util.tree_unflatten(treedef, out)


def _stream_jobs(params, min_bytes):
    """Shared eligibility walk of :func:`compress_params_for_streaming` and
    :func:`streaming_encode_plan` — the two must see the same leaves."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [None] * len(flat)
    eligible = []   # (slot, leaf, perm, tp_axis, flat2d)
    for slot, (path, leaf) in enumerate(flat):
        pstr = _pstr(path)
        if not stream_eligible(pstr, leaf.shape, leaf.dtype, min_bytes):
            out[slot] = leaf
            continue
        if leaf.ndim == 2:      # embed/head-style leaf as an L=1 stack
            tp_axis = _tp_axis_for(pstr, leaf.shape)
            perm = jnp.moveaxis(leaf, tp_axis, 0)[None]
            eligible.append((slot, leaf, perm, tp_axis, True))
            continue
        tp_axis = _tp_axis_for(pstr, leaf.shape[1:])
        perm = jnp.moveaxis(leaf, 1 + tp_axis, 1)       # (L, tp_dim, ...)
        eligible.append((slot, leaf, perm, tp_axis, False))
    return out, treedef, eligible


def streaming_encode_plan(params, *,
                          shared_params: Optional[EnecParams] = None,
                          min_bytes: int = MIN_STREAM_BYTES,
                          shards: int = STREAM_SHARDS, codec=None):
    """The :class:`repro.core.EncodePlan` that
    :func:`compress_params_for_streaming` would execute over ``params`` —
    the whole-tree O(#buckets) dispatch guarantee as inspectable data
    (``len(plan.buckets)`` == encode dispatches; benches and CI assert it
    against the measured cache counters instead of trusting folklore)."""
    _, _, eligible = _stream_jobs(params, min_bytes)
    codec = codec or current_codec()
    return codec.plan_encode([e[2] for e in eligible], stacked=True,
                             p=shared_params, shards=shards)


def decompress_sliced(p_sliced):
    """Materialize every storage-only handle in a layer slice (the retired
    ``decompressor`` hook's behaviour — the model now does this itself via
    ``runtime.weights.resolve``; kept for direct/manual use)."""
    return resolve(p_sliced)


def materialize_weight_tree(tree, codec=None):
    """Inverse of :func:`assign_weight_modes` /
    :func:`compress_params_for_streaming`: every handle back to its dense
    ``(L, ...)`` leaf, batched through the decode pipeline so the whole
    tree costs O(#decoder buckets) decode dispatches instead of one per
    leaf (or per layer) — bit-identical to materializing each handle alone
    (ENEC is lossless and the batched decode is dispatch-sharing only).
    """
    flat, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_handle)
    slots = [i for i, leaf in enumerate(flat) if is_handle(leaf)]
    outs = materialize_full_many([flat[i] for i in slots], codec)
    for i, out in zip(slots, outs):
        flat[i] = out
    return jax.tree_util.tree_unflatten(treedef, flat)


def abstract_streamed_params(cfg, p: EnecParams, *,
                             min_bytes: int = MIN_STREAM_BYTES,
                             shards: int = STREAM_SHARDS):
    """ShapeDtypeStruct version of compress_params_for_streaming — lets the
    dry-run lower the streamed serve step without allocating anything.
    Shares :func:`stream_eligible` / :func:`_tp_axis_for` with the concrete
    path so the two cannot drift."""
    from repro.models.registry import abstract_params

    params = abstract_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = _pstr(path)
        if not stream_eligible(pstr, leaf.shape, leaf.dtype, min_bytes):
            out.append(leaf)
            continue
        flat2d = len(leaf.shape) == 2
        layer_shape = leaf.shape if flat2d else leaf.shape[1:]
        tp_axis = _tp_axis_for(pstr, layer_shape)
        n_layers = 1 if flat2d else leaf.shape[0]
        perm_shape = (layer_shape[tp_axis],) + tuple(
            d for i, d in enumerate(layer_shape) if i != tp_axis)
        ct1 = abstract_compressed(perm_shape, leaf.dtype, p, shards=shards)
        streams = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype),
            ct1.streams)
        ct = CompressedTensor(
            streams=streams, raw_bytes=None, fmt_name=ct1.fmt_name,
            params=ct1.params, shape=ct1.shape, dtype_str=ct1.dtype_str,
            block_elems=ct1.block_elems, shards=ct1.shards, mode="enec")
        out.append(StreamedWeight(ct=ct, tp_axis=tp_axis,
                                  layer_shape=tuple(layer_shape),
                                  dtype_str=str(jnp.dtype(leaf.dtype)),
                                  flat=flat2d))
    return jax.tree_util.tree_unflatten(treedef, out)


def mode_mix(tree) -> dict:
    """Handle-kind census of a weight tree (``runtime.weights.handle_kind``
    per leaf).  A clean restore shows one compressed kind plus raw
    smalls; a DEGRADED restore shows up here as a mixed tree — leaves
    adopted from a prior step's different layout, or dense fallbacks for
    quarantined bundles.  Logits are unaffected (every kind executes the
    canonical contraction); the mix is the observable of how far the tree
    is from its requested mode."""
    mix: dict = {}
    for leaf in jax.tree.leaves(tree, is_leaf=is_handle):
        k = handle_kind(leaf)
        mix[k] = mix.get(k, 0) + 1
    return mix


def stream_stats(tree) -> dict:
    """Bytes + handle-count accounting over a weight-execution tree.

    ``overlap_eligible_tensors`` counts the streamed leaves the decode-
    prefetch pipeline (``runtime.overlap``) can schedule ahead of compute;
    ``flat_stream_tensors`` is the subset stored as L=1 stacks of plain 2-D
    leaves (embed / lm_head), which sit outside the layer loop and decode
    once per step rather than once per layer."""
    from repro.runtime.experts import ExpertRef
    total_raw = total_dev = 0
    counts = {"streamed_tensors": 0, "fused_tensors": 0, "dense_handles": 0,
              "flat_stream_tensors": 0, "overlap_eligible_tensors": 0,
              "expert_tensors": 0}
    for leaf in jax.tree.leaves(tree, is_leaf=is_handle):
        if isinstance(leaf, ExpertRef):
            # expert stacks live as compressed records in HOST RAM; the
            # device holds only the tiny (L,) layer-id vector, so their
            # raw bytes count toward hbm_ratio with ~zero device bytes
            counts["expert_tensors"] += 1
            total_raw += leaf.raw_nbytes()
            total_dev += leaf.layer_ids.size * leaf.layer_ids.dtype.itemsize
        elif isinstance(leaf, StreamedWeight):
            counts["streamed_tensors"] += 1
            if leaf.flat:
                counts["flat_stream_tensors"] += 1
            else:
                counts["overlap_eligible_tensors"] += 1
            n_layers = leaf.ct.streams.mask.shape[0]
            per_layer_raw = int(np.prod(leaf.layer_shape)) \
                * jnp.dtype(leaf.dtype_str).itemsize
            total_raw += n_layers * per_layer_raw
            total_dev += leaf.ct.nbytes_device()
        elif isinstance(leaf, FusedWeight):
            counts["fused_tensors"] += 1
            n_layers = leaf.ct.streams.mask.shape[0]
            total_raw += n_layers * leaf.k * leaf.n \
                * jnp.dtype(leaf.dtype_str).itemsize
            total_dev += leaf.ct.nbytes_device()
        elif isinstance(leaf, DenseWeight):
            counts["dense_handles"] += 1
            total_raw += leaf.w.size * leaf.w.dtype.itemsize
            total_dev += leaf.w.size * leaf.w.dtype.itemsize
        elif hasattr(leaf, "size"):
            total_raw += leaf.size * leaf.dtype.itemsize
            total_dev += leaf.size * leaf.dtype.itemsize
    return {**counts, "raw_bytes": total_raw, "device_bytes": total_dev,
            "hbm_ratio": total_raw / max(total_dev, 1)}
