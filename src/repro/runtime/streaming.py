"""Weight-streaming serving: ENEC-compressed weights resident in HBM,
decompressed layer-by-layer inside the serve step (paper §VI-C).

The paper overlaps layer l+1's decompression with layer l's forward on the
NPU; here the layer stack is a ``lax.scan`` whose body decompresses its
slice of the compressed streams first — XLA's latency-hiding scheduler
overlaps the stream DMA + decode of iteration l+1 with iteration l's
matmuls, which is the same pipeline one level down the hierarchy.

TP locality: a weight whose axis ``k`` is model-sharded is compressed in a
*moveaxis(k -> 0)* layout with the block dimension sharded on "model".
Decompression is then shard-local (blocks stay on their device), the
un-permute is a metadata transpose, and no resharding collectives appear on
the latency path.

Only leaves >= ``min_bytes`` are compressed (norms/biases stay raw —
negligible bytes, and the decode cost would not amortize).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (CompressedTensor, abstract_compressed,
                            compress_stacked_many, decompress_array)
from repro.core.params import EnecParams
from repro.runtime import sharding as sh

MIN_STREAM_BYTES = 1 << 20  # 1 MiB
STREAM_SHARDS = 16          # production TP width (divisors also work)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StreamedWeight:
    """A stacked weight (L, ...) stored as per-layer ENEC streams."""
    ct: CompressedTensor                       # arrays have leading (L,) dim
    tp_axis: int = dataclasses.field(metadata=dict(static=True))
    layer_shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))


def _is_ct(x):
    return isinstance(x, (StreamedWeight, CompressedTensor))


def _tp_axis_for(path: str, shape) -> int:
    """Which axis is model-sharded at serve time (mirror of sharding.py)."""
    name = path.rsplit("/", 1)[-1]
    if name == "embed":
        return 0
    if name in ("wo", "w_down", "out_proj"):
        return len(shape) - 2
    if name in ("e_gate", "e_up", "e_down"):
        return len(shape) - 3
    return len(shape) - 1


def compress_params_for_streaming(params, *, shared_params: Optional[EnecParams] = None,
                                  min_bytes: int = MIN_STREAM_BYTES,
                                  shards: int = STREAM_SHARDS):
    """params tree -> same-structure tree with big stacked leaves replaced by
    StreamedWeight.  Leaves under ``period``/stacks keep their leading layer
    dim in the stream arrays so ``lax.scan`` slices them layer by layer.

    Device-resident batched pipeline (docs/PIPELINE.md): every eligible
    ``(L, ...)`` stack is handed to ``compress_stacked_many``, which computes
    statistics on device (one tiny host transfer for the whole tree), runs
    the histogram search per stack (a layer stack is one logical tensor, so
    every layer shares static codec metadata), and encodes each stack in ONE
    jit dispatch — no per-layer ``compress_array`` loop, no full-tensor
    ``device_get``, no ``jnp.stack`` of stream pytrees.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [None] * len(flat)
    eligible = []   # (slot, leaf, perm, tp_axis, layer_shape)
    for slot, (path, leaf) in enumerate(flat):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "name",
                        getattr(k, "idx", k)))) for k in path)
        stacked = "period" in pstr or "stack" in pstr
        nbytes = leaf.size * leaf.dtype.itemsize
        if (not stacked or nbytes < min_bytes or leaf.ndim < 3
                or leaf.dtype not in (jnp.bfloat16, jnp.float16, jnp.float32)):
            out[slot] = leaf
            continue
        layer_shape = leaf.shape[1:]
        tp_axis = _tp_axis_for(pstr, layer_shape)
        perm = jnp.moveaxis(leaf, 1 + tp_axis, 1)       # (L, tp_dim, ...)
        eligible.append((slot, leaf, perm, tp_axis, layer_shape))
    cts = compress_stacked_many([e[2] for e in eligible],
                                p=shared_params, shards=shards)
    for (slot, leaf, _, tp_axis, layer_shape), ct in zip(eligible, cts):
        if ct is None:
            out[slot] = leaf                            # incompressible/const
            continue
        out[slot] = StreamedWeight(ct=ct, tp_axis=tp_axis,
                                   layer_shape=tuple(layer_shape),
                                   dtype_str=str(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def decompress_sliced(p_sliced):
    """The ``decompressor`` hook for lm.py: StreamedWeight (layer slice,
    leading L dim already removed by scan/indexing) -> dense weight."""
    def one(leaf):
        if not isinstance(leaf, StreamedWeight):
            return leaf
        w_perm = decompress_array(leaf.ct)              # moveaxis'd layout
        w = jnp.moveaxis(w_perm, 0, leaf.tp_axis)
        return w.astype(jnp.dtype(leaf.dtype_str))
    return jax.tree.map(one, p_sliced,
                        is_leaf=lambda x: isinstance(x, StreamedWeight))


def abstract_streamed_params(cfg, p: EnecParams, *,
                             min_bytes: int = MIN_STREAM_BYTES,
                             shards: int = STREAM_SHARDS):
    """ShapeDtypeStruct version of compress_params_for_streaming — lets the
    dry-run lower the streamed serve step without allocating anything."""
    from repro.models.registry import abstract_params

    params = abstract_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "name",
                        getattr(k, "idx", k)))) for k in path)
        stacked = "period" in pstr or "stack" in pstr
        nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        if (not stacked or nbytes < min_bytes or len(leaf.shape) < 3
                or jnp.dtype(leaf.dtype) not in (jnp.bfloat16, jnp.float16,
                                                 jnp.float32)):
            out.append(leaf)
            continue
        layer_shape = leaf.shape[1:]
        tp_axis = _tp_axis_for(pstr, layer_shape)
        n_layers = leaf.shape[0]
        perm_shape = (layer_shape[tp_axis],) + tuple(
            d for i, d in enumerate(layer_shape) if i != tp_axis)
        ct1 = abstract_compressed(perm_shape, leaf.dtype, p, shards=shards)
        streams = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype),
            ct1.streams)
        ct = CompressedTensor(
            streams=streams, raw_bytes=None, fmt_name=ct1.fmt_name,
            params=ct1.params, shape=ct1.shape, dtype_str=ct1.dtype_str,
            block_elems=ct1.block_elems, shards=ct1.shards, mode="enec")
        out.append(StreamedWeight(ct=ct, tp_axis=tp_axis,
                                  layer_shape=tuple(layer_shape),
                                  dtype_str=str(jnp.dtype(leaf.dtype))))
    return jax.tree_util.tree_unflatten(treedef, out)


def stream_stats(streamed) -> dict:
    """Bytes accounting over a streamed tree."""
    total_raw = total_dev = 0
    n_streamed = 0
    for leaf in jax.tree.leaves(
            streamed, is_leaf=lambda x: isinstance(x, StreamedWeight)):
        if isinstance(leaf, StreamedWeight):
            n_streamed += 1
            l = leaf.ct.streams.mask.shape[0]
            per_layer_raw = int(np.prod(leaf.layer_shape)) \
                * jnp.dtype(leaf.dtype_str).itemsize
            total_raw += l * per_layer_raw
            total_dev += leaf.ct.nbytes_device()
        elif hasattr(leaf, "size"):
            total_raw += leaf.size * leaf.dtype.itemsize
            total_dev += leaf.size * leaf.dtype.itemsize
    return {"streamed_tensors": n_streamed, "raw_bytes": total_raw,
            "device_bytes": total_dev,
            "hbm_ratio": total_raw / max(total_dev, 1)}
