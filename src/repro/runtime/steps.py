"""Step builders: the jit-able train / prefill / decode functions shared by
the launcher, the dry-run and the examples."""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import adamw


def build_train_step(model, opt_cfg: adamw.AdamWConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    def loss_of(params, batch):
        return model.loss_fn(params, batch)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params, batch)
        params, opt_state, om = adamw.apply(opt_cfg, params, opt_state, grads)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def build_prefill_step(model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill_fn(params, batch, max_len)
    return prefill_step


def build_decode_step(model) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_fn(params, cache, tokens)
    return decode_step
