"""Weight-execution handles: HOW a serve-time weight is stored and executed.

The serving stack used to thread a ``decompressor=`` pytree-materialization
hook through the model; this module replaces it with a first-class
abstraction.  Every big weight leaf is assigned one of three execution
modes by the policy layer (``runtime.streaming.assign_weight_modes``):

  dense    :class:`DenseWeight`     raw array resident in HBM
  stream   :class:`StreamedWeight`  ENEC streams in HBM; decompressed to a
                                    dense weight inside the serve step
  fused    :class:`FusedWeight`     ENEC tile streams in HBM; decompressed
                                    INSIDE the matmul kernel's VMEM tiles —
                                    the dense weight never exists in HBM

Handles share one interface: ``matmul(x)`` contracts (M, K) activations
against the (K, N) weight, ``materialize()`` returns the dense weight.
Every mode's ``matmul`` realizes the *same* canonical contraction — the
128x128 tile grid with k-major f32 accumulation of
``kernels.ref.tiled_matmul_ref``, which is the exact schedule the fused
Pallas kernel executes — so serve logits are bit-identical across modes:
the mode changes where weight bytes live and when they decompress, never
the numerics.

Handles are registered pytrees whose array fields carry a leading ``(L,)``
layer-stack dim; ``lax.scan`` (or ``tree.map(a[i])`` on the unrolled path)
slices them per layer, then :func:`resolve` materializes storage-only
handles while matmul-capable ones pass through to the layers
(``models.layers.weight_matmul``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.api import MATMUL_TILE, CompressedTensor
from repro.core.codec_api import current_codec
from repro.kernels.ref import tiled_matmul_ref


class WeightHandle:
    """Base marker for weight-execution handles.

    Subclasses implement ``matmul(x2d) -> (M, N) f32`` (the canonical tiled
    contraction) and ``materialize() -> (K, N)`` (the dense weight, bit-exact
    for compressed modes — ENEC is lossless).
    """

    def matmul(self, x):
        raise NotImplementedError

    def materialize(self, codec=None):
        raise NotImplementedError


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseWeight(WeightHandle):
    """Raw weight executed through the canonical serve matmul (baseline
    mode, and the fallback when a leaf turns out incompressible)."""
    w: jax.Array  # (..., K, N); leading (L,) when stacked

    def materialize(self, codec=None):
        return self.w

    def matmul(self, x):
        return tiled_matmul_ref(x, self.w)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StreamedWeight(WeightHandle):
    """A stacked weight (L, ...) stored as per-layer ENEC streams.

    Compressed in a *moveaxis(tp_axis -> 0)* layout so decompression stays
    shard-local under TP (see runtime/streaming.py).  ``execution`` is the
    resolve-time behaviour: "materialize" leaves (non-matmul consumers like
    MoE experts / SSM params) are decompressed to dense arrays before the
    layer runs; "matmul" leaves pass through to the layers and execute the
    canonical tiled contraction on the just-decompressed weight.

    ``flat`` marks a handle built from a NON-stacked 2-D leaf (embed /
    lm_head-style) stored as an L=1 stack: ``layer_shape`` is the full leaf
    shape, the stream layout keeps the leading (1,) stack dim (so wire
    records and ``stream_stats`` see one invariant layout), and
    materialization squeezes it back out.  Flat handles are never sliced by
    the layer loop.
    """
    ct: CompressedTensor                       # arrays have leading (L,) dim
    tp_axis: int = dataclasses.field(metadata=dict(static=True))
    layer_shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))
    execution: str = dataclasses.field(default="materialize",
                                       metadata=dict(static=True))
    flat: bool = dataclasses.field(default=False,
                                   metadata=dict(static=True))

    def materialize(self, codec=None):
        # moveaxis'd layout; the ambient codec decodes unless one is passed.
        # Under an ambient serving mesh the stream shards are first gathered
        # as compressed bytes (collectives.maybe_gather_ct), then ONE local
        # decode runs on every device — the interconnect never carries the
        # dense weight.
        from repro.runtime.collectives import maybe_gather_ct
        ct = maybe_gather_ct(self.ct, codec)
        w_perm = (codec or current_codec()).decompress_array(ct)
        w = jnp.moveaxis(w_perm, 0, self.tp_axis)
        return w.astype(jnp.dtype(self.dtype_str))

    def matmul(self, x):
        return tiled_matmul_ref(x, self.materialize())


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FusedWeight(WeightHandle):
    """A (L, K, N) matmul weight stored as ENEC *tile* streams and executed
    by the fused decompress+matmul Pallas kernel — the dense weight never
    materializes in HBM.  ``k``/``n`` are the unpadded logical dims (ragged
    edges ride the zero-padded tile layout of ``core.api.matmul_tiles``)."""
    ct: CompressedTensor                       # tile streams, leading (L,)
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))

    def matmul(self, x):
        from repro.kernels import ops  # lazy: keep module import light
        from repro.runtime.collectives import maybe_gather_ct
        return ops.decompress_matmul(x, maybe_gather_ct(self.ct),
                                     self.k, self.n)

    def materialize(self, codec=None):
        from repro.runtime.collectives import maybe_gather_ct
        w = (codec or current_codec()).untile_matmul_weight(
            maybe_gather_ct(self.ct, codec), self.k, self.n)
        return w.astype(jnp.dtype(self.dtype_str))


def is_handle(x) -> bool:
    return isinstance(x, WeightHandle)


def handle_kind(leaf) -> str:
    """Weight-execution kind of a tree leaf: "dense"/"stream"/"fused" for
    handles, "expert" for an expert-store reference
    (``runtime.experts.ExpertRef``), "raw" for plain arrays — the shared
    vocabulary the restore report and the serve health line use to
    describe a (possibly mixed) degraded tree.  All kinds produce
    bit-identical logits (module docstring; experts: models/moe.py), so a
    mixed kind census is a capacity/latency statement, never a
    correctness one."""
    if isinstance(leaf, DenseWeight):
        return "dense"
    if isinstance(leaf, StreamedWeight):
        return "stream"
    if isinstance(leaf, FusedWeight):
        return "fused"
    if isinstance(leaf, WeightHandle):
        # lazy: experts.py imports this module at load time
        from repro.runtime.experts import ExpertRef
        if isinstance(leaf, ExpertRef):
            return "expert"
    return "raw"


# ---------------------------------------------------------------------------
# checkpoint (de)serialization: spec <-> handle
# ---------------------------------------------------------------------------

def handle_spec(handle: WeightHandle) -> dict:
    """JSON-able static metadata of a compressed handle — everything the
    checkpoint manifest needs to rebuild it around a deserialized stream
    bundle (docs/CHECKPOINT.md)."""
    if isinstance(handle, StreamedWeight):
        spec = {"kind": "stream", "tp_axis": handle.tp_axis,
                "layer_shape": list(handle.layer_shape),
                "dtype": handle.dtype_str, "execution": handle.execution}
        if handle.flat:
            spec["flat"] = True
        return spec
    if isinstance(handle, FusedWeight):
        return {"kind": "fused", "k": handle.k, "n": handle.n,
                "dtype": handle.dtype_str}
    raise TypeError(f"no spec for handle type {type(handle).__name__}")


def handle_from_spec(spec: dict, ct: CompressedTensor) -> WeightHandle:
    """Inverse of :func:`handle_spec`: rebuild the handle around a stream
    bundle deserialized straight from the wire — the dense weight is never
    touched."""
    kind = spec["kind"]
    if kind == "stream":
        return StreamedWeight(ct=ct, tp_axis=int(spec["tp_axis"]),
                              layer_shape=tuple(spec["layer_shape"]),
                              dtype_str=spec["dtype"],
                              execution=spec.get("execution", "materialize"),
                              flat=bool(spec.get("flat", False)))
    if kind == "fused":
        return FusedWeight(ct=ct, k=int(spec["k"]), n=int(spec["n"]),
                           dtype_str=spec["dtype"])
    raise ValueError(f"unknown handle spec kind {kind!r}")


def finish_materialize(handle, w_stacked):
    """Stacked decode result -> the handle's original dense ``(L, ...)``
    leaf (un-permute / un-tile the storage layout)."""
    if isinstance(handle, StreamedWeight):
        w = jnp.moveaxis(w_stacked, 1, 1 + handle.tp_axis)
        if handle.flat:        # L=1 stack of a 2-D leaf: drop the stack dim
            w = w[0]
        return w.astype(jnp.dtype(handle.dtype_str))
    if isinstance(handle, FusedWeight):
        t = MATMUL_TILE
        k, n = handle.k, handle.n
        kp, np_ = -(-k // t) * t, -(-n // t) * t
        tiles = w_stacked.reshape(w_stacked.shape[0], np_ // t, kp // t, t, t)
        w = tiles.transpose(0, 2, 3, 1, 4).reshape(w_stacked.shape[0], kp, np_)
        return w[:, :k, :n].astype(jnp.dtype(handle.dtype_str))
    raise TypeError(f"not a compressed handle: {type(handle).__name__}")


def materialize_full(handle, codec=None):
    """Materialize a STACKED handle to its original dense ``(L, ...)`` leaf
    in one decode dispatch (``materialize()`` operates on per-layer slices;
    this is the whole-stack inverse the checkpoint loader needs to restore a
    training tree from serving-layout records)."""
    if isinstance(handle, DenseWeight):
        return handle.w
    from repro.runtime.collectives import maybe_gather_ct
    codec = codec or current_codec()
    return finish_materialize(
        handle, codec.decompress_stacked(maybe_gather_ct(handle.ct, codec)))


def materialize_full_many(handles, codec=None):
    """:func:`materialize_full` over many handles with O(#decoder buckets)
    decode dispatches — handles sharing a bucket decode in one concatenated
    dispatch via ``Codec.decompress_stacked_many`` (batched checkpoint
    restore, whole-tree materialization)."""
    from repro.runtime.collectives import maybe_gather_ct
    codec = codec or current_codec()
    decs = codec.decompress_stacked_many(
        [None if isinstance(h, DenseWeight)
         else maybe_gather_ct(h.ct, codec) for h in handles])
    return [h.w if isinstance(h, DenseWeight) else finish_materialize(h, d)
            for h, d in zip(handles, decs)]


def resolve(tree, codec=None, *, prefetched=None):
    """Per-layer handle resolution — the serve step's replacement for the
    retired ``decompressor=`` hook.  Storage-only handles (StreamedWeight in
    "materialize" execution) become dense arrays; matmul-capable handles
    pass through for the layers to execute; everything else is untouched.

    Without prefetch, every StreamedWeight decodes serially inside the
    layer it belongs to.  The measured overlap scheduler
    (``runtime.overlap``, benchmarks/bench_overlap.py) instead decodes
    layer l+1 one step ahead and hands the result back here:
    ``prefetched`` maps flatten slots (``tree`` flattened with
    ``is_leaf=is_handle``) to already-decoded dense weights — a
    "materialize" handle at that slot is replaced by the buffer directly,
    a "matmul" handle becomes a :class:`DenseWeight` around it (same
    canonical tiled contraction, so logits are bit-identical either way).
    ``codec`` pins the decoding codec; default is the ambient codec at
    trace time.
    """
    flat, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_handle)
    pre = prefetched or {}
    out = []
    for slot, leaf in enumerate(flat):
        if slot in pre:
            if not isinstance(leaf, StreamedWeight):
                raise TypeError(
                    f"prefetched slot {slot} is not a StreamedWeight: "
                    f"{type(leaf).__name__}")
            w = pre[slot]
            out.append(DenseWeight(w=w) if leaf.execution == "matmul" else w)
        elif isinstance(leaf, StreamedWeight) and leaf.execution != "matmul":
            out.append(leaf.materialize(codec))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
