"""Compressed-bytes collectives: mesh placement and gathering for ENEC
stream bundles (ROADMAP item 3; paper thesis extended from PCIe to the
interconnect).

The sharded serving model is FSDP-of-compressed-bytes:

  * At rest each device owns ONLY its TP shard's wire records — the stream
    arrays' shard dim (``CompressedTensor.shards``) is placed on the mesh
    ``"model"`` axis (:func:`place_serving_tree`, or straight from the
    checkpoint via :func:`stream_placer` + ``from_wire(stream_place=)``).
  * When a layer is consumed, the missing shards are gathered as
    FIXED-LENGTH WIRE PAYLOADS over the mesh axis (:func:`gather_ct`) —
    the interconnect only ever carries compressed bytes — and then ONE
    batched decode runs locally on every device
    (``StreamedWeight.materialize`` / the overlap prefetch drivers call
    :func:`maybe_gather_ct` first, so overlap composes with sharding).
  * Dense math then runs replicated, so sharded serve logits are
    bit-identical to single-device serve in every mode: only the *storage*
    and the *bytes on the wire* are distributed, never the rounding.

:func:`shard_local_decode` is the zero-traffic variant — each device
decodes only its own block shard under ``shard_map`` (per-block decode is
independent, so the result is bit-identical to a full decode); the parity
tests drive it across every format.

Every gather is attributed to the codec's ``d2d_allgather`` ledger link
(:meth:`Codec.count_link`).  Gathers that happen inside a jit trace are
counted once per trace, not once per executed step — the schedule is
static, so per-step traffic is ``counted_bytes`` x steps (see
docs/DISTRIBUTED.md).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import codec as block_codec
from repro.core.api import CompressedTensor
from repro.core.codec_api import current_codec
from repro.runtime import sharding

MODEL_AXIS = "model"


# ---------------------------------------------------------------------------
# the ambient serving mesh
# ---------------------------------------------------------------------------

_mesh_ctx: contextvars.ContextVar = contextvars.ContextVar(
    "repro_serving_mesh", default=None)


def serving_mesh():
    """The ambient ``(mesh, axis)`` installed by :func:`use_serving_mesh`,
    or ``None`` — read by :func:`maybe_gather_ct` at trace time so handle
    materialization gathers without threading a mesh through every
    signature."""
    return _mesh_ctx.get()


@contextlib.contextmanager
def use_serving_mesh(mesh: Mesh, axis: str = MODEL_AXIS):
    """Install ``mesh`` as the ambient serving mesh for the block: every
    ``StreamedWeight.materialize`` / ``FusedWeight.matmul`` / overlap
    prefetch inside gathers its compressed shards over ``axis`` first."""
    token = _mesh_ctx.set((mesh, axis))
    try:
        yield mesh
    finally:
        _mesh_ctx.reset(token)


# ---------------------------------------------------------------------------
# placement: each device holds only its shard's wire records
# ---------------------------------------------------------------------------

def _axis_count(mesh: Mesh, axis) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def stream_placer(mesh: Mesh, axis: str = MODEL_AXIS):
    """The ``from_wire(stream_place=)`` hook for mesh restores: uploads
    each stream leaf with its TP-shard dim placed on ``axis``, so shard
    ``s``'s wire bytes land on the devices that own mesh coordinate ``s``
    only — the per-shard pack never fans out over h2d.  Leaves without a
    shard dim (or with an indivisible one) upload replicated."""
    def place(host_arr, shard_dim):
        names = [None] * host_arr.ndim
        if shard_dim is not None and _axis_count(mesh, axis) > 1 \
                and host_arr.shape[shard_dim] % mesh.shape[axis] == 0:
            names[shard_dim] = axis
        return jax.device_put(host_arr, NamedSharding(mesh, P(*names)))
    return place


def serving_pspecs(tree, mesh: Mesh, axis: str = MODEL_AXIS):
    """PartitionSpecs for a serving tree: handles/CompressedTensors get
    metadata-derived stream specs (:func:`sharding.handle_pspecs`), every
    plain leaf replicates — the bit-parity compute model shards only the
    compressed storage, never the dense math."""
    from repro.runtime.weights import is_handle

    def _special(x):
        return is_handle(x) or isinstance(x, CompressedTensor)

    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_special)
    specs = []
    for leaf in leaves:
        if is_handle(leaf):
            specs.append(sharding.handle_pspecs(leaf, mesh, axis))
        elif isinstance(leaf, CompressedTensor):
            specs.append(sharding.ct_pspecs(leaf, mesh, axis))
        else:
            specs.append(P(*((None,) * jnp.ndim(leaf))))
    return jax.tree_util.tree_unflatten(treedef, specs)


def place_serving_tree(tree, mesh: Mesh, axis: str = MODEL_AXIS):
    """``device_put`` a serving tree onto ``mesh`` per
    :func:`serving_pspecs`: stream shards distributed over ``axis``,
    everything else replicated."""
    return jax.device_put(tree, sharding.to_named(
        serving_pspecs(tree, mesh, axis), mesh))


# ---------------------------------------------------------------------------
# compressed-bytes all-gather
# ---------------------------------------------------------------------------

def _replicate(a, mesh: Mesh):
    ns = NamedSharding(mesh, P(*((None,) * jnp.ndim(a))))
    if isinstance(a, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(a, ns)
    return jax.device_put(a, ns)


def stream_nbytes(ct: CompressedTensor) -> int:
    """Device-layout byte total of the stream arrays (>= the exact
    ``nbytes_wire``: the high stream is padded to its static bound)."""
    return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
               for a in jax.tree.leaves(ct.streams))


def gather_ct(ct: CompressedTensor, mesh: Mesh, axis: str = MODEL_AXIS,
              codec=None) -> CompressedTensor:
    """The compression-aware all-gather: replicate ``ct``'s stream arrays
    over the mesh ``axis`` so every device holds all shards' fixed-length
    wire payloads, ready for one batched shard-local decode.  ONLY
    compressed bytes move — ``(A-1) x stream_nbytes(ct)`` total interconnect
    traffic for an ``A``-way axis, attributed to the ``d2d_allgather``
    ledger link (never the dense equivalent ``(A-1) x nbytes_raw``).

    No-op (and nothing counted) for raw/const/unsharded tensors or when
    ``ct.shards`` doesn't divide the axis.  Works eagerly (``device_put``)
    and inside jit (``with_sharding_constraint`` — counted at trace time).

    A tensor consumed at several call sites (e.g. a tied embed/head handle)
    is gathered ONCE: the eager gathered result is cached on the source
    tensor, so repeat consumption neither re-transfers nor re-counts.
    Tracer streams are never cached (a trace-local value must not outlive
    its trace); inside jit XLA CSEs duplicate gathers itself.
    """
    A = _axis_count(mesh, axis)
    if ct.mode != "enec" or ct.shards <= 1 or A <= 1 or ct.shards % A:
        return ct
    hit = getattr(ct, "_gather_cache", None)
    if hit is not None and hit[0] is mesh and hit[1] == axis:
        return hit[2]
    n_leaves = len(jax.tree.leaves(ct.streams))
    (codec or current_codec()).count_link(
        "d2d_allgather", stream_nbytes(ct) * (A - 1), ops=n_leaves)
    streams = jax.tree.map(lambda a: _replicate(a, mesh), ct.streams)
    out = dataclasses.replace(ct, streams=streams)
    cached = getattr(ct, "_wire_bytes", None)
    if cached is not None:   # keep the lazily-filled wire-size cache
        out._wire_bytes = cached
    if not any(isinstance(a, jax.core.Tracer)
               for a in jax.tree.leaves(ct.streams)):
        ct._gather_cache = (mesh, axis, out)
    return out


def maybe_gather_ct(ct: CompressedTensor, codec=None) -> CompressedTensor:
    """:func:`gather_ct` under the ambient serving mesh; identity when no
    mesh is installed.  The hook every consumption point calls
    (``StreamedWeight.materialize``, ``FusedWeight.matmul``, the overlap
    prefetch) so single-device behavior is untouched."""
    ctx = serving_mesh()
    if ctx is None or not isinstance(ct, CompressedTensor):
        return ct
    mesh, axis = ctx
    return gather_ct(ct, mesh, axis, codec)


# ---------------------------------------------------------------------------
# shard-local decode (zero interconnect traffic)
# ---------------------------------------------------------------------------

def shard_local_decode(ct: CompressedTensor, mesh: Mesh,
                       axis: str = MODEL_AXIS):
    """Decode a mesh-sharded tensor with each device decoding ONLY its own
    block shard under ``shard_map`` — no stream gather, no dense traffic;
    the dense result comes out sharded over its leading (block) dim.

    Per-block decode is independent (the paper's fixed-length block
    design), so the result is bit-identical to
    ``codec.decompress_array(ct)`` on a single device — asserted per
    format by tests/test_mesh_exec.py.  Per-layer (unstacked) enec tensors
    only; raw/const tensors have nothing to shard-decode.
    """
    if ct.mode != "enec":
        raise ValueError(f"shard_local_decode needs an enec tensor, "
                         f"got mode {ct.mode!r}")
    if ct.shards <= 1:
        raise ValueError("tensor is unsharded — use codec.decompress_array")
    base = 3 if ct.shards > 1 else 2
    if ct.streams.mask.ndim != base:
        raise ValueError("shard_local_decode takes per-layer tensors; "
                         "slice the layer stack first (slice_stacked)")
    A = _axis_count(mesh, axis)
    if A <= 1 or ct.shards % A:
        raise ValueError(
            f"shards={ct.shards} not divisible over mesh axis "
            f"{axis!r} of size {A}")
    fmt, p, block_elems = ct.fmt, ct.params, ct.block_elems
    in_specs = jax.tree.map(
        lambda a: P(axis, *((None,) * (a.ndim - 1))), ct.streams)

    def body(streams):
        # local shapes: (S/A, B/S, ...) — flatten to this device's flat
        # blocks and run the pure reference block decode (jit-compatible;
        # shard_map compiles it once per program)
        flat = block_codec.flatten_blocks(streams)
        return block_codec.decode_blocks(flat, block_elems, fmt, p)

    bits = shard_map(body, mesh=mesh, in_specs=(in_specs,),
                     out_specs=P(axis, None))(ct.streams)
    return block_codec.from_blocks(bits, ct.shape, fmt)
