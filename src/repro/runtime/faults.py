"""Deterministic, seeded fault injection for the checkpoint/restore stack.

The restore path's reliability claims (docs/RELIABILITY.md) are only worth
anything if they can be exercised on demand: this module lets tests, CI,
and ``benchmarks/bench_faults.py`` inject the exact failure classes the
enec-v2 container is designed to survive —

  read      ``open``/``read`` of a matching path raises ``InjectedFault``
            (an ``OSError``, so the retry policy treats it like a real
            filesystem error); ``times`` bounds how often it fires, which
            is how a *transient* fail-twice-then-succeed fault differs
            from a *permanent* one (``times=-1``)
  write     same, for the checkpoint writer pool's pack writes
  corrupt   bytes returned by a matching read are bit-flipped or truncated
            (the frame CRC then rejects the record downstream — corruption
            is detected by the REAL validation path, never simulated)
  decode    the checkpoint loader's decode dispatch fails for a matching
            record name (models a kernel/runtime failure after the bytes
            arrived intact)
  step      a serving-engine scheduler step fails for a matching request
            key (``runtime/engine.py`` probes every active request before
            each prefill/decode step; a transient step fault is absorbed
            by the engine's RetryPolicy, a permanent one evicts only the
            poisoned request while the rest of the batch continues)

Faults activate through a contextvar (``inject(...)`` contextmanager — the
test-local route) or through the ``ENEC_FAULTS`` environment variable (a
JSON spec list — the route for CI jobs and subprocess launchers that cannot
reach into the process).  Injection is deterministic: spec matching is
first-match in declaration order, firing counters are exact, and any
randomized choice (a ``corrupt`` spec without an explicit offset) draws
from a ``random.Random(seed)`` owned by the injector.

Nothing in the I/O helpers below costs anything when no injector is active:
``read_range``/``read_file`` degrade to a plain seek+read.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
import os
import random
import time
from pathlib import Path
from typing import List, Optional, Union


class InjectedFault(OSError):
    """An injected I/O or decode fault.  Subclasses ``OSError`` so the
    retry policy (runtime/retry.py) handles injected and real filesystem
    failures identically."""


class FaultConfigError(ValueError):
    """The ``ENEC_FAULTS`` environment variable (or an explicit spec) is
    malformed.  Raised eagerly with a one-line message naming the env var
    so a typo'd CI fault schedule fails at the first injection point, not
    as a raw JSON/TypeError traceback deep inside a checkpoint read."""


FAULT_KINDS = ("read", "write", "corrupt", "decode", "step")
CORRUPT_MODES = ("flip", "truncate")


@dataclasses.dataclass
class FaultSpec:
    """One fault to inject.

    ``match`` is a substring test against the target (a file path for
    read/write/corrupt, a record name for decode); "" matches everything.
    ``times`` caps the number of firings (-1 = unlimited/permanent).
    ``offset`` picks the byte to corrupt within the read slice (``None``
    = seeded choice); for ``mode="truncate"`` it is the length to keep.
    ``delay_s`` sleeps before the fault takes effect (slow-I/O modelling).
    """
    kind: str
    match: str = ""
    times: int = -1
    offset: Optional[int] = None
    mode: str = "flip"
    xor: int = 0x08
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corrupt mode {self.mode!r}; "
                             f"expected one of {CORRUPT_MODES}")


class FaultInjector:
    """Holds the active :class:`FaultSpec` list and the per-spec firing
    counters.  One injector == one deterministic fault schedule."""

    def __init__(self, specs, seed: int = 0):
        self.specs: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in specs]
        self.seed = seed
        self._rng = random.Random(seed)
        self.fired = [0] * len(self.specs)

    def stats(self) -> list:
        """Per-spec firing counters, in declaration order."""
        return [{"kind": s.kind, "match": s.match, "times": s.times,
                 "fired": n} for s, n in zip(self.specs, self.fired)]

    def _take(self, kind: str, target) -> Optional[FaultSpec]:
        """First live spec of ``kind`` matching ``target``; consumes one
        firing (and applies its delay) when found."""
        for i, s in enumerate(self.specs):
            if s.kind != kind or s.match not in str(target):
                continue
            if s.times >= 0 and self.fired[i] >= s.times:
                continue
            self.fired[i] += 1
            if s.delay_s:
                time.sleep(s.delay_s)
            return s
        return None

    def check_read(self, path) -> None:
        if self._take("read", path) is not None:
            raise InjectedFault(f"injected read fault: {path}")

    def check_write(self, path) -> None:
        if self._take("write", path) is not None:
            raise InjectedFault(f"injected write fault: {path}")

    def check_decode(self, name) -> None:
        if self._take("decode", name) is not None:
            raise InjectedFault(f"injected decode fault: {name}")

    def check_step(self, key) -> None:
        if self._take("step", key) is not None:
            raise InjectedFault(f"injected step fault: {key}")

    def corrupt(self, path, data: bytes) -> bytes:
        """Apply a matching ``corrupt`` spec to bytes just read from
        ``path`` — flip one byte or truncate, leaving detection to the
        real frame/CRC validation downstream."""
        s = self._take("corrupt", path)
        if s is None or not data:
            return data
        if s.mode == "truncate":
            keep = s.offset if s.offset is not None \
                else self._rng.randrange(len(data))
            return data[:max(0, min(keep, len(data) - 1))]
        buf = bytearray(data)
        idx = s.offset if s.offset is not None and 0 <= s.offset < len(buf) \
            else self._rng.randrange(len(buf))
        buf[idx] ^= (s.xor or 0x01) & 0xFF
        return bytes(buf)


# ---------------------------------------------------------------------------
# activation: contextmanager (in-process) or ENEC_FAULTS env (subprocess/CI)
# ---------------------------------------------------------------------------

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "enec_fault_injector", default=None)
_ENV_CACHE: tuple = (None, None)   # (raw env string, parsed injector)


def _parse_env_schedule(raw: str) -> FaultInjector:
    """Parse ``ENEC_FAULTS`` into a :class:`FaultInjector`, converting every
    malformed-input failure (bad JSON, wrong container shape, unknown fault
    ``kind``/``mode``, bogus field types) into a one-line
    :class:`FaultConfigError` that names the env var."""
    try:
        data = json.loads(raw)
    except json.JSONDecodeError as e:
        raise FaultConfigError(
            f"ENEC_FAULTS is not valid JSON: {e}") from None
    if isinstance(data, list):
        data = {"specs": data}
    if not isinstance(data, dict):
        raise FaultConfigError(
            f"ENEC_FAULTS must be a JSON list of fault specs or an object "
            f"with a 'specs' key, got {type(data).__name__}")
    try:
        return FaultInjector(data.get("specs", []),
                             seed=int(data.get("seed", 0)))
    except (TypeError, ValueError) as e:
        raise FaultConfigError(f"ENEC_FAULTS has a bad fault spec: {e}") \
            from None


def active() -> Optional[FaultInjector]:
    """The injector in effect, if any: the ``inject()`` contextvar wins,
    else ``ENEC_FAULTS`` (JSON: a spec list, or ``{"seed": .., "specs":
    [..]}``), else None.  A malformed env schedule raises
    :class:`FaultConfigError` at the first injection point instead of a
    raw traceback from deep inside a checkpoint read."""
    inj = _ACTIVE.get()
    if inj is not None:
        return inj
    raw = os.environ.get("ENEC_FAULTS")
    if not raw:
        return None
    global _ENV_CACHE
    if _ENV_CACHE[0] != raw:
        _ENV_CACHE = (raw, _parse_env_schedule(raw))
    return _ENV_CACHE[1]


@contextlib.contextmanager
def inject(*specs: Union[FaultSpec, dict], seed: int = 0):
    """Activate a fault schedule for the enclosed block and yield the
    injector (its ``stats()``/``fired`` counters are assertable after)."""
    if len(specs) == 1 and isinstance(specs[0], FaultInjector):
        inj = specs[0]
    else:
        inj = FaultInjector(list(specs), seed=seed)
    token = _ACTIVE.set(inj)
    try:
        yield inj
    finally:
        _ACTIVE.reset(token)


# ---------------------------------------------------------------------------
# fault-aware I/O helpers (the checkpoint layer's single read/write funnel)
# ---------------------------------------------------------------------------

def read_range(path, offset: int, length: int) -> bytes:
    """seek+read ``length`` bytes at ``offset``, applying any active read
    and corrupt faults for ``path``."""
    inj = active()
    if inj is not None:
        inj.check_read(path)
    with open(path, "rb") as f:
        f.seek(offset)
        data = f.read(length)
    if inj is not None:
        data = inj.corrupt(path, data)
    return data


def read_file(path) -> bytes:
    """Whole-file read through the same fault funnel as :func:`read_range`."""
    inj = active()
    if inj is not None:
        inj.check_read(path)
    with open(path, "rb") as f:
        data = f.read()
    if inj is not None:
        data = inj.corrupt(path, data)
    return data


def check_write(path) -> None:
    """Raise the active write fault for ``path``, if any (called by the
    checkpoint writer pool before each pack write)."""
    inj = active()
    if inj is not None:
        inj.check_write(path)


def check_decode(name) -> None:
    """Raise the active decode fault for record ``name``, if any (called
    by the checkpoint loader before admitting a record to the batched
    decode plan)."""
    inj = active()
    if inj is not None:
        inj.check_decode(name)


def check_step(key) -> None:
    """Raise the active serving-step fault for request ``key``, if any
    (called by the engine's scheduler before each prefill/decode step for
    every active request, so a fault can poison one request without
    touching the rest of the batch)."""
    inj = active()
    if inj is not None:
        inj.check_step(key)


# ---------------------------------------------------------------------------
# on-disk corruption helper (tests / CI / bench: damage a committed record)
# ---------------------------------------------------------------------------

def flip_pack_byte(ckpt_root, name: str = "", *, step: Optional[int] = None,
                   byte: int = 0, xor: int = 0x08) -> tuple:
    """Permanently flip one byte inside a committed pack record's payload
    (the frame CRC will reject it on the next read).  ``name`` selects the
    first manifest entry whose record name contains it (declaration order);
    ``byte`` indexes into the record payload.  Returns ``(record_name,
    pack_path, absolute_offset)`` so the caller can assert the quarantine
    line points at exactly this damage."""
    from repro.core import wire as enec_wire

    root = Path(ckpt_root)
    if step is None:
        dirs = sorted(p for p in root.glob("step_*") if p.is_dir())
        if not dirs:
            raise FileNotFoundError(f"no step directories under {root}")
        cdir = dirs[-1]
    else:
        cdir = root / f"step_{step:012d}"
    manifest = json.loads((cdir / "manifest.json").read_text())
    entry = next((e for e in manifest["leaves"]
                  if name in e["name"] and "pack" in e), None)
    if entry is None:
        raise ValueError(f"no pack record matching {name!r} in {cdir}")
    pack_path = cdir / manifest["packs"][entry["pack"]]
    pos = entry["offset"] + enec_wire.FRAME_HEADER_BYTES \
        + min(max(byte, 0), entry["bytes"] - 1)
    with open(pack_path, "r+b") as f:
        f.seek(pos)
        old = f.read(1)
        f.seek(pos)
        f.write(bytes([old[0] ^ xor]))
    return entry["name"], str(pack_path), pos
