"""Admission control for the continuous-batching serving engine.

Three pieces, all deliberately free of JAX so they are unit-testable with a
fake clock and reusable by any scheduler:

:class:`Request`
    One generation request and its whole observable lifecycle — prompt,
    token budget, priority, absolute deadlines (TTFT and total), state
    machine, timestamps, and the emitted tokens/logits.

:class:`AdmissionQueue`
    A BOUNDED FIFO with explicit backpressure.  ``offer()`` either accepts
    or rejects-with-reason (``queue_full`` / ``overloaded`` / ``draining``)
    — the queue never grows without bound, so overload shows up as honest
    rejections at the front door instead of unbounded latency inside.
    Requests whose TTFT deadline expires while queued are shed *before*
    they consume a prefill, and the overload governor may shed the
    lowest-priority queued work when a step misbehaves.

:class:`OverloadGovernor`
    The step watchdog + overload state machine.  It learns a baseline step
    time during warmup, flags steps that are *stuck* (over the absolute
    watchdog) or *slow* (over ``overload_factor`` x baseline), and while
    violations persist holds the engine in the ``overloaded`` state —
    where admission degrades (new low-priority work is rejected) so the
    latency of already-admitted requests is protected.  ``recovery_steps``
    consecutive healthy steps return it to ``nominal``.

See docs/TRAFFIC.md for the full semantics table.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import deque
from typing import List, Optional, Tuple

# offer() rejection reasons (Request.detail of a "rejected" request)
REJECT_QUEUE_FULL = "queue_full"
REJECT_OVERLOADED = "overloaded"
REJECT_DRAINING = "draining"

# terminal request states and what they mean:
#   done       all requested tokens emitted within deadline
#   timed_out  all tokens emitted, but the last one landed past the total
#              deadline (the eviction check runs at step granularity, so a
#              deadline expiring mid-step can complete late — accounted
#              honestly, never reported as "done")
#   rejected   refused at the front door (detail = reason above)
#   shed       dropped from the queue before any prefill ran
#              (detail = "deadline" | "overload" | "drain")
#   evicted    removed mid-flight, KV slot reclaimed
#              (detail = "deadline" | "fault" | "abort")
TERMINAL_STATES = ("done", "timed_out", "rejected", "shed", "evicted")

_rid_counter = itertools.count()


@dataclasses.dataclass
class Request:
    """One generation request moving through the engine."""
    prompt: object                      # 1-D int32 array of prompt tokens
    max_new_tokens: int
    priority: int = 0                   # higher = more important
    ttft_deadline_s: Optional[float] = None   # absolute clock() time
    deadline_s: Optional[float] = None        # absolute clock() time
    name: str = ""
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))

    # lifecycle (engine-owned)
    state: str = "new"
    detail: str = ""
    submit_s: Optional[float] = None
    admit_s: Optional[float] = None     # prefill started
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    logits: list = dataclasses.field(default_factory=list)
    retries: int = 0                    # step-fault retries absorbed

    def __post_init__(self):
        if not self.name:
            self.name = f"req-{self.rid}"

    @property
    def key(self) -> str:
        """The fault-injection match target (FaultSpec kind="step")."""
        return self.name

    @property
    def finished(self) -> bool:
        return self.state in TERMINAL_STATES

    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None or self.submit_s is None:
            return None
        return self.first_token_s - self.submit_s

    def tpot_s(self) -> Optional[float]:
        """Mean time per output token after the first (None for <2 tokens)."""
        if (self.finish_s is None or self.first_token_s is None
                or len(self.tokens) < 2):
            return None
        return (self.finish_s - self.first_token_s) / (len(self.tokens) - 1)


class AdmissionQueue:
    """Bounded FIFO admission queue with reject-with-reason backpressure.

    Thread-safe: ``offer()`` may be called from any thread while the
    engine loop drains the queue.  All mutation happens under one lock;
    the counters are exact.
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.depth = depth
        self._q: deque = deque()
        self._lock = threading.Lock()
        self._closed = False
        self.counters = {"offered": 0, "accepted": 0,
                         "rejected_queue_full": 0, "rejected_overloaded": 0,
                         "rejected_draining": 0, "shed_deadline": 0,
                         "shed_overload": 0, "shed_drain": 0}
        self.max_depth_seen = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Stop accepting new work (graceful drain): every later ``offer``
        is rejected with ``draining``."""
        with self._lock:
            self._closed = True

    def offer(self, req: Request, *,
              overloaded: bool = False) -> Tuple[bool, str]:
        """Try to enqueue.  Returns ``(accepted, reason)`` where ``reason``
        is "" on success.  Rejections are explicit and counted — the queue
        NEVER grows past ``depth``.  Under overload only priority > 0
        requests are admitted (admission degrades, admitted-request
        latency does not)."""
        with self._lock:
            self.counters["offered"] += 1
            if self._closed:
                reason = REJECT_DRAINING
            elif overloaded and req.priority <= 0:
                reason = REJECT_OVERLOADED
            elif len(self._q) >= self.depth:
                reason = REJECT_QUEUE_FULL
            else:
                self._q.append(req)
                self.counters["accepted"] += 1
                self.max_depth_seen = max(self.max_depth_seen, len(self._q))
                req.state = "queued"
                return True, ""
            self.counters[f"rejected_{reason}"] += 1
            req.state, req.detail = "rejected", reason
            return False, reason

    def shed_expired(self, now: float) -> List[Request]:
        """Remove queued requests whose TTFT deadline has already passed —
        they are shed BEFORE consuming a prefill.  Returns the shed
        requests (already marked)."""
        shed = []
        with self._lock:
            keep = deque()
            for req in self._q:
                if req.ttft_deadline_s is not None \
                        and now > req.ttft_deadline_s:
                    req.state, req.detail = "shed", "deadline"
                    self.counters["shed_deadline"] += 1
                    shed.append(req)
                else:
                    keep.append(req)
            self._q = keep
        return shed

    def shed_lowest_priority(self, n: int = 1,
                             reason: str = "overload") -> List[Request]:
        """Drop up to ``n`` queued requests, lowest priority first (ties:
        newest arrival first, so the oldest viable work keeps its place).
        Called by the engine when the governor trips."""
        shed = []
        with self._lock:
            for _ in range(n):
                if not self._q:
                    break
                victim = min(enumerate(self._q),
                             key=lambda iv: (iv[1].priority, -iv[0]))[0]
                req = self._q[victim]
                del self._q[victim]
                req.state, req.detail = "shed", reason
                self.counters[f"shed_{reason}"] += 1
                shed.append(req)
        return shed

    def drain_all(self, reason: str = "drain") -> List[Request]:
        """Empty the queue (shutdown: queued-but-never-admitted work is
        shed, in-flight work finishes)."""
        with self._lock:
            shed = list(self._q)
            self._q.clear()
        for req in shed:
            req.state, req.detail = "shed", reason
            with self._lock:
                self.counters[f"shed_{reason}"] += 1
        return shed

    def take(self) -> Optional[Request]:
        """Pop the oldest queued request (FIFO), or None."""
        with self._lock:
            return self._q.popleft() if self._q else None

    def peek_viable(self) -> bool:
        with self._lock:
            return bool(self._q)


class OverloadGovernor:
    """Step watchdog + overload state machine (docs/TRAFFIC.md).

    States: ``warmup`` (learning the baseline) -> ``nominal`` <->
    ``overloaded``.  A step is a *violation* when it exceeds the absolute
    ``watchdog_s`` (stuck) or ``overload_factor`` x the learned baseline
    (slow).  Every violation trips (returns True from ``observe_step``) so
    the engine sheds lowest-priority queued work immediately; the state
    stays ``overloaded`` — degrading admission — until ``recovery_steps``
    consecutive healthy steps pass.  The baseline EMA only updates on
    healthy steps, so a long overload episode cannot drag the baseline up
    and mask itself.
    """

    def __init__(self, *, watchdog_s: float = 5.0,
                 overload_factor: float = 4.0, warmup_steps: int = 3,
                 recovery_steps: int = 8):
        self.watchdog_s = watchdog_s
        self.overload_factor = overload_factor
        self.warmup_steps = max(1, warmup_steps)
        self.recovery_steps = max(1, recovery_steps)
        self.baseline_s: Optional[float] = None
        self._warm: List[float] = []
        self._healthy = 0
        self.state = "warmup"
        self.counters = {"steps": 0, "stuck_steps": 0, "slow_steps": 0,
                         "trips": 0, "recoveries": 0}

    @property
    def overloaded(self) -> bool:
        return self.state == "overloaded"

    def observe_step(self, dt_s: float) -> bool:
        """Record one step's wall time.  Returns True when the step is a
        violation (the engine should shed queued low-priority work)."""
        self.counters["steps"] += 1
        stuck = dt_s > self.watchdog_s
        if self.baseline_s is None:
            # warmup: even before a baseline exists, the absolute watchdog
            # still catches a stuck step
            if stuck:
                self.counters["stuck_steps"] += 1
                self.counters["trips"] += 1
                self.state = "overloaded"
                self._healthy = 0
                return True
            self._warm.append(dt_s)
            if len(self._warm) >= self.warmup_steps:
                self.baseline_s = sorted(self._warm)[len(self._warm) // 2]
                if self.state == "warmup":
                    self.state = "nominal"
            return False
        slow = dt_s > self.overload_factor * self.baseline_s
        if stuck or slow:
            self.counters["stuck_steps" if stuck else "slow_steps"] += 1
            self.counters["trips"] += 1
            self.state = "overloaded"
            self._healthy = 0
            return True
        self.baseline_s = 0.9 * self.baseline_s + 0.1 * dt_s
        self._healthy += 1
        if self.state == "overloaded" and self._healthy >= self.recovery_steps:
            self.state = "nominal"
            self.counters["recoveries"] += 1
        return False

    def stats(self) -> dict:
        return dict(self.counters, state=self.state,
                    baseline_s=self.baseline_s)
