"""Resilient continuous-batching serving engine (docs/TRAFFIC.md).

The scheduler that turns the fast codec into a serving system: requests
enter a bounded :class:`~repro.runtime.admission.AdmissionQueue`, join a
fixed ring of KV *slots* at token granularity, decode together as one
batched step, and leave individually — completion, deadline eviction, and
fault eviction all happen per request while the rest of the batch keeps
going.

Design points (each is load-bearing for a robustness claim):

* **Slot ring, not per-request caches.**  One KV/state cache of
  ``max_slots`` slots is allocated once (``model.init_cache``); a request
  joins by prefilling alone (batch=1) and scattering its cache into its
  slot, and leaves by having the slot marked free — no reallocation, no
  recompile.  Every model op is row-independent, so slot occupancy cannot
  perturb other rows: engine logits are bit-identical to the one-shot
  path (asserted in tests/test_engine.py across dense/stream/fused).

* **Batch-size buckets bound recompiles.**  The decode step runs on the
  slot prefix ``[0, bucket)`` where ``bucket`` is the smallest power of
  two covering the highest occupied slot (capped at ``max_slots``), so at
  most ``log2(max_slots)+1`` step variants ever compile.

* **Deadlines are enforced at every stage.**  Expired-in-queue requests
  are shed before consuming a prefill; in-flight requests past their
  total deadline are evicted at step granularity with their slot
  reclaimed; a request that completes past its deadline is accounted
  ``timed_out``, never ``done``.

* **Step watchdog + overload governor.**  Step wall times feed the
  :class:`~repro.runtime.admission.OverloadGovernor`; a stuck or slow
  step sheds the lowest-priority queued work immediately, and sustained
  overload degrades *admission* (reject at the door) rather than the
  latency of admitted requests.

* **Serving-time fault tolerance.**  Before each prefill/decode step the
  engine probes ``runtime.faults.check_step(request.key)`` per active
  request under its :class:`~repro.runtime.retry.RetryPolicy` (with the
  request's remaining deadline as the retry budget): transient faults are
  absorbed, permanent ones evict ONLY the poisoned request, survivors
  continue bit-identically, and health transitions to ``degraded`` — not
  ``failed``.

* **Graceful drain.**  ``shutdown(deadline_s)`` refuses new work, sheds
  the queue, finishes in-flight requests until the deadline, and evicts
  stragglers as ``aborted``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.runtime import faults as rt_faults
from repro.runtime.admission import (AdmissionQueue, OverloadGovernor,
                                     Request)
from repro.runtime.retry import RetryPolicy


class EngineError(RuntimeError):
    """Unrecoverable engine failure (invalid request, failed state)."""


class ServerHealth:
    """Readiness/health state of a serving process — the answer to a load
    balancer's probe (docs/RELIABILITY.md, docs/TRAFFIC.md).

    States: ``initializing`` -> ``restoring`` -> ``ready`` | ``degraded``
    (serving with fallback handles or after a fault eviction) |
    ``draining`` (shutdown in progress: in-flight finishes, new work
    refused) -> ``stopped`` | ``failed``.

    Engine-owned and thread-safe: all mutation goes through
    :meth:`transition` under a lock (probes may read from other threads),
    and :meth:`reset` returns a long-lived module-level instance (e.g.
    ``launch.serve.HEALTH``) to a clean slate between embedded runs — the
    old module-global was mutated in place and never reset on exceptions.
    """

    STATES = ("initializing", "restoring", "ready", "degraded", "draining",
              "stopped", "failed")

    def __init__(self, state: str = "initializing", detail: str = ""):
        self._lock = threading.Lock()
        self.state = state
        self.detail = detail

    def transition(self, state: str, detail: str = "") -> None:
        if state not in self.STATES:
            raise ValueError(f"unknown health state {state!r}; "
                             f"expected one of {self.STATES}")
        with self._lock:
            self.state, self.detail = state, detail

    def reset(self) -> None:
        self.transition("initializing", "")

    def ready(self) -> bool:
        """Should a load balancer route traffic here?  Degraded serving is
        still correct serving (logits are bit-identical across handle
        modes) — it answers yes.  Draining/stopped/failed answer no."""
        return self.state in ("ready", "degraded")


@dataclasses.dataclass
class EngineConfig:
    """Static policy of one :class:`Engine` (docs/TRAFFIC.md)."""
    max_slots: int = 4            # concurrency: size of the KV slot ring
    queue_depth: int = 16         # bounded admission queue depth
    max_prompt_len: int = 32
    max_new_tokens: int = 8       # per-request cap (requests may ask less)
    default_ttft_deadline_s: Optional[float] = None
    default_deadline_s: Optional[float] = None
    watchdog_s: float = 5.0       # absolute stuck-step threshold
    overload_factor: float = 4.0  # slow-step threshold (x baseline)
    warmup_steps: int = 3
    recovery_steps: int = 8
    shed_per_trip: int = 1        # queued requests shed per governor trip
    collect_logits: bool = False  # keep per-token logits on each request

    @property
    def max_len(self) -> int:
        return self.max_prompt_len + self.max_new_tokens


def _next_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, capped at ``cap`` (the final bucket)."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class Engine:
    """Continuous-batching request scheduler over the weight-handle
    executor (PR 2) and the Codec API (PR 5).

    The engine is single-driver: one thread calls :meth:`step` /
    :meth:`run_until_idle` / :meth:`shutdown`; :meth:`submit` is
    thread-safe and may be called from anywhere.  All JAX dispatches trace
    under the engine's codec (``use_codec``) plus any extra ambient
    context supplied by the launcher (e.g. a serving mesh).
    """

    def __init__(self, model, params, config: EngineConfig, *,
                 codec=None, retry: Optional[RetryPolicy] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 health: Optional[ServerHealth] = None,
                 extra_context: Optional[Callable] = None,
                 expert_store=None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.config = config
        self.codec = codec
        # the MoE expert-streaming store behind any ExpertRef handles in
        # ``params`` (runtime/experts.py): observed for cache stats and
        # per-step cache-miss decode cost; the fetches themselves happen
        # inside the model's moe_block via io_callback
        self.expert_store = expert_store
        self.clock = clock
        self.sleep = sleep
        self.retry = retry if retry is not None \
            else RetryPolicy(sleep=sleep, clock=clock)
        self.health = health if health is not None else ServerHealth()
        self._extra_context = extra_context

        self.queue = AdmissionQueue(config.queue_depth)
        self.governor = OverloadGovernor(
            watchdog_s=config.watchdog_s,
            overload_factor=config.overload_factor,
            warmup_steps=config.warmup_steps,
            recovery_steps=config.recovery_steps)

        s = config.max_slots
        if s < 1:
            raise ValueError(f"max_slots must be >= 1, got {s}")
        self._slots: List[Optional[Request]] = [None] * s
        self._lengths = np.zeros((s,), np.int32)   # host-authoritative
        self._tokens = np.zeros((s,), np.int32)
        self._entries = None                       # device cache (lazy)
        self._prefill_fns: Dict[int, Callable] = {}
        self._step_fns: Dict[int, Callable] = {}
        self._install_fn = None

        self.results: Dict[int, Request] = {}
        self.counters = {"submitted": 0, "admitted": 0, "done": 0,
                         "timed_out": 0, "rejected": 0, "shed": 0,
                         "evicted_deadline": 0, "evicted_fault": 0,
                         "evicted_abort": 0, "steps": 0, "prefills": 0,
                         "fault_retries": 0}
        self.step_times_s: List[float] = []
        # per decode step: expert-cache MISS decode seconds (0.0 on a
        # fully-resident step) — step_times_s[i] - step_decode_s[i] is the
        # compute-only cost, making the cache-budget latency knob visible
        self.step_decode_s: List[float] = []
        self._draining = False
        # a launcher may hand in a health object already in "degraded"
        # (quarantined restore) — that outranks a plain "ready"
        if not self.health.ready():
            self.health.transition("ready")

    # -- ambient contexts ---------------------------------------------------

    def _trace_ctx(self):
        stack = contextlib.ExitStack()
        if self.codec is not None:
            from repro.core.codec_api import use_codec
            stack.enter_context(use_codec(self.codec))
        if self._extra_context is not None:
            stack.enter_context(self._extra_context())
        return stack

    # -- jit pieces (compiled lazily, bounded variants) ---------------------

    def _ensure_cache(self):
        if self._entries is None:
            cache = self.model.init_cache(self.config.max_slots,
                                          self.config.max_len)
            self._entries = cache["entries"]

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_fns:
            import jax
            import jax.numpy as jnp
            model, max_len = self.model, self.config.max_len

            def fn(params, tokens):
                logits, cache = model.prefill_fn(params, {"tokens": tokens},
                                                 max_len)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                return tok, logits, cache["entries"]

            self._prefill_fns[plen] = jax.jit(fn)
        return self._prefill_fns[plen]

    def _install(self, req_entries, slot: int):
        """Scatter a prefilled batch=1 cache into slot ``slot`` of the
        ring (one compile total: the slot index is a traced scalar)."""
        import jax

        if self._install_fn is None:
            def fn(entries, req_entries, slot):
                return jax.tree.map(
                    lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                        full, part, slot, axis=1),
                    entries, req_entries)

            self._install_fn = jax.jit(fn)
        self._entries = self._install_fn(self._entries, req_entries,
                                         np.int32(slot))

    def _step_fn(self, bucket: int):
        """One fused decode step over slots ``[0, bucket)``: slice the
        ring, decode, argmax, scatter the updated cache back."""
        if bucket not in self._step_fns:
            import jax
            import jax.numpy as jnp
            model = self.model

            def fn(params, entries, tokens, lengths):
                sub = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, 0, bucket, axis=1),
                    entries)
                cache = {"entries": sub, "lengths": lengths[:bucket]}
                logits, new_cache = model.decode_fn(params, cache,
                                                    tokens[:bucket])
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                new_entries = jax.tree.map(
                    lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                        full, part, 0, axis=1),
                    entries, new_cache["entries"])
                return tok, logits, new_entries

            donate = (1,) if jax.default_backend() != "cpu" else ()
            self._step_fns[bucket] = jax.jit(fn, donate_argnums=donate)
        return self._step_fns[bucket]

    # -- submission ---------------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None, *,
               priority: int = 0, ttft_deadline_s: Optional[float] = None,
               deadline_s: Optional[float] = None,
               name: str = "") -> Request:
        """Offer one request.  Deadlines are RELATIVE seconds from now
        (None falls back to the config defaults).  Returns the Request —
        inspect ``.state``: "queued" on admission, "rejected" with
        ``.detail`` naming the reason on backpressure.  Invalid shapes
        (prompt too long for the ring) raise :class:`EngineError`: that is
        a caller bug, not load."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_new = self.config.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        if not 1 <= n_new <= self.config.max_new_tokens:
            raise EngineError(f"max_new_tokens {n_new} outside [1, "
                              f"{self.config.max_new_tokens}]")
        if not 1 <= prompt.size <= self.config.max_prompt_len:
            raise EngineError(f"prompt length {prompt.size} outside [1, "
                              f"{self.config.max_prompt_len}]")
        now = self.clock()
        if ttft_deadline_s is None:
            ttft_deadline_s = self.config.default_ttft_deadline_s
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        req = Request(
            prompt=prompt, max_new_tokens=n_new, priority=priority,
            ttft_deadline_s=None if ttft_deadline_s is None
            else now + ttft_deadline_s,
            deadline_s=None if deadline_s is None else now + deadline_s,
            name=name)
        req.submit_s = now
        self.counters["submitted"] += 1
        self.results[req.rid] = req
        ok, _ = self.queue.offer(req, overloaded=self.governor.overloaded)
        if not ok:
            self.counters["rejected"] += 1
        return req

    # -- lifecycle helpers --------------------------------------------------

    def _active(self) -> List[Request]:
        return [r for r in self._slots if r is not None]

    def _free_slot(self, req: Request) -> None:
        slot = req.slot
        if slot is not None and self._slots[slot] is req:
            self._slots[slot] = None
            self._lengths[slot] = 0   # KV slot reclaimed for reuse
        req.slot = None

    def _finish(self, req: Request, state: str, detail: str = "") -> None:
        req.state, req.detail = state, detail
        req.finish_s = self.clock()
        self._free_slot(req)
        if state == "evicted":
            self.counters[f"evicted_{detail}"] += 1
        elif state in self.counters:
            self.counters[state] += 1

    def _complete(self, req: Request) -> None:
        """All tokens emitted: honest accounting against the deadline —
        a finish past the total deadline is ``timed_out``, not ``done``."""
        now = self.clock()
        late = req.deadline_s is not None and now > req.deadline_s
        self._finish(req, "timed_out" if late else "done")

    def _probe_step_faults(self, now: float) -> None:
        """Per active request: absorb transient step faults through the
        retry policy (budgeted by the request's remaining deadline); a
        permanent fault evicts ONLY the poisoned request and degrades
        health — survivors keep decoding."""
        if rt_faults.active() is None:
            return
        for req in self._active():
            budget = None
            if req.deadline_s is not None:
                budget = max(0.0, req.deadline_s - now)
            before = self.retry.stats()["retries"]
            try:
                self.retry.call(lambda r=req: rt_faults.check_step(r.key),
                                describe=f"step:{req.key}",
                                max_elapsed_s=budget)
            except rt_faults.InjectedFault as e:
                self._finish(req, "evicted", "fault")
                req.detail = "fault"
                self.health.transition(
                    "degraded", f"step fault evicted {req.name}: {e}")
            absorbed = self.retry.stats()["retries"] - before
            req.retries += absorbed
            self.counters["fault_retries"] += absorbed

    def _shed_and_evict(self, now: float) -> None:
        for req in self.queue.shed_expired(now):
            self.counters["shed"] += 1
            req.finish_s = now
        for req in self._active():
            if req.deadline_s is not None and now > req.deadline_s:
                self._finish(req, "evicted", "deadline")

    def _admit(self) -> int:
        """Fill free slots from the queue (lowest slot first, FIFO order);
        each admission = one batch=1 prefill scattered into the ring."""
        admitted = 0
        while not self._draining and self.queue.peek_viable():
            try:
                slot = self._slots.index(None)
            except ValueError:
                break
            req = self.queue.take()
            if req is None:
                break
            now = self.clock()
            if req.deadline_s is not None and now > req.deadline_s:
                req.state, req.detail = "shed", "deadline"
                req.finish_s = now
                self.queue.counters["shed_deadline"] += 1
                self.counters["shed"] += 1
                continue
            # step-fault probe BEFORE the prefill consumes compute
            if rt_faults.active() is not None:
                budget = None if req.deadline_s is None \
                    else max(0.0, req.deadline_s - now)
                before = self.retry.stats()["retries"]
                try:
                    self.retry.call(
                        lambda r=req: rt_faults.check_step(r.key),
                        describe=f"step:{req.key}", max_elapsed_s=budget)
                except rt_faults.InjectedFault as e:
                    req.finish_s = self.clock()
                    req.state, req.detail = "evicted", "fault"
                    self.counters["evicted_fault"] += 1
                    self.health.transition(
                        "degraded",
                        f"step fault evicted {req.name} at admission: {e}")
                    continue
                finally:
                    absorbed = self.retry.stats()["retries"] - before
                    req.retries += absorbed
                    self.counters["fault_retries"] += absorbed
            req.admit_s = self.clock()
            req.slot = slot
            self._slots[slot] = req
            req.state = "running"
            self.counters["admitted"] += 1
            self._run_prefill(req, slot)
            admitted += 1
            if req.finished:
                continue
            if len(req.tokens) >= req.max_new_tokens:
                self._complete(req)
        return admitted

    def _run_prefill(self, req: Request, slot: int) -> None:
        import jax

        self._ensure_cache()
        self.counters["prefills"] += 1
        fn = self._prefill_fn(req.prompt.size)
        with self._trace_ctx():
            tok, logits, req_entries = fn(self.params,
                                          req.prompt[None, :])
            jax.block_until_ready(tok)
            self._install(req_entries, slot)
        req.first_token_s = self.clock()
        t = int(np.asarray(tok)[0])
        req.tokens.append(t)
        self._tokens[slot] = t
        self._lengths[slot] = req.prompt.size
        if self.config.collect_logits:
            req.logits.append(np.asarray(logits)[0])

    def _decode_step(self) -> None:
        import jax

        active = self._active()
        bucket = _next_bucket(max(r.slot for r in active) + 1,
                              self.config.max_slots)
        fn = self._step_fn(bucket)
        dec0 = (self.expert_store.decode_seconds()
                if self.expert_store is not None else 0.0)
        t0 = self.clock()
        with self._trace_ctx():
            # real (non-injected) transient runtime errors ride the same
            # retry policy as checkpoint I/O; a persistent failure poisons
            # the whole batch — evict it and degrade rather than die
            try:
                tok, logits, new_entries = self.retry.call(
                    lambda: fn(self.params, self._entries, self._tokens,
                               self._lengths),
                    describe=f"decode_step:b{bucket}")
                np_tok = np.asarray(tok)
            except OSError as e:
                for req in active:
                    self._finish(req, "evicted", "fault")
                self.health.transition(
                    "degraded", f"decode step failed, batch evicted: {e}")
                return
        self._entries = new_entries
        dt = self.clock() - t0
        self.counters["steps"] += 1
        self.step_times_s.append(dt)
        self.step_decode_s.append(
            (self.expert_store.decode_seconds() - dec0)
            if self.expert_store is not None else 0.0)
        if self.governor.observe_step(dt):
            for req in self.queue.shed_lowest_priority(
                    self.config.shed_per_trip, reason="overload"):
                self.counters["shed"] += 1
                req.finish_s = self.clock()
        np_logits = np.asarray(logits) if self.config.collect_logits \
            else None
        for req in active:
            slot = req.slot
            t = int(np_tok[slot])
            req.tokens.append(t)
            self._tokens[slot] = t
            self._lengths[slot] += 1
            if np_logits is not None:
                req.logits.append(np_logits[slot])
            if len(req.tokens) >= req.max_new_tokens:
                self._complete(req)

    # -- driver -------------------------------------------------------------

    def step(self) -> bool:
        """One scheduler iteration: shed/evict by deadline, probe step
        faults, admit from the queue, run one batched decode step.
        Returns True if any work happened (admission or decode)."""
        if self.health.state == "failed":
            raise EngineError(f"engine failed: {self.health.detail}")
        now = self.clock()
        self._shed_and_evict(now)
        admitted = self._admit()
        self._probe_step_faults(self.clock())
        if not self._active():
            return admitted > 0
        self._decode_step()
        return True

    def has_work(self) -> bool:
        return bool(self._active()) or self.queue.peek_viable()

    def run_until_idle(self, max_steps: Optional[int] = None) -> None:
        """Drive steps until queue and slots are empty (bench/launcher
        loop; submissions may keep arriving from other threads)."""
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                return

    def shutdown(self, deadline_s: Optional[float] = None) -> None:
        """Graceful drain: refuse new work, shed the queue, finish
        in-flight requests; past ``deadline_s`` (relative seconds) the
        stragglers are evicted as ``abort``.  Health: ``draining`` ->
        ``stopped``."""
        self._draining = True
        self.queue.close()
        self.health.transition("draining",
                               f"{len(self._active())} in flight")
        for req in self.queue.drain_all("drain"):
            self.counters["shed"] += 1
            req.finish_s = self.clock()
        abs_deadline = None if deadline_s is None \
            else self.clock() + deadline_s
        while self._active():
            if abs_deadline is not None and self.clock() > abs_deadline:
                for req in self._active():
                    self._finish(req, "evicted", "abort")
                break
            self.step()
        self.health.transition("stopped", "drained")

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        """One dict with every counter a probe, bench, or test needs.
        ``experts`` (present when an expert store is installed) carries
        the LRU hit/miss/eviction/resident-bytes counters next to the
        engine counters."""
        out = {
            "engine": dict(self.counters,
                           compiled_buckets=sorted(self._step_fns),
                           active=len(self._active()),
                           queued=len(self.queue)),
            "queue": dict(self.queue.counters,
                          depth=len(self.queue),
                          max_depth_seen=self.queue.max_depth_seen,
                          cap=self.queue.depth),
            "governor": self.governor.stats(),
            "retry": self.retry.stats(),
            "health": {"state": self.health.state,
                       "detail": self.health.detail},
        }
        if self.expert_store is not None:
            out["experts"] = self.expert_store.stats()
        return out
