"""Subpackage."""
