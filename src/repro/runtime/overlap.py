"""Deterministic double-buffered decode-prefetch pipeline for streamed
serving (paper §VI-C; ROADMAP item "overlapped serving decode").

The serial stream-mode layer loop pays ``decode(l) + matmul(l)`` per
layer: every :class:`~repro.runtime.weights.StreamedWeight` decodes inside
the layer that consumes it.  This module restructures the loop into a
software pipeline with an explicit schedule:

    prologue:  decode layer 0                      (1 batched dispatch set)
    step j:    issue decode of layer min(j+1, P-1) ─┐ independent dataflow,
               run layer j on decoded j            ─┘ the backend overlaps
    (the clamped last-step prefetch keeps every layer inside the scan body
    so logits stay bit-identical to the serial scan — see pipeline_scan)

The scan carry holds exactly ONE layer's decoded weights while the next
layer's decode is in flight — two layers' dense weights live at once
(double-buffering; the carry buffer is reused in place by ``lax.scan``),
never the whole stack.  Steady-state per-layer cost on an asynchronous
backend is ``max(decode, matmul)`` instead of ``decode + matmul``;
benchmarks/bench_overlap.py measures both terms and the achieved ratio
instead of asserting the overlap in a docstring.

Each step's prefetch is ONE batched decode over every streamed leaf of the
layer — O(#decoder buckets per layer) dispatches via
:meth:`Codec.plan_decode` (``exact=True``: the same leaf set decodes every
step, so the block count is padded by zero instead of bucket-rounded) —
never one dispatch per leaf.  Decoded bits are bit-identical to the serial
per-leaf path, and the consumption point is the same canonical tiled
contraction (``resolve`` with ``prefetched=``), so logits with overlap
on/off are bit-identical in every serving mode: only scheduling moves.

Drivers mirror the two layer-loop shapes of ``models/lm.py``:
:func:`pipeline_scan` (compact HLO; compressed streams are closed over in
full and indexed per step with ``dynamic_index_in_dim`` — a shifted-xs scan
would copy the whole compressed stack every step) and
:func:`pipeline_unrolled` (static slices, exact cost_analysis).  The scan
driver modulo-unrolls the pipeline by :data:`SCAN_UNROLL_WINDOW` layers:
inside an unrolled window the prefetch handoff is straight-line dataflow
(the backend fuses decode j+1 with layer j's compute and drops the final
window's dead prefetch), and the decoded-weight carry crosses only window
boundaries — without it, every layer pays a carry round-trip that costs
more than the decode it hides on a synchronous single-stream backend.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.codec_api import current_codec
from repro.runtime.weights import StreamedWeight, is_handle, resolve

OVERLAP_MODES = ("off", "on", "auto")

# Modulo-unroll window of the pipelined scan: layers per merged scan body.
# Within a window the prefetch handoff compiles as straight-line dataflow;
# the decoded double-buffer crosses the loop carry only once per window.
SCAN_UNROLL_WINDOW = 8


def overlap_enabled(mode: str, period) -> bool:
    """Should the layer loop over ``period`` run pipelined?  "off" never;
    "on"/"auto" whenever there is a stream to prefetch (a tree with no
    StreamedWeight leaves has nothing to overlap — dense and fused handles
    decode inside the matmul kernel or not at all)."""
    if mode not in OVERLAP_MODES:
        raise ValueError(f"unknown overlap mode {mode!r}; "
                         f"expected one of {OVERLAP_MODES}")
    if mode == "off":
        return False
    return any(isinstance(leaf, StreamedWeight)
               for leaf in jax.tree.leaves(period, is_leaf=is_handle))


@dataclasses.dataclass
class OverlapSchedule:
    """The static prefetch schedule of one period stack: which flatten
    slots hold streamed weights (the prefetch set), the period structure to
    rebuild slices into, and the per-layer decode-dispatch count the
    pipeline will pay each step (``buckets_per_layer`` — asserted by
    tests/test_overlap.py against the codec's measured dispatch counters).
    """
    leaves: list                 # full-period flatten (is_leaf=is_handle)
    treedef: Any
    slots: Tuple[int, ...]       # indices of StreamedWeight leaves
    n_periods: int
    buckets_per_layer: int


def build_schedule(period, n_periods: int, codec=None) -> OverlapSchedule:
    """Flatten ``period`` (handles as leaves) and record the prefetch
    slots.  Slot indices are computed on the full stacked structure, which
    is identical to every per-layer slice's structure, so the same indices
    address ``resolve(..., prefetched=)`` later."""
    codec = codec or current_codec()
    leaves, treedef = jax.tree_util.tree_flatten(period, is_leaf=is_handle)
    slots = tuple(i for i, leaf in enumerate(leaves)
                  if isinstance(leaf, StreamedWeight))
    keys = {codec._decoder_key(leaves[s].ct.fmt_name, leaves[s].ct.params,
                               leaves[s].ct.block_elems) for s in slots}
    return OverlapSchedule(leaves=leaves, treedef=treedef, slots=slots,
                           n_periods=n_periods,
                           buckets_per_layer=len(keys))


def _take(a, index):
    """Layer ``index`` of a leading-(L,) array: a static slice for Python
    ints, ``dynamic_index_in_dim`` for the traced scan counter."""
    if isinstance(index, int):
        return a[index]
    return jax.lax.dynamic_index_in_dim(a, index, 0, keepdims=False)


def decode_layer(schedule: OverlapSchedule, index, codec=None) -> tuple:
    """ONE batched decode of every streamed leaf's layer ``index`` —
    the per-step prefetch dispatch.  Returns the finished dense weights
    (un-permuted, target dtype) in slot order, bit-identical to
    ``StreamedWeight.materialize`` on the same slice."""
    from repro.runtime.collectives import maybe_gather_ct
    codec = codec or current_codec()
    handles = [schedule.leaves[s] for s in schedule.slots]
    # slice layer `index`, then (under an ambient serving mesh) gather the
    # layer's compressed shards over the mesh axis — the prefetch step's
    # interconnect traffic is this layer's wire payloads, never its dense
    # weights, so overlap composes with sharding
    cts = [maybe_gather_ct(
               dataclasses.replace(
                   h.ct, streams=jax.tree.map(lambda a: _take(a, index),
                                              h.ct.streams)),
               codec)
           for h in handles]
    decs = codec.decompress_stacked_many(cts, exact=True)
    return tuple(
        jnp.moveaxis(d, 0, h.tp_axis).astype(jnp.dtype(h.dtype_str))
        for h, d in zip(handles, decs))


def _resolved_slice(schedule: OverlapSchedule, rest_leaves, decoded,
                    codec=None):
    """Rebuild one period slice from the non-streamed sliced leaves and the
    prefetched decode results, resolved for the layer functions."""
    leaves = list(rest_leaves)
    for s in schedule.slots:
        leaves[s] = schedule.leaves[s]
    tree = jax.tree_util.tree_unflatten(schedule.treedef, leaves)
    return resolve(tree, codec,
                   prefetched=dict(zip(schedule.slots, decoded)))


def _rest_leaves(schedule: OverlapSchedule, index: int) -> list:
    """Static layer slice of every NON-streamed period leaf (plain stacked
    arrays and dense/fused handles alike); prefetch slots stay ``None``."""
    slots = set(schedule.slots)
    return [None if i in slots else jax.tree.map(lambda a: a[index], leaf)
            for i, leaf in enumerate(schedule.leaves)]


def pipeline_scan(schedule: OverlapSchedule, apply_fn: Callable, carry0, *,
                  xs_extra=None, codec=None, wrap: Optional[Callable] = None,
                  unroll: Optional[int] = None):
    """Pipelined ``lax.scan`` over the period stack.

    ``apply_fn(carry, resolved_slice, extra_slice, index) -> (carry, y)``
    runs one period; ``xs_extra`` is an optional per-layer pytree (leading
    ``(P,)`` — e.g. the decode cache entries) sliced alongside; ``wrap``
    (e.g. ``jax.checkpoint``) wraps the scan body.

    The scan runs ALL P layers with the carry holding the CURRENT layer's
    decoded weights and a counter; each body issues layer ``j+1``'s batched
    decode before applying layer ``j``.  The prefetch index is clamped to
    ``P-1`` — the final step re-issues layer P-1's decode (its result is
    discarded with the carry, and dropped as dead code when the window is
    fully unrolled) so that EVERY layer's compute compiles inside the scan
    body: XLA fuses (and therefore rounds) scan-body math differently from
    eagerly inlined math, so an eager epilogue layer would break bit-parity
    with the serial scan.

    The loop is modulo-unrolled by ``unroll`` layers (default
    ``min(P, SCAN_UNROLL_WINDOW)``): inside a window the decode→consume
    handoff is ordinary dataflow the backend schedules and fuses freely;
    the double-buffer rides the loop carry only across window boundaries.
    Returns ``(carry, ys)`` with ``ys`` stacked over all P layers like a
    plain scan's.
    """
    codec = codec or current_codec()
    P = schedule.n_periods
    if unroll is None:
        unroll = min(P, SCAN_UNROLL_WINDOW)
    dec = decode_layer(schedule, 0, codec)
    slots = set(schedule.slots)
    xs_rest = [None if i in slots else leaf
               for i, leaf in enumerate(schedule.leaves)]

    def body(c, xs_j):
        carry, dec_cur, j = c
        rest_j, extra_j = xs_j
        # issue layer j+1's decode BEFORE layer j's compute: the two are
        # independent dataflow, free to overlap on an async backend
        dec_next = decode_layer(schedule, jnp.minimum(j + 1, P - 1), codec)
        carry, y = apply_fn(
            carry, _resolved_slice(schedule, rest_j, dec_cur, codec),
            extra_j, j)
        return (carry, dec_next, j + 1), y

    if wrap is not None:
        body = wrap(body)
    (carry, _, _), ys = jax.lax.scan(
        body, (carry0, dec, jnp.int32(0)), (xs_rest, xs_extra),
        unroll=unroll)
    return carry, ys


def pipeline_unrolled(schedule: OverlapSchedule, apply_fn: Callable, carry0,
                      *, xs_extra=None, codec=None,
                      wrap: Optional[Callable] = None):
    """Pipelined statically-unrolled layer loop (same contract as
    :func:`pipeline_scan`); returns ``(carry, [y_0, ..., y_{P-1}])`` — the
    caller stacks, mirroring the serial unrolled driver."""
    codec = codec or current_codec()
    body = apply_fn if wrap is None else wrap(apply_fn)
    carry, ys = carry0, []
    dec = decode_layer(schedule, 0, codec)
    for i in range(schedule.n_periods):
        dec_next = (decode_layer(schedule, i + 1, codec)
                    if i + 1 < schedule.n_periods else None)
        extra = (None if xs_extra is None
                 else jax.tree.map(lambda a: a[i], xs_extra))
        carry, y = body(
            carry, _resolved_slice(schedule, _rest_leaves(schedule, i),
                                   dec, codec), extra, i)
        ys.append(y)
        dec = dec_next
    return carry, ys
