"""Elastic scaling: rebuild meshes from surviving topology and reshard
state.

Flow on a real fleet: a node failure kills the job -> the scheduler
restarts it on the surviving slice -> ``best_mesh_for`` picks the largest
(data, model) grid the new device count supports (model width capped by
head/ffn divisibility) -> CheckpointManager.load() reshards LATEST onto it
(device_put with the new NamedShardings) -> training resumes at the saved
step.  Nothing in the pipeline depends on world size: data is a pure
function of (seed, step), and ENEC-compressed checkpoints are
layout-agnostic wire bytes.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.launch.mesh import make_mesh


def candidate_grids(n_devices: int, max_model: int = 16):
    """(data, model) factorizations, largest model axis first."""
    out = []
    m = max_model
    while m >= 1:
        if n_devices % m == 0:
            out.append((n_devices // m, m))
        m //= 2
    return out


def best_mesh_for(cfg, n_devices: Optional[int] = None, max_model: int = 16):
    """Largest usable (data, model) mesh for this arch on the surviving
    devices. Model axis must divide the TP-sharded dims actually used."""
    n = n_devices if n_devices is not None else len(jax.devices())
    hd_total = cfg.n_heads * cfg.head_dim_()
    for data, model in candidate_grids(n, max_model):
        divisible = (hd_total % model == 0
                     and (cfg.d_ff % model == 0 or cfg.d_ff == 0)
                     and (cfg.n_experts % model == 0 or cfg.n_experts == 0))
        if divisible:
            return make_mesh((data, model), ("data", "model"))
    return make_mesh((n,), ("data",))


def reshard(tree, mesh, pspecs):
    """Move existing (host or device) state onto a new mesh."""
    from jax.sharding import NamedSharding
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                             is_leaf=lambda x: hasattr(x, "spec") or
                             type(x).__name__ == "PartitionSpec")
    return jax.device_put(tree, shardings)
