"""Lossless ENEC gradient sync across the slow (cross-pod DCI) axis.

At multi-pod scale the cross-pod all-reduce of gradients rides links an
order of magnitude slower than in-pod ICI.  Because ENEC is lossless, the
sync below is *bit-identical* to a plain all-reduce up to f32 summation
order — no accuracy/convergence caveats, unlike lossy 1-bit/top-k schemes.

Pattern (inside shard_map over the "pod" axis):
    local grads (already reduced within pod by the in-pod program)
      -> ENEC-encode (block streams, fixed-shape pytree)
      -> all_gather over "pod" (compressed bytes on the wire: ~1/ratio)
      -> decode both pods' streams locally, sum.

Gradient exponents are highly skewed (same §III statistics as weights), so
ratios land in the 1.3-1.5x range for bf16 grads — that much less DCI
traffic on every step.

``compressed_allreduce`` is the shard_map-ready primitive; tests run it on
a toy 2-pod host mesh and assert bit-identity with jax.lax.psum.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.codec_api import current_codec
from repro.core.dtypes import format_for
from repro.core.params import EnecParams


def compressed_allreduce(x, axis_name: str, p: EnecParams,
                         block_elems: int = 16384):
    """All-reduce ``x`` over ``axis_name`` with ENEC-compressed transport.

    Must run inside shard_map/vmap with ``axis_name`` bound.  ``p`` is the
    pre-searched codec parameterization (search offline on a gradient
    sample; §VI-E transferability applies).
    """
    fmt = format_for(x.dtype)
    bits = codec.to_blocks(x, fmt, block_elems)
    streams = codec.encode_blocks(bits, fmt, p)
    gathered = jax.tree.map(
        lambda a: jax.lax.all_gather(a, axis_name), streams)
    n = gathered.mask.shape[0]
    # ledger: each pod ships its local streams to the n-1 peers — only
    # compressed bytes ride the slow axis (counted once per trace; the
    # schedule is static, so per-step traffic = counted bytes x steps)
    leaves = jax.tree.leaves(streams)
    current_codec().count_link(
        "d2d_psum",
        (n - 1) * sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                      for a in leaves),
        ops=len(leaves))

    total = jnp.zeros(x.shape, jnp.float32)
    for i in range(n):  # static pod count (2): unrolled decode+sum
        s_i = jax.tree.map(lambda a: a[i], gathered)
        bits_i = codec.decode_blocks(s_i, block_elems, fmt, p)
        x_i = codec.from_blocks(bits_i, x.shape, fmt)
        total = total + x_i.astype(jnp.float32)
    return total.astype(x.dtype)


def wire_bytes_saved(x, p: EnecParams) -> dict:
    """Estimate of per-step cross-pod traffic with/without compression."""
    fmt = format_for(x.dtype)
    raw = x.size * x.dtype.itemsize
    comp = raw / max(fmt.total_bits /
                     (p.expected_bits + fmt.raw_bits), 1e-9) \
        if p.expected_bits else raw
    return {"raw_bytes": raw, "compressed_bytes": int(comp),
            "ratio": raw / max(comp, 1)}
