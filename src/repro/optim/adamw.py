"""AdamW with f32 master moments over bf16 params + gradient clipping and
accumulation.  Pure-JAX (no optax in this environment); state is a pytree
sharded exactly like the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable] = None  # step -> lr multiplier


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, state: AdamWState, grads):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    step = state.step + 1
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule else 1.0)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm,
                                                   "lr": lr}


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                            0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup, warm, cos)
    return sched
