"""Subpackage."""
