"""Shared transformer primitives: norms, rotary, attention (GQA + qk-norm),
gated MLPs, embeddings, KV caches.

Conventions
-----------
* activations bf16, matmuls accumulate f32 (``preferred_element_type``),
  norms/softmax/losses in f32;
* weights live in bf16 (the ENEC compression target), optimizer keeps f32
  master copies;
* attention over long sequences uses a *statically unrolled* streaming
  softmax over KV chunks (flash-style) so the dry-run's HLO carries the true
  FLOP/byte counts (while-loop bodies are counted once by cost_analysis) and
  peak memory stays O(T * chunk);
* every function is shape-polymorphic over leading batch dims and jit/pjit
  friendly (no data-dependent shapes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.weights import WeightHandle

ACT_DTYPE = jnp.bfloat16
KV_CHUNK = 2048  # flash chunk; statically unrolled (<= 32 iterations at 32k)

import os as _os


def weight_matmul(w, x, eq: str):
    """Contract x's last axis against the (K, N) weight ``w``.

    ``w`` may be a WeightHandle (serve-time weight-execution modes: dense /
    streamed / fused all realize the same canonical tiled contraction, so
    logits are bit-identical across modes) or a plain array, which keeps the
    legacy einsum path — train and raw-params serving are untouched.
    """
    if isinstance(w, WeightHandle):
        lead = x.shape[:-1]
        out = w.matmul(x.reshape(-1, x.shape[-1]))
        return out.reshape(lead + (out.shape[-1],))
    return jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)


def safe_einsum(eq, a, b):
    """einsum with f32 accumulation.

    XLA:CPU's DotThunk cannot *execute* some batched bf16xbf16->f32 dots
    (compilation/lowering is fine — the dry-run is unaffected).  When running
    on CPU outside the dry-run we up-cast operands; on TPU the native
    mixed-precision dot is used.  Set REPRO_DRYRUN=1 to keep bf16 operands in
    the lowered HLO (exact byte accounting).
    """
    if jax.default_backend() == "cpu" and not _os.environ.get("REPRO_DRYRUN"):
        return jnp.einsum(eq, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.einsum(eq, a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=ACT_DTYPE):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=ACT_DTYPE):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float = 1e6):
    """x: (..., T, H, hd), positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnParamsShape:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False


def init_attention(key, s: AttnParamsShape, dtype=ACT_DTYPE):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (s.d_model, s.n_heads * s.head_dim), 0, dtype),
        "wk": dense_init(ks[1], (s.d_model, s.n_kv_heads * s.head_dim), 0, dtype),
        "wv": dense_init(ks[2], (s.d_model, s.n_kv_heads * s.head_dim), 0, dtype),
        "wo": dense_init(ks[3], (s.n_heads * s.head_dim, s.d_model), 0, dtype),
    }
    if s.qk_norm:
        p["q_norm"] = jnp.zeros((s.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((s.head_dim,), dtype)
    return p


def _project_qkv(p, x, s: AttnParamsShape, positions, theta):
    b, t, _ = x.shape
    q = weight_matmul(p["wq"], x, "btd,dh->bth")
    k = weight_matmul(p["wk"], x, "btd,dh->bth")
    v = weight_matmul(p["wv"], x, "btd,dh->bth")
    q = q.reshape(b, t, s.n_heads, s.head_dim).astype(ACT_DTYPE)
    k = k.reshape(b, t, s.n_kv_heads, s.head_dim).astype(ACT_DTYPE)
    v = v.reshape(b, t, s.n_kv_heads, s.head_dim).astype(ACT_DTYPE)
    if s.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _chunk_scores(q, k, scale):
    """q (B,Tq,H,hd) x k (B,S,KV,hd) -> (B,H,Tq,S) f32, GQA via reshape."""
    b, tq, h, hd = q.shape
    kv = k.shape[2]
    grp = h // kv
    qg = q.reshape(b, tq, kv, grp, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                   preferred_element_type=jnp.float32) * scale
    return s.reshape(b, kv * grp, tq, s.shape[-1])


def _chunk_out(probs, v, h):
    """probs (B,H,Tq,S) x v (B,S,KV,hd) -> (B,Tq,H,hd)."""
    b, _, tq, s_len = probs.shape
    kv = v.shape[2]
    grp = h // kv
    pg = probs.reshape(b, kv, grp, tq, s_len)
    out = jnp.einsum("bkgts,bskh->btkgh", pg, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, tq, h, v.shape[-1])


def flash_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    prefix_len: int = 0, chunk: int = KV_CHUNK):
    """Streaming-softmax attention, statically unrolled over KV chunks.

    q: (B, Tq, H, hd); k, v: (B, S, KV, hd).  ``causal`` applies a causal
    mask with the query positions offset by ``q_offset`` relative to keys;
    positions < ``prefix_len`` are always visible (PaliGemma prefix-LM).
    """
    b, tq, h, hd = q.shape
    s_total = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, s_total)
    n_chunks = (s_total + chunk - 1) // chunk

    m = jnp.full((b, h, tq, 1), -jnp.inf, jnp.float32)
    denom = jnp.zeros((b, h, tq, 1), jnp.float32)
    acc = jnp.zeros((b, tq, h, hd), jnp.float32)
    q_pos = q_offset + jnp.arange(tq)[:, None]

    for c in range(n_chunks):
        lo = c * chunk
        hi = min(lo + chunk, s_total)
        kc, vc = k[:, lo:hi], v[:, lo:hi]
        scores = _chunk_scores(q, kc, scale)  # (B,H,Tq,hi-lo) f32
        if causal:
            k_pos = lo + jnp.arange(hi - lo)[None, :]
            visible = (k_pos <= q_pos) | (k_pos < prefix_len)
            scores = jnp.where(visible[None, None], scores, -jnp.inf)
        m_new = jnp.maximum(m, scores.max(axis=-1, keepdims=True))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(scores - m_safe)
        p = jnp.where(jnp.isfinite(scores), p, 0.0)
        correction = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        correction = jnp.where(jnp.isfinite(m), correction, 0.0)
        denom = denom * correction + p.sum(axis=-1, keepdims=True)
        acc = acc * correction.squeeze(-1).transpose(0, 2, 1)[..., None] \
            + _chunk_out(p.astype(ACT_DTYPE), vc, h)
        m = m_new
    denom = jnp.maximum(denom, 1e-30)
    out = acc / denom.squeeze(-1).transpose(0, 2, 1)[..., None]
    return out.astype(ACT_DTYPE)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     score_shard: bool = False):
    """Single-token decode: q (B, 1, H, hd) over caches (B, S, KV, hd).

    ``lengths``: (B,) int32 — number of valid cache entries per sequence.
    ``score_shard`` pins the (B, H, 1, S) score chain S-sharded on "model"
    (flash-decoding style): local max/exp/sum + tiny stat all-reduces
    instead of SPMD rematerializing full-length f32 scores (§Perf).
    """
    b, _, h, hd = q.shape
    s_len = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = _chunk_scores(q, k_cache, scale)  # (B, H, 1, S)
    k_pos = jnp.arange(s_len)[None, None, None, :]
    bias = jnp.where(k_pos < lengths[:, None, None, None], 0.0, -1e30)
    scores = scores + bias  # additive mask: one fused add, no select chain

    def pin(x):
        if not score_shard:
            return x
        from jax.sharding import PartitionSpec as _P
        return jax.lax.with_sharding_constraint(
            x, _P(None, None, None, "model"))

    scores = pin(scores)
    m = pin(jnp.max(scores, axis=-1, keepdims=True))
    p = pin(jnp.exp(scores - jax.lax.stop_gradient(m)))
    denom = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / denom
    return _chunk_out(probs.astype(ACT_DTYPE), v_cache, h)


def attention_block(p, x, s: AttnParamsShape, positions, theta, *,
                    causal=True, prefix_len=0, chunk=KV_CHUNK):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _project_qkv(p, x, s, positions, theta)
    out = flash_attention(q, k, v, causal=causal, prefix_len=prefix_len,
                          chunk=chunk)
    out = weight_matmul(p["wo"], out.reshape(x.shape[0], x.shape[1], -1),
                        "btf,fd->btd").astype(x.dtype)
    return out, (k, v)


def attention_decode_block(p, x, s: AttnParamsShape, cache_kv, lengths,
                           theta, score_shard: bool = False):
    """One-token decode step. x: (B, 1, D). cache_kv: (k, v) (B, S, KV, hd).

    Writes the new k/v at position ``lengths`` per sequence, then attends.
    """
    k_cache, v_cache = cache_kv
    positions = lengths[:, None]  # (B, 1) — rope position of the new token
    q, k_new, v_new = _project_qkv(p, x, s, positions, theta)
    b = x.shape[0]
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, lengths].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, lengths].set(v_new[:, 0])
    out = decode_attention(q, k_cache, v_cache, lengths + 1,
                           score_shard=score_shard)
    out = weight_matmul(p["wo"], out.reshape(b, 1, -1),
                        "btf,fd->btd").astype(x.dtype)
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, s: AttnParamsShape, dtype=ACT_DTYPE):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (s.d_model, s.n_heads * s.head_dim), 0, dtype),
        "wk": dense_init(ks[1], (s.d_model, s.n_kv_heads * s.head_dim), 0, dtype),
        "wv": dense_init(ks[2], (s.d_model, s.n_kv_heads * s.head_dim), 0, dtype),
        "wo": dense_init(ks[3], (s.n_heads * s.head_dim, s.d_model), 0, dtype),
    }


def cross_attention_block(p, x, memory_kv, s: AttnParamsShape):
    """x: (B, T, D) queries over precomputed encoder memory (k, v)."""
    b, t, _ = x.shape
    k, v = memory_kv
    q = jnp.einsum("btd,dh->bth", x, p["wq"],
                   preferred_element_type=jnp.float32)
    q = q.reshape(b, t, s.n_heads, s.head_dim).astype(ACT_DTYPE)
    out = flash_attention(q, k, v, causal=False)
    return jnp.einsum("btf,fd->btd", out.reshape(b, t, -1), p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def cross_memory(p, enc_out, s: AttnParamsShape):
    """Precompute encoder-side K/V once per sequence."""
    b, t, _ = enc_out.shape
    k = jnp.einsum("btd,dh->bth", enc_out, p["wk"],
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("btd,dh->bth", enc_out, p["wv"],
                   preferred_element_type=jnp.float32)
    k = k.reshape(b, t, s.n_kv_heads, s.head_dim).astype(ACT_DTYPE)
    v = v.reshape(b, t, s.n_kv_heads, s.head_dim).astype(ACT_DTYPE)
    return k, v


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=ACT_DTYPE):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), 0, dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), 0, dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), 0, dtype),
    }


def mlp_block(p, x, activation: str = "silu"):
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    g = weight_matmul(p["w_gate"], x, "btd,df->btf")
    u = weight_matmul(p["w_up"], x, "btd,df->btf")
    h = (act(g) * u).astype(ACT_DTYPE)
    return weight_matmul(p["w_down"], h, "btf,fd->btd").astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / head / loss
# ---------------------------------------------------------------------------

def embed_tokens(embedding, tokens):
    return jnp.take(embedding, tokens, axis=0).astype(ACT_DTYPE)


def lm_logits(x, head):
    """x (B, T, D) @ head (D, V) -> f32 logits."""
    return jnp.einsum("btd,dv->btv", x, head,
                      preferred_element_type=jnp.float32)


def cross_entropy(logits, targets, mask=None):
    """Mean next-token NLL in f32. logits (B,T,V), targets (B,T) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
