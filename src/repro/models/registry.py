"""Build any assigned architecture behind one functional interface.

``build_model(cfg)`` returns a :class:`Model` with pure functions that close
over the config — ready for ``jax.jit`` / pjit with shardings from
``repro.runtime.sharding``.  ``input_specs(cfg, shape)`` produces the
ShapeDtypeStruct stand-ins for every input of the chosen cell (the dry-run
contract: weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

from . import encdec, lm


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable            # rng -> params
    loss_fn: Callable         # (params, batch) -> (loss, metrics)
    prefill_fn: Callable      # (params, batch, max_len) -> (logits, cache)
    decode_fn: Callable       # (params, cache, tokens) -> (logits, cache)
    init_cache: Callable      # (batch, max_len) -> cache (zeros, static)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.is_encdec:
        return Model(
            cfg=cfg,
            init=partial(encdec.init_params, cfg=cfg),
            loss_fn=lambda params, batch: encdec.loss_fn(params, cfg, batch),
            prefill_fn=lambda params, batch, max_len: encdec.prefill(
                params, cfg, batch["frames"], batch["tokens"], max_len),
            decode_fn=lambda params, cache, tokens: encdec.decode_step(
                params, cfg, cache, tokens),
            init_cache=lambda batch, max_len, enc_len=4096: encdec.init_cache(
                cfg, batch, max_len, enc_len),
        )
    # weight-execution handles (runtime/weights.py) in ``params`` resolve
    # inside the model — no decompressor hook to thread through
    return Model(
        cfg=cfg,
        init=partial(lm.init_params, cfg=cfg),
        loss_fn=lambda params, batch: lm.loss_fn(params, cfg, batch),
        prefill_fn=lambda params, batch, max_len: lm.prefill_fn(
            params, cfg, batch, max_len),
        decode_fn=lambda params, cache, tokens: lm.decode_fn(
            params, cfg, cache, tokens),
        init_cache=lambda batch, max_len: lm.init_cache(cfg, batch, max_len),
    )


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


ENC_FRAMES_STUB = 4096  # encoder frames for whisper serving cells


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    train  : token/target batches (+ modality prefix stubs)
    prefill: prompt tokens (+ stubs)
    decode : one new token per sequence + the KV/state cache
    """
    b, t = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        if cfg.is_encdec:
            specs["frames"] = _sds((b, t, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = _sds((b, t), jnp.int32)
            specs["targets"] = _sds((b, t), jnp.int32)
        else:
            t_text = t - cfg.prefix_embed
            specs["tokens"] = _sds((b, t_text), jnp.int32)
            specs["targets"] = _sds((b, t_text), jnp.int32)
            if cfg.prefix_embed:
                specs["prefix_embeds"] = _sds((b, cfg.prefix_embed,
                                               cfg.d_model), jnp.bfloat16)
    elif shape.kind == "prefill":
        if cfg.is_encdec:
            specs["frames"] = _sds((b, t, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = _sds((b, t), jnp.int32)
        else:
            t_text = t - cfg.prefix_embed
            specs["tokens"] = _sds((b, t_text), jnp.int32)
            if cfg.prefix_embed:
                specs["prefix_embeds"] = _sds((b, cfg.prefix_embed,
                                               cfg.d_model), jnp.bfloat16)
    elif shape.kind == "decode":
        specs["tokens"] = _sds((b,), jnp.int32)
        specs["cache"] = cache_specs(cfg, b, t)
    else:
        raise ValueError(shape.kind)
    return specs


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """Abstract cache pytree (ShapeDtypeStructs) for decode lowering."""
    model = build_model(cfg)
    if cfg.is_encdec:
        shapes = jax.eval_shape(
            lambda: model.init_cache(batch, max_len, ENC_FRAMES_STUB))
    else:
        shapes = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    return shapes


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def param_count(cfg: ArchConfig) -> int:
    import math
    tree = abstract_params(cfg)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def active_param_count(cfg: ArchConfig) -> int:
    """Active-per-token params (MoE: top-k experts only) for 6*N_active*D."""
    import math
    total = param_count(cfg)
    if cfg.n_experts:
        # subtract inactive expert params
        tree = abstract_params(cfg)
        expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if any(str(k).startswith("e_") for k in keys):
                expert += math.prod(leaf.shape)
        active_frac = cfg.experts_per_token / cfg.n_experts
        total = total - expert + int(expert * active_frac)
    return total
