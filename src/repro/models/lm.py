"""Unified decoder-only LM covering the dense / MoE / SSM-hybrid / xLSTM /
prefix-VLM families via per-period "block programs".

A *block program* is a list of per-layer descriptors, one period long; the
model is ``n_periods`` repetitions of it (Jamba: period 8 with one attention
layer and alternating MoE; xLSTM: period 4 = [m, m, m, s]; dense/MoE
transformers: period 1).  Parameters of each program position are stacked
over periods, so the layer stack runs either as ``lax.scan`` (compact HLO,
fast compile — runtime default) or as a statically unrolled Python loop
(exact cost_analysis — the dry-run's choice for small models, with the
scan-correction protocol of launch/roofline.py for the big ones).

Interface (all pure functions, pjit-ready):
  init(rng) -> params
  loss_fn(params, batch) -> (loss, metrics)
  prefill_fn(params, batch) -> (last_logits, cache)
  decode_fn(params, cache, tokens) -> (logits, cache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.runtime.overlap import (build_schedule, overlap_enabled,
                                   pipeline_scan, pipeline_unrolled)
from repro.runtime.weights import is_handle
from repro.runtime.weights import resolve as resolve_weights

from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .layers import (ACT_DTYPE, AttnParamsShape, attention_block,
                     attention_decode_block, cross_entropy, dense_init,
                     embed_init, embed_tokens, init_attention, init_mlp,
                     lm_logits, mlp_block, rms_norm)


class BlockDesc(NamedTuple):
    seq: str          # attn | mamba | mlstm | slstm
    ffn: Optional[str]  # mlp | moe | None


def block_program(cfg) -> list:
    """cfg -> list[BlockDesc] (one period)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        return [BlockDesc("attn", "mlp")]
    if fam == "moe":
        return [BlockDesc("attn", "moe")]
    if fam == "ssm":      # xLSTM 3:1 mLSTM:sLSTM
        return [BlockDesc("mlstm", None), BlockDesc("mlstm", None),
                BlockDesc("mlstm", None), BlockDesc("slstm", None)]
    if fam == "hybrid":   # Jamba: attn 1-of-8, MoE every other layer
        out = []
        for i in range(8):
            seq = "attn" if i == 4 else "mamba"
            ffn = "moe" if i % 2 == 1 else "mlp"
            out.append(BlockDesc(seq, ffn))
        return out
    raise ValueError(fam)


def attn_shape(cfg) -> AttnParamsShape:
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    return AttnParamsShape(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                           cfg.qk_norm)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_position(key, desc: BlockDesc, cfg):
    ks = jax.random.split(key, 4)
    p = {"pre_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE)}
    if desc.seq == "attn":
        p["attn"] = init_attention(ks[0], attn_shape(cfg))
    elif desc.seq == "mamba":
        p["mamba"] = ssm_lib.init_mamba(ks[0], cfg.d_model, cfg.ssm_state,
                                        cfg.conv_dim)
    elif desc.seq == "mlstm":
        p["mlstm"] = xlstm_lib.init_mlstm(ks[0], cfg.d_model, cfg.n_heads)
    elif desc.seq == "slstm":
        p["slstm"] = xlstm_lib.init_slstm(ks[0], cfg.d_model, cfg.n_heads)
    if desc.ffn is not None:
        p["post_norm"] = jnp.zeros((cfg.d_model,), ACT_DTYPE)
    if desc.ffn == "mlp":
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    elif desc.ffn == "moe":
        p["moe"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.moe_d_ff,
                                    cfg.n_experts)
    return p


def init_params(key, cfg):
    program = block_program(cfg)
    n_periods = cfg.n_layers // len(program)
    ks = jax.random.split(key, n_periods + 3)
    period = []
    for pos, desc in enumerate(program) if n_periods else []:
        stacks = [
            _init_position(jax.random.fold_in(ks[i], pos), desc, cfg)
            for i in range(n_periods)
        ]
        period.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacks))
    params = {
        "embed": embed_init(ks[-1], (cfg.vocab_size, cfg.d_model)),
        "period": period,
        "final_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[-2], (cfg.d_model, cfg.vocab_size))
    return params


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def _apply_position(p, desc: BlockDesc, cfg, x, positions, *,
                    prefix_len: int = 0):
    """Full-sequence forward of one block. Returns (x, cache_entry, aux)."""
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    cache_entry = None
    if desc.seq == "attn":
        out, kv = attention_block(p["attn"], h, attn_shape(cfg), positions,
                                  cfg.rope_theta, causal=True,
                                  prefix_len=prefix_len,
                                  chunk=cfg.attn_chunk)
        cache_entry = {"k": kv[0], "v": kv[1]}
    elif desc.seq == "mamba":
        out, cache_entry = ssm_lib.mamba_forward(p["mamba"], h, cfg.ssm_state,
                                                 cfg.conv_dim)
    elif desc.seq == "mlstm":
        out, cache_entry = xlstm_lib.mlstm_forward(p["mlstm"], h, cfg.n_heads)
    elif desc.seq == "slstm":
        out, cache_entry = xlstm_lib.slstm_forward(p["slstm"], h)
    x = x + out
    aux = {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0)}
    if desc.ffn is not None:
        h = rms_norm(x, p["post_norm"], cfg.norm_eps)
        if desc.ffn == "mlp":
            x = x + mlp_block(p["mlp"], h)
        else:
            out, aux = moe_lib.moe_block(p["moe"], h, cfg.experts_per_token,
                                         cfg.moe_combine_dtype,
                                         cfg.moe_dispatch_a2a)
            x = x + out
    return x, cache_entry, aux


def _apply_position_step(p, desc: BlockDesc, cfg, x, cache, lengths):
    """One-token decode of one block. Returns (x, new_cache_entry, aux)."""
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if desc.seq == "attn":
        out, kv = attention_decode_block(
            p["attn"], h, attn_shape(cfg), (cache["k"], cache["v"]),
            lengths, cfg.rope_theta, score_shard=cfg.decode_score_shard)
        new_cache = {"k": kv[0], "v": kv[1]}
    elif desc.seq == "mamba":
        out, new_cache = ssm_lib.mamba_step(p["mamba"], h, cache,
                                            cfg.ssm_state)
    elif desc.seq == "mlstm":
        out, new_cache = xlstm_lib.mlstm_step(p["mlstm"], h, cache,
                                              cfg.n_heads)
    elif desc.seq == "slstm":
        out, new_cache = xlstm_lib.slstm_step(p["slstm"], h, cache)
    x = x + out
    if desc.ffn == "mlp":
        x = x + mlp_block(p["mlp"], rms_norm(x, p["post_norm"], cfg.norm_eps))
    elif desc.ffn == "moe":
        out, _ = moe_lib.moe_block(p["moe"], rms_norm(x, p["post_norm"],
                                                      cfg.norm_eps),
                                   cfg.experts_per_token,
                                   cfg.moe_combine_dtype,
                                   cfg.moe_dispatch_a2a)
        x = x + out
    return x, new_cache


# ---------------------------------------------------------------------------
# layer-stack drivers (scan or unrolled)
# ---------------------------------------------------------------------------

def _remat_policy(cfg):
    return {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]


def _dense_leaf(leaf):
    """Materialize a top-level weight handle (the policy may stream even
    non-stacked 2-D leaves like ``embed``/``head`` as L=1 stacks); plain
    arrays pass through."""
    return leaf.materialize() if is_handle(leaf) else leaf


def _wrap_body(cfg, body):
    return jax.checkpoint(body, prevent_cse=False,
                          policy=_remat_policy(cfg)) if cfg.remat else body


def _run_stack(params, cfg, x, positions, *, prefix_len=0, want_cache=False):
    """Forward through all periods. Returns (x, caches, aux_sum).

    Weight-execution handles (runtime/weights.py) in the period stack are
    resolved per layer slice.  When the overlap policy is active
    (``cfg.overlap`` + streamed leaves present), the loop runs as the
    double-buffered prefetch pipeline of ``runtime.overlap`` — layer l+1's
    batched decode is issued before layer l's matmuls; otherwise streams
    decode serially inside their own layer.  Logits are bit-identical
    either way (only scheduling moves).
    """
    program = block_program(cfg)
    n_periods = cfg.n_layers // len(program)
    period = params["period"]
    if n_periods == 0:  # 0-layer variant used by the dry-run cost protocol
        return x, None, jnp.float32(0)

    def period_body(x, sliced):
        aux_sum = jnp.float32(0)
        caches = []
        for pos, desc in enumerate(program):
            p = resolve_weights(sliced[pos])
            x, cache_entry, aux = _apply_position(
                p, desc, cfg, x, positions, prefix_len=prefix_len)
            caches.append(cache_entry)
            aux_sum = aux_sum + aux["lb_loss"] + 1e-3 * aux["z_loss"]
        return x, caches, aux_sum

    if overlap_enabled(getattr(cfg, "overlap", "auto"), period):
        schedule = build_schedule(period, n_periods)

        def apply_fn(carry, sliced, _extra, _i):
            x, aux_acc = carry
            x, caches, aux = period_body(x, sliced)
            out = [c for c in caches if c is not None] if want_cache else None
            return (x, aux_acc + aux), out

        if cfg.scan_layers:
            (x, aux_sum), caches = pipeline_scan(
                schedule, apply_fn, (x, jnp.float32(0)),
                wrap=partial(_wrap_body, cfg))
            return x, caches, aux_sum
        (x, aux_sum), cache_list = pipeline_unrolled(
            schedule, apply_fn, (x, jnp.float32(0)),
            wrap=partial(_wrap_body, cfg))
        if want_cache and cache_list and cache_list[0]:
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
        else:
            caches = None
        return x, caches, aux_sum

    if cfg.scan_layers:
        def scan_body(carry, sliced):
            x, aux_acc = carry
            x, caches, aux = period_body(x, sliced)
            out = [c for c in caches if c is not None] if want_cache else None
            return (x, aux_acc + aux), out

        body = _wrap_body(cfg, scan_body)
        (x, aux_sum), stacked = jax.lax.scan(body, (x, jnp.float32(0)), period)
        caches = stacked
    else:
        aux_sum = jnp.float32(0)
        cache_list = []
        body = _wrap_body(cfg, period_body)
        for i in range(n_periods):
            sliced = jax.tree.map(lambda a: a[i], period)
            x, caches_i, aux = body(x, sliced)
            cache_list.append([c for c in caches_i if c is not None])
            aux_sum = aux_sum + aux
        if want_cache and cache_list and cache_list[0]:
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
        else:
            caches = None
    return x, caches, aux_sum


def _assemble_inputs(params, cfg, batch):
    """tokens (+ optional modality prefix embeddings) -> (x, positions,
    prefix_len)."""
    x = embed_tokens(_dense_leaf(params["embed"]), batch["tokens"])
    prefix_len = 0
    if cfg.prefix_embed and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(ACT_DTYPE)
        x = jnp.concatenate([pe, x], axis=1)
        prefix_len = pe.shape[1]
    positions = jnp.arange(x.shape[1])[None, :]
    return x, positions, prefix_len


def forward(params, cfg, batch, *, want_cache=False):
    x, positions, prefix_len = _assemble_inputs(params, cfg, batch)
    x, caches, aux = _run_stack(params, cfg, x, positions,
                                prefix_len=prefix_len, want_cache=want_cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (_dense_leaf(params["embed"]).T if cfg.tie_embeddings
            else _dense_leaf(params["head"]))
    return x, caches, aux, head, prefix_len


def loss_fn(params, cfg, batch):
    x, _, aux, head, prefix_len = forward(params, cfg, batch)
    logits = lm_logits(x[:, prefix_len:], head)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    loss = cross_entropy(logits[:, :-1], targets[:, 1:],
                         None if mask is None else mask[:, 1:])
    total = loss + 1e-2 * aux
    return total, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    """Abstract-friendly cache init (all zeros; shapes static)."""
    program = block_program(cfg)
    n_periods = cfg.n_layers // len(program)
    s = attn_shape(cfg)
    entries = []
    for desc in program:
        if desc.seq == "attn":
            e = {"k": jnp.zeros((n_periods, batch, max_len, s.n_kv_heads,
                                 s.head_dim), ACT_DTYPE),
                 "v": jnp.zeros((n_periods, batch, max_len, s.n_kv_heads,
                                 s.head_dim), ACT_DTYPE)}
        elif desc.seq == "mamba":
            c = ssm_lib.init_mamba_cache(cfg.d_model, cfg.ssm_state,
                                         cfg.conv_dim, batch)
            e = jax.tree.map(lambda a: jnp.stack([a] * n_periods), c)
        elif desc.seq == "mlstm":
            c = xlstm_lib.init_mlstm_cache(cfg.d_model, cfg.n_heads, batch)
            e = jax.tree.map(lambda a: jnp.stack([a] * n_periods), c)
        elif desc.seq == "slstm":
            c = xlstm_lib.init_slstm_cache(cfg.d_model, batch)
            e = jax.tree.map(lambda a: jnp.stack([a] * n_periods), c)
        entries.append(e)
    return {"entries": entries, "lengths": jnp.zeros((batch,), jnp.int32)}


def prefill_fn(params, cfg, batch, max_len: int):
    """Run the prompt, build the cache. Returns (last_token_logits, cache)."""
    x, caches, _, head, prefix_len = forward(params, cfg, batch,
                                             want_cache=True)
    b, t = x.shape[0], x.shape[1]
    logits = lm_logits(x[:, -1:], head)[:, 0]  # forward() already normed x
    cache = init_cache(cfg, b, max_len)
    # install prefill state: attn K/V into the cache prefix, SSM/xLSTM final
    # states wholesale
    if caches is not None:
        for pos, desc in enumerate(block_program(cfg)):
            entry = cache["entries"][pos]
            got = caches[pos]
            if desc.seq == "attn":
                entry["k"] = jax.lax.dynamic_update_slice_in_dim(
                    entry["k"], got["k"].astype(ACT_DTYPE), 0, axis=2)
                entry["v"] = jax.lax.dynamic_update_slice_in_dim(
                    entry["v"], got["v"].astype(ACT_DTYPE), 0, axis=2)
            else:
                cache["entries"][pos] = jax.tree.map(
                    lambda new, old: new.astype(old.dtype), got, entry)
    cache["lengths"] = jnp.full((b,), t, jnp.int32)
    return logits, cache


def decode_fn(params, cfg, cache, tokens):
    """One decode step. tokens: (B,) int32. Returns (logits (B, V), cache)."""
    program = block_program(cfg)
    n_periods = cfg.n_layers // len(program)
    embed = _dense_leaf(params["embed"])
    x = embed_tokens(embed, tokens[:, None])
    lengths = cache["lengths"]
    period = params["period"]
    entries = cache["entries"]
    if n_periods == 0:  # 0-layer variant used by the dry-run cost protocol
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = embed.T if cfg.tie_embeddings else _dense_leaf(params["head"])
        return lm_logits(x, head)[:, 0], dict(cache, lengths=lengths + 1)

    def period_body(x, sliced_params, sliced_cache):
        new_entries = []
        for pos, desc in enumerate(program):
            p = resolve_weights(sliced_params[pos])
            x, new_c = _apply_position_step(p, desc, cfg, x,
                                            sliced_cache[pos], lengths)
            new_entries.append(new_c)
        return x, new_entries

    if overlap_enabled(getattr(cfg, "overlap", "auto"), period):
        schedule = build_schedule(period, n_periods)

        def apply_fn(x, sliced, sliced_cache, _i):
            return period_body(x, sliced, sliced_cache)

        if cfg.scan_layers:
            x, new_entries = pipeline_scan(schedule, apply_fn, x,
                                           xs_extra=entries)
        else:
            x, outs = pipeline_unrolled(schedule, apply_fn, x,
                                        xs_extra=entries)
            new_entries = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        cache = {"entries": new_entries, "lengths": lengths + 1}
    elif cfg.scan_layers:
        def scan_body(x, sl):
            sp, sc = sl
            x, new_entries = period_body(x, sp, sc)
            return x, new_entries

        x, new_entries = jax.lax.scan(scan_body, x, (period, entries))
        cache = {"entries": new_entries, "lengths": lengths + 1}
    else:
        outs = []
        for i in range(n_periods):
            sp = jax.tree.map(lambda a: a[i], period)
            sc = jax.tree.map(lambda a: a[i], entries)
            x, new_e = period_body(x, sp, sc)
            outs.append(new_e)
        new_entries = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        cache = {"entries": new_entries, "lengths": lengths + 1}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = embed.T if cfg.tie_embeddings else _dense_leaf(params["head"])
    return lm_logits(x, head)[:, 0], cache
