"""Encoder-decoder backbone (whisper-tiny).  The conv audio frontend is a
STUB per the assignment: ``input_specs()`` feeds precomputed frame
embeddings (B, T_audio, D); this module implements the transformer backbone
(bidirectional encoder, causal decoder with self+cross attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (ACT_DTYPE, attention_block, attention_decode_block,
                     cross_attention_block, cross_memory, cross_entropy,
                     dense_init, embed_init, embed_tokens, init_attention,
                     init_cross_attention, init_mlp, lm_logits, mlp_block,
                     rms_norm)
from .lm import _dense_leaf, attn_shape


def init_params(key, cfg):
    ks = jax.random.split(key, 6)
    s = attn_shape(cfg)
    n_enc, n_dec = cfg.encoder_layers, cfg.n_layers

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"pre_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
                "attn": init_attention(k1, s),
                "post_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"pre_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
                "attn": init_attention(k1, s),
                "xnorm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
                "xattn": init_cross_attention(k2, s),
                "post_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff)}

    enc = [enc_layer(jax.random.fold_in(ks[0], i)) for i in range(n_enc)]
    dec = [dec_layer(jax.random.fold_in(ks[1], i)) for i in range(n_dec)]
    return {
        "enc_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_stack": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model)),
        "enc_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
        "final_norm": jnp.zeros((cfg.d_model,), ACT_DTYPE),
        "head": dense_init(ks[3], (cfg.d_model, cfg.vocab_size)),
    }


def _enc_layer_fwd(p, cfg, x, positions):
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    out, _ = attention_block(p["attn"], h, attn_shape(cfg), positions,
                             cfg.rope_theta, causal=False)
    x = x + out
    x = x + mlp_block(p["mlp"], rms_norm(x, p["post_norm"], cfg.norm_eps),
                      activation="gelu")
    return x


def _dec_layer_fwd(p, cfg, x, memory_kv, positions):
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    out, kv = attention_block(p["attn"], h, attn_shape(cfg), positions,
                              cfg.rope_theta, causal=True)
    x = x + out
    x = x + cross_attention_block(
        p["xattn"], rms_norm(x, p["xnorm"], cfg.norm_eps), memory_kv,
        attn_shape(cfg))
    x = x + mlp_block(p["mlp"], rms_norm(x, p["post_norm"], cfg.norm_eps),
                      activation="gelu")
    return x, kv


def encode(params, cfg, frames):
    """frames: (B, T_a, D) precomputed embeddings -> encoder output."""
    x = frames.astype(ACT_DTYPE)
    positions = jnp.arange(x.shape[1])[None, :]
    if cfg.scan_layers and cfg.encoder_layers > 1:
        def body(x, sl):
            return _enc_layer_fwd(sl, cfg, x, positions), None
        x, _ = jax.lax.scan(body, x, params["enc_stack"])
    else:
        for i in range(cfg.encoder_layers):
            sl = jax.tree.map(lambda a: a[i], params["enc_stack"])
            x = _enc_layer_fwd(sl, cfg, x, positions)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg, enc_out, tokens):
    """Teacher-forced decoder forward -> logits (B, T, V)."""
    x = embed_tokens(_dense_leaf(params["embed"]), tokens)
    positions = jnp.arange(x.shape[1])[None, :]
    s = attn_shape(cfg)

    if cfg.scan_layers and cfg.n_layers > 1:
        def body(x, sl):
            memory = cross_memory(sl["xattn"], enc_out, s)
            x, _ = _dec_layer_fwd(sl, cfg, x, memory, positions)
            return x, None
        x, _ = jax.lax.scan(body, x, params["dec_stack"])
    else:
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda a: a[i], params["dec_stack"])
            memory = cross_memory(sl["xattn"], enc_out, s)
            x, _ = _dec_layer_fwd(sl, cfg, x, memory, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(x, _dense_leaf(params["head"]))


def loss_fn(params, cfg, batch):
    enc_out = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, enc_out, batch["tokens"])
    loss = cross_entropy(logits[:, :-1], batch["targets"][:, 1:])
    return loss, {"nll": loss, "aux": jnp.float32(0)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: int):
    s = attn_shape(cfg)
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, s.n_kv_heads,
                        s.head_dim), ACT_DTYPE),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, s.n_kv_heads,
                        s.head_dim), ACT_DTYPE),
        "mem_k": jnp.zeros((cfg.n_layers, batch, enc_len, s.n_kv_heads,
                            s.head_dim), ACT_DTYPE),
        "mem_v": jnp.zeros((cfg.n_layers, batch, enc_len, s.n_kv_heads,
                            s.head_dim), ACT_DTYPE),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, cfg, frames, tokens, max_len: int):
    """Encoder pass + decoder prompt pass; build self+cross caches."""
    enc_out = encode(params, cfg, frames)
    s = attn_shape(cfg)
    b, t = tokens.shape
    cache = init_cache(cfg, b, max_len, enc_out.shape[1])
    x = embed_tokens(_dense_leaf(params["embed"]), tokens)
    positions = jnp.arange(t)[None, :]
    for i in range(cfg.n_layers):
        sl = jax.tree.map(lambda a: a[i], params["dec_stack"])
        memory = cross_memory(sl["xattn"], enc_out, s)
        cache["mem_k"] = cache["mem_k"].at[i].set(memory[0])
        cache["mem_v"] = cache["mem_v"].at[i].set(memory[1])
        x, kv = _dec_layer_fwd(sl, cfg, x, memory, positions)
        cache["k"] = cache["k"].at[i, :, :t].set(kv[0])
        cache["v"] = cache["v"].at[i, :, :t].set(kv[1])
    cache["lengths"] = jnp.full((b,), t, jnp.int32)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(x[:, -1:], _dense_leaf(params["head"]))[:, 0], cache


def decode_step(params, cfg, cache, tokens):
    """One decoder token. tokens: (B,)."""
    s = attn_shape(cfg)
    x = embed_tokens(_dense_leaf(params["embed"]), tokens[:, None])
    lengths = cache["lengths"]

    def body(x, sl):
        p, c = sl
        h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
        out, kv = attention_decode_block(p["attn"], h, s, (c["k"], c["v"]),
                                         lengths, cfg.rope_theta)
        x = x + out
        x = x + cross_attention_block(
            p["xattn"], rms_norm(x, p["xnorm"], cfg.norm_eps),
            (c["mem_k"], c["mem_v"]), s)
        x = x + mlp_block(p["mlp"], rms_norm(x, p["post_norm"], cfg.norm_eps),
                          activation="gelu")
        return x, {"k": kv[0], "v": kv[1]}

    if cfg.scan_layers and cfg.n_layers > 1:
        percache = {"k": cache["k"], "v": cache["v"],
                    "mem_k": cache["mem_k"], "mem_v": cache["mem_v"]}
        x, new_kv = jax.lax.scan(body, x, (params["dec_stack"], percache))
        cache = dict(cache, k=new_kv["k"], v=new_kv["v"],
                     lengths=lengths + 1)
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            sl = (jax.tree.map(lambda a: a[i], params["dec_stack"]),
                  {"k": cache["k"][i], "v": cache["v"][i],
                   "mem_k": cache["mem_k"][i], "mem_v": cache["mem_v"][i]})
            x, kv = body(x, sl)
            ks.append(kv["k"]); vs.append(kv["v"])
        cache = dict(cache, k=jnp.stack(ks), v=jnp.stack(vs),
                     lengths=lengths + 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(x, _dense_leaf(params["head"]))[:, 0], cache
