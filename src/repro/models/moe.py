"""Mixture-of-Experts FFN with top-k token-choice routing.

Dispatch strategy: *per-sequence* capacity gather (GShard-style capacity,
applied within each batch row).  For every (sequence, expert) pair we select
the expert's top-C assigned tokens (C = 1.25 * k * T / E; overflow drops,
standard at scale), gather them into a dense (B, E, C, D) batch, run all
expert FFNs as one batched einsum, and scatter-add the weighted outputs
back.

Why per-sequence: selection/sort stays local to the data shard (no global
top-k over all tokens -> no all-gather of router scores), and the expert
einsum is local when experts shard on the model axis (EP).  The only
cross-device traffic is the combine-side partial-sum reduction that XLA
inserts over the model axis.  [Perf note: replacing that all-reduce combine
with all-to-all dispatch/return is hillclimb material — see EXPERIMENTS.md
§Perf.]

Aux losses: Switch load-balancing + router z-loss, returned to the caller.

Expert streaming (runtime/experts.py, docs/MOE.md): when the expert
stacks arrive as :class:`~repro.runtime.experts.ExpertRef` handles
instead of dense arrays, the block fetches only the step's ROUTED experts
through the store's LRU decode cache and receives full ``(E, ...)``
stacks with zeros in unrouted slots.  Bit-identity with the dense path is
structural, not approximate: the combine masks zero-gate capacity slots
to exactly ``+0.0`` (``jnp.where`` below) so a slot's contribution never
depends on the weight bytes behind an unrouted expert, and routed experts
decode losslessly — both paths feed the scatter-add identical addends in
identical order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.experts import ExpertRef, routed_expert_stacks

from .layers import ACT_DTYPE, dense_init, safe_einsum

CAPACITY_FACTOR = 1.25


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype=ACT_DTYPE):
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, n_experts), 0, jnp.float32),
        "e_gate": dense_init(ks[1], (n_experts, d_model, d_ff), 1, dtype),
        "e_up": dense_init(ks[2], (n_experts, d_model, d_ff), 1, dtype),
        "e_down": dense_init(ks[3], (n_experts, d_ff, d_model), 1, dtype),
    }


def capacity_for(seq_len: int, n_experts: int, k: int,
                 factor: float = CAPACITY_FACTOR) -> int:
    c = int(factor * k * seq_len / n_experts)
    c = max(1, min(c, seq_len))
    if seq_len >= 8:
        c = min(max(8, (c + 7) // 8 * 8), seq_len)
    return c


def _expert_weights(p, topk_i):
    """The step's (e_gate, e_up, e_down) stacks: dense arrays pass
    through; :class:`ExpertRef` handles fetch the routed experts via the
    store's batched LRU decode path (zeros in unrouted slots)."""
    leaves = (p["e_gate"], p["e_up"], p["e_down"])
    refs = [w for w in leaves if isinstance(w, ExpertRef)]
    if not refs:
        return leaves
    if len(refs) != len(leaves):
        raise TypeError(
            "moe_block needs e_gate/e_up/e_down uniformly dense or "
            "uniformly expert-streamed; got a mix — see "
            "runtime.experts.install_expert_store")
    return routed_expert_stacks(refs, topk_i)


def moe_block(p, x, k: int, combine_dtype: str = "f32",
              dispatch_a2a: bool = False):
    """x: (B, T, D) -> (out (B, T, D), aux dict with router stats).

    combine_dtype="bf16" halves the EP combine (psum over the model axis)
    wire bytes at the cost of bf16 rounding in the expert-sum (§Perf).
    """
    b, t, d = x.shape
    e = p["router"].shape[1]

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)               # (B, T, k)
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # assignment weight of token t for expert e within its own sequence
    bidx = jnp.arange(b)[:, None, None]
    tidx = jnp.arange(t)[None, :, None]
    assign = jnp.zeros((b, t, e), jnp.float32)
    assign = assign.at[bidx, tidx, topk_i].set(topk_p)     # (B, T, E)

    c = capacity_for(t, e, k)
    # per (sequence, expert) top-C tokens — local to the data shard
    gate_ec, idx_ec = jax.lax.top_k(assign.transpose(0, 2, 1), c)  # (B, E, C)
    x_ec = jnp.take_along_axis(
        x[:, None, :, :], idx_ec[..., None], axis=2)       # (B, E, C, D)
    if dispatch_a2a:
        # EP dispatch: reshard batch->contract dim (an all-to-all) so the
        # expert matmuls against contract-dim-sharded weights are local
        # partial sums — avoids XLA's gather-via-masked-allreduce (§Perf).
        from jax.sharding import PartitionSpec as _P
        x_ec = jax.lax.with_sharding_constraint(
            x_ec, _P(None, "model", None, "data"))

    w_gate, w_up, w_down = _expert_weights(p, topk_i)
    g = safe_einsum("becd,edf->becf", x_ec, w_gate)
    u = safe_einsum("becd,edf->becf", x_ec, w_up)
    h = (jax.nn.silu(g) * u).astype(ACT_DTYPE)
    y_ec = safe_einsum("becf,efd->becd", h, w_down)  # (B, E, C, D) f32

    # zero-gate capacity slots (unassigned capacity AND every slot of an
    # unrouted expert) contribute exactly +0.0 — a bare multiply could
    # leak a weight-dependent -0.0, breaking dense-vs-streamed
    # bit-identity at those slots
    y_ec = jnp.where(gate_ec[..., None] > 0, y_ec * gate_ec[..., None], 0.0)
    acc_dt = jnp.bfloat16 if combine_dtype == "bf16" else jnp.float32
    out = jnp.zeros((b, t, d), acc_dt)
    out = out.at[bidx, idx_ec].add(y_ec.astype(acc_dt))    # combine (psum on EP)

    me = probs.mean(axis=(0, 1))                           # (E,)
    ce = (assign > 0).astype(jnp.float32).mean(axis=(0, 1))
    aux = {
        "lb_loss": e * jnp.sum(me * ce),
        "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return out.reshape(b, t, d).astype(x.dtype), aux
