"""Model zoo: the 10 assigned architectures behind one functional interface."""
from .registry import build_model, Model  # noqa: F401
