"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with recurrent gate feedback).

Both are attention-free with O(1) decode state — xlstm-125m is one of the
two assigned architectures that runs the long_500k cell.

Implementation: numerically-stabilized recurrent forms via ``lax.scan``
(exponential input gates with the m_t running-max stabilizer, App. A of the
paper).  Roofline note: scan bodies are counted once by cost_analysis;
launch/roofline.py adds the analytic per-step state-update FLOPs
(~B*H*hd^2*6 per mLSTM layer-step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ACT_DTYPE, dense_init, rms_norm


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C (B, H, hd_v, hd_k)
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, n_heads: int, dtype=ACT_DTYPE):
    hd = d_model // n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d_model, d_model), 0, dtype),
        "wk": dense_init(ks[1], (d_model, d_model), 0, dtype),
        "wv": dense_init(ks[2], (d_model, d_model), 0, dtype),
        "wi": dense_init(ks[3], (d_model, n_heads), 0, jnp.float32),
        "wf": dense_init(ks[4], (d_model, n_heads), 0, jnp.float32),
        "wo_gate": dense_init(ks[5], (d_model, d_model), 0, dtype),
        "out_proj": dense_init(ks[6], (d_model, d_model), 0, dtype),
        "norm": jnp.zeros((d_model,), dtype),
    }


def _mlstm_qkvif(p, x, n_heads: int):
    b, t, d = x.shape
    hd = d // n_heads
    q = jnp.einsum("btd,de->bte", x, p["wq"],
                   preferred_element_type=jnp.float32).reshape(b, t, n_heads, hd)
    k = jnp.einsum("btd,de->bte", x, p["wk"],
                   preferred_element_type=jnp.float32).reshape(b, t, n_heads, hd)
    v = jnp.einsum("btd,de->bte", x, p["wv"],
                   preferred_element_type=jnp.float32).reshape(b, t, n_heads, hd)
    k = k / jnp.sqrt(jnp.float32(hd))
    i_pre = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wi"])
    f_pre = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wf"])
    o_gate = jax.nn.sigmoid(
        jnp.einsum("btd,de->bte", x, p["wo_gate"],
                   preferred_element_type=jnp.float32))
    return q, k, v, i_pre, f_pre, o_gate


def _mlstm_cell(carry, inp):
    """Stabilized mLSTM cell (paper eqs. 19-27)."""
    c, n, m = carry                       # (B,H,hdv,hdk), (B,H,hdk), (B,H)
    q_t, k_t, v_t, i_pre, f_pre = inp     # (B,H,hd) x3, (B,H) x2
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] \
        * (v_t[..., :, None] * k_t[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * k_t
    h_num = jnp.einsum("bhvk,bhk->bhv", c, q_t)
    h_den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), jnp.exp(-m_new))
    h = h_num / h_den[..., None]
    return (c, n, m_new), h


def mlstm_forward(p, x, n_heads: int):
    """x: (B, T, D) -> (B, T, D), scan over time."""
    b, t, d = x.shape
    hd = d // n_heads
    q, k, v, i_pre, f_pre, o_gate = _mlstm_qkvif(p, x, n_heads)
    carry = (jnp.zeros((b, n_heads, hd, hd), jnp.float32),
             jnp.zeros((b, n_heads, hd), jnp.float32),
             jnp.full((b, n_heads), -1e30, jnp.float32))
    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    carry, hs = jax.lax.scan(_mlstm_cell, carry, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, t, d)          # (B, T, D)
    h = rms_norm(h.astype(ACT_DTYPE), p["norm"])
    h = h * o_gate.astype(ACT_DTYPE)
    out = jnp.einsum("btd,de->bte", h, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "m": carry[2]}


def init_mlstm_cache(d_model: int, n_heads: int, batch: int):
    hd = d_model // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_step(p, x, cache, n_heads: int):
    """Single-token decode, O(1) state."""
    q, k, v, i_pre, f_pre, o_gate = _mlstm_qkvif(p, x, n_heads)
    carry = (cache["c"], cache["n"], cache["m"])
    carry, h = _mlstm_cell(carry, (q[:, 0], k[:, 0], v[:, 0],
                                   i_pre[:, 0], f_pre[:, 0]))
    b, d = x.shape[0], x.shape[2]
    h = h.reshape(b, 1, d)
    h = rms_norm(h.astype(ACT_DTYPE), p["norm"]) * o_gate.astype(ACT_DTYPE)
    out = jnp.einsum("btd,de->bte", h, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "m": carry[2]}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory, recurrent gate feedback (inherently sequential)
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, n_heads: int, dtype=ACT_DTYPE):
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (d_model, 4 * d_model), 0, dtype),
        "r_in": dense_init(ks[1], (d_model, 4 * d_model), 0, dtype),
        "out_proj": dense_init(ks[2], (d_model, d_model), 0, dtype),
        "norm": jnp.zeros((d_model,), dtype),
    }


def _slstm_cell(p, carry, x_pre_t):
    """carry: (c, n, h, m) each (B, D) f32; x_pre_t: (B, 4D) — the input
    projection is hoisted OUT of the time scan (it has no recurrent
    dependency), leaving only the recurrent r_in matmul in the loop."""
    c, n, h, m = carry
    pre = (x_pre_t
           + jnp.einsum("bd,de->be", h.astype(ACT_DTYPE), p["r_in"],
                        preferred_element_type=jnp.float32))
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_pre)
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new)


def slstm_forward(p, x):
    """x: (B, T, D) -> (B, T, D), sequential scan (the paper's sLSTM has
    true recurrent feedback — not parallelizable; this is expected).  The
    input projection runs as ONE (B*T, D)x(D, 4D) matmul outside the scan."""
    b, t, d = x.shape
    x_pre = jnp.einsum("btd,de->bte", x, p["w_in"],
                       preferred_element_type=jnp.float32)

    def step(carry, x_pre_t):
        carry = _slstm_cell(p, carry, x_pre_t)
        return carry, carry[2]

    carry = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) \
        + (jnp.full((b, d), -1e30, jnp.float32),)
    carry, hs = jax.lax.scan(step, carry, x_pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(ACT_DTYPE)
    h = rms_norm(h, p["norm"])
    out = jnp.einsum("btd,de->bte", h, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}


def init_slstm_cache(d_model: int, batch: int):
    return {
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.zeros((batch, d_model), jnp.float32),
        "h": jnp.zeros((batch, d_model), jnp.float32),
        "m": jnp.full((batch, d_model), -1e30, jnp.float32),
    }


def slstm_step(p, x, cache):
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    x_pre = jnp.einsum("bd,de->be", x[:, 0], p["w_in"],
                       preferred_element_type=jnp.float32)
    carry = _slstm_cell(p, carry, x_pre)
    h = carry[2][:, None, :].astype(ACT_DTYPE)
    h = rms_norm(h, p["norm"])
    out = jnp.einsum("btd,de->bte", h, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
