"""Selective state-space (Mamba/S6) block — used by the Jamba hybrid.

Training/prefill run the recurrence with ``lax.scan`` over time carrying the
(B, d_inner, d_state) state; the per-step tensors stay small (the
(T, d_inner, d_state) outer product is never materialized — that is the
memory trick Mamba's kernels implement, expressed here at the XLA level).
Decode is the same body applied once.

Roofline note: the scan body is counted ONCE by HLO cost_analysis.  The
pointwise state update is <1% of a Jamba layer's FLOPs (projections
dominate), and launch/roofline.py adds the exact analytic correction
``T * (6 * B * d_inner * d_state)`` per SSM layer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import ACT_DTYPE, dense_init


def mamba_dims(d_model: int, d_state: int, expand: int = 2):
    d_inner = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    return d_inner, dt_rank


def init_mamba(key, d_model: int, d_state: int, conv_dim: int,
               dtype=ACT_DTYPE):
    d_inner, dt_rank = mamba_dims(d_model, d_state)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), 0, dtype),
        "conv_w": dense_init(ks[1], (conv_dim, d_inner), 0, dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * d_state), 0, dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), 0, dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),  # softplus^-1(0.01)
        "a_log": jnp.log(a).astype(jnp.float32),       # (d_inner, d_state)
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_inner, d_model), 0, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv over time. x (B, T, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K=4: static unroll, exact HLO cost
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_coeffs(p, xz, d_state: int):
    """Shared projection math for scan/step. xz: (B, T, 2*d_inner)."""
    d_inner = p["dt_proj"].shape[1]
    dt_rank = p["dt_proj"].shape[0]
    x, z = jnp.split(xz, 2, axis=-1)
    x = _causal_conv(x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x.astype(jnp.float32)).astype(ACT_DTYPE)
    proj = jnp.einsum("btc,cr->btr", x, p["x_proj"],
                      preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rc->btc", proj[..., :dt_rank], p["dt_proj"],
                   preferred_element_type=jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    b_t = proj[..., dt_rank : dt_rank + d_state]           # (B, T, S)
    c_t = proj[..., dt_rank + d_state :]                   # (B, T, S)
    return x, z, dt, b_t, c_t


def mamba_forward(p, u, d_state: int, conv_dim: int = 4):
    """u: (B, T, D) -> ((B, T, D), final_state_cache). Scan over time (the
    (T, d_inner, d_state) outer product never materializes)."""
    xz = jnp.einsum("btd,de->bte", u, p["in_proj"],
                    preferred_element_type=jnp.float32).astype(ACT_DTYPE)
    x_raw = jnp.split(xz, 2, axis=-1)[0]                   # pre-conv (for cache)
    x, z, dt, b_t, c_t = _ssm_coeffs(p, xz, d_state)
    a = -jnp.exp(p["a_log"])                               # (C, S)

    def step(h, inp):
        x_t, dt_t, bt_t, ct_t = inp                        # (B,C),(B,C),(B,S),(B,S)
        da = jnp.exp(dt_t[..., None] * a)                  # (B, C, S)
        h = da * h + (dt_t * x_t)[..., None] * bt_t[:, None, :]
        y = jnp.einsum("bcs,bs->bc", h, ct_t)
        return h, y

    b, t, c = x.shape
    h0 = jnp.zeros((b, c, d_state), jnp.float32)
    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2),
          b_t.transpose(1, 0, 2), c_t.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + x.astype(jnp.float32) * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("btc,cd->btd", y.astype(ACT_DTYPE), p["out_proj"],
                     preferred_element_type=jnp.float32).astype(u.dtype)
    state = {"h": h_final, "conv": x_raw[:, t - (conv_dim - 1):, :]}
    return out, state


def init_mamba_cache(d_model: int, d_state: int, conv_dim: int, batch: int):
    d_inner, _ = mamba_dims(d_model, d_state)
    return {
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_dim - 1, d_inner), ACT_DTYPE),
    }


def mamba_step(p, u, cache, d_state: int):
    """Single-token decode. u: (B, 1, D). O(1) state — this is what makes
    the hybrid run long_500k."""
    xz = jnp.einsum("btd,de->bte", u, p["in_proj"],
                    preferred_element_type=jnp.float32).astype(ACT_DTYPE)
    x_raw, z = jnp.split(xz, 2, axis=-1)
    conv_win = jnp.concatenate([cache["conv"], x_raw], axis=1)  # (B, K, C)
    new_conv = conv_win[:, 1:]
    w = p["conv_w"].astype(jnp.float32)
    x = (conv_win.astype(jnp.float32) * w[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(jnp.float32)
    x = jax.nn.silu(x).astype(ACT_DTYPE)                   # (B, 1, C)
    dt_rank = p["dt_proj"].shape[0]
    proj = jnp.einsum("btc,cr->btr", x, p["x_proj"],
                      preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rc->btc", proj[..., :dt_rank], p["dt_proj"],
                   preferred_element_type=jnp.float32)
        + p["dt_bias"].astype(jnp.float32))[:, 0]          # (B, C)
    b_t = proj[:, 0, dt_rank : dt_rank + d_state]
    c_t = proj[:, 0, dt_rank + d_state :]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a)
    h = da * cache["h"] + (dt * x[:, 0].astype(jnp.float32))[..., None] \
        * b_t[:, None, :]
    y = jnp.einsum("bcs,bs->bc", h, c_t)[:, None, :]       # (B, 1, C)
    y = y + x.astype(jnp.float32) * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("btc,cd->btd", y.astype(ACT_DTYPE), p["out_proj"],
                     preferred_element_type=jnp.float32).astype(u.dtype)
    return out, {"h": h, "conv": new_conv}
