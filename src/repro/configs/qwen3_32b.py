"""Qwen3-32B [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA, head_dim=128. [hf:Qwen/Qwen3-8B; hf]

This is also the paper's own flagship evaluation model (Table II/IV/V and
the Fig. 10 end-to-end inference study use Qwen3-32B).
"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120, n_heads=64,
    n_kv_heads=8, d_ff=25600, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, scan_layers=False, remat=False)
