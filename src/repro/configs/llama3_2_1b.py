"""Llama-3.2-1B [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B; unverified]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048, n_heads=32,
    n_kv_heads=8, d_ff=8192, vocab_size=128256, head_dim=64,
    rope_theta=5e5, tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, scan_layers=False, remat=False)
