"""Architecture + shape configuration system.

Each assigned architecture gets one module in this package defining CONFIG
(exact published sizes) and SMOKE (a reduced same-family config for CPU
tests).  Shapes are the four assigned input-shape cells; applicability per
family follows DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = (
    "qwen3_32b", "minitron_4b", "llama3_2_1b", "stablelm_3b", "whisper_tiny",
    "paligemma_3b", "qwen3_moe_235b_a22b", "phi3_5_moe_42b_a6_6b",
    "xlstm_125m", "jamba_v0_1_52b",
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    # SSM / hybrid
    ssm_state: int = 16
    conv_dim: int = 4
    # encoder-decoder
    encoder_layers: int = 0
    # VLM / audio stub frontend: number of prefix embeddings
    prefix_embed: int = 0
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # execution knobs
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (save matmul outputs)
    moe_combine_dtype: str = "f32"  # f32 | bf16 (halves EP combine traffic)
    moe_dispatch_a2a: bool = False  # reshard x_ec batch->contract via a2a
    decode_score_shard: bool = False  # flash-decoding: pin scores S-sharded
    attn_chunk: int = 2048          # flash KV chunk (train/prefill)
    # decode-prefetch pipeline for streamed weights (runtime/overlap.py):
    # off | on | auto (auto == on whenever streamed leaves are present)
    overlap: str = "auto"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can serve long_500k (O(1)/O(chunk) decode state, no full-attn KV
        explosion at 500k — see DESIGN.md §6)."""
        return self.family in ("ssm", "hybrid")

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs autoregress (whisper via decoder)

    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """(applicable, reason-if-not). long_500k needs sub-quadratic decode."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "SKIP(full-attn): 500k KV decode assigned only to SSM/hybrid"
    return True, ""


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE
