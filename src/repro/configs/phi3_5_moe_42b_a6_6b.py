"""Phi-3.5-MoE-42B-A6.6B [moe]: 32L d_model=4096 32H (GQA kv=8)
d_ff=6400 per expert, vocab=32064, 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab_size=32064, head_dim=128,
    n_experts=16, experts_per_token=2, moe_d_ff=6400, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, moe_d_ff=64, n_experts=4, experts_per_token=2, vocab_size=512,
    scan_layers=False, remat=False)
