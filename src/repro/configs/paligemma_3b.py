"""PaliGemma-3B [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend is a STUB (input_specs feeds precomputed
patch embeddings as a bidirectional prefix). [arXiv:2407.07726; hf]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048, n_heads=8,
    n_kv_heads=1, d_ff=16384, vocab_size=257216, head_dim=256,
    prefix_embed=256, tie_embeddings=True, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512, prefix_embed=8, scan_layers=False, remat=False)
