"""StableLM-3B [dense]: 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=6912, vocab_size=50304, head_dim=80,
    rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512, scan_layers=False, remat=False)
