"""Per-architecture configs (exact published sizes) + reduced smoke configs."""
from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeSpec, get_config,
                   get_smoke_config, shape_applicable)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeSpec", "get_config",
           "get_smoke_config", "shape_applicable"]
