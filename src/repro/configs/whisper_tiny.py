"""Whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536
vocab=51865 — enc-dec; conv audio frontend is a STUB (input_specs feeds
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=6, d_ff=1536, vocab_size=51865, head_dim=64,
    encoder_layers=4, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=2,
    n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=512,
    scan_layers=False, remat=False)
