"""xLSTM-125M [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (3:1), attention-free, O(1) decode state -> runs long_500k.
[arXiv:2405.04517; unverified]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=50304, head_dim=192, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    vocab_size=512, scan_layers=False, remat=False)
