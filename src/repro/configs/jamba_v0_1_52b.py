"""Jamba-v0.1-52B [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536, MoE 16 experts top-2 every other layer, Mamba:attn 7:1
interleave (period 8, attention at position 4). O(1)-state Mamba layers +
only 4 attention layers -> runs long_500k. [arXiv:2403.19887; hf]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65536, head_dim=128,
    n_experts=16, experts_per_token=2, moe_d_ff=14336, ssm_state=16,
    conv_dim=4, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=128, moe_d_ff=128, n_experts=4, experts_per_token=2, vocab_size=512,
    ssm_state=4, scan_layers=False, remat=False)
