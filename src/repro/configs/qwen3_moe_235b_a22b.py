"""Qwen3-MoE-235B-A22B [moe]: 94L d_model=4096 64H (GQA kv=4)
moe_d_ff=1536, vocab=151936, 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab_size=151936, head_dim=128,
    qk_norm=True, n_experts=128, experts_per_token=8, moe_d_ff=1536,
    rope_theta=1e6)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, moe_d_ff=64, n_experts=8, experts_per_token=2, vocab_size=512,
    scan_layers=False, remat=False)
