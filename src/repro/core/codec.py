"""ENEC block codec (paper §IV-B basic design + §V optimizations), pure JAX.

A tensor is flattened, zero-padded to a multiple of the 16,384-element block
size, and encoded block-by-block:

  exponent --linear map--> y --group (L)--> 1-bit anomaly mask per group
  low  stream: low ``m`` bits of EVERY element        (fixed length)
  high stream: high ``n-m`` bits of anomalous groups  (block-level variable,
               stored rank-ordered & zero-padded to its static bound)
  raw  stream: sign|mantissa lanes                    (fixed length)

Only block-level variability remains (the key ENEC idea) — every array here
has a static shape, so the codec jits, shards and Pallas-lowers cleanly.

This module is the *reference* path (also used on CPU); the Pallas kernels
in ``repro.kernels`` implement the same layout for the TPU hot path and are
verified against this module element-for-element.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import bitio, transform
from .dtypes import FloatFormat, combine_fields, split_fields
from .params import DEFAULT_BLOCK_ELEMS, EnecParams


class BlockStreams(NamedTuple):
    """Static-shape per-block streams for one tensor (leading dim = blocks)."""
    mask: jax.Array      # (B, G/8)  uint8 — per-group anomaly bits
    low: jax.Array       # (B, packed(N, m)) uint8
    high: jax.Array      # (B, packed(N, n-m)) uint8 — rank-ordered, padded
    high_len: jax.Array  # (B,) int32 — true high-stream length in BITS
    raw: jax.Array       # (B, packed(N, raw_bits)) uint8


def stream_shapes(n_elems: int, fmt: FloatFormat, p: EnecParams):
    """Static byte widths of each stream for an N-element block."""
    g = n_elems // p.L
    return {
        "mask": g // 8,
        "low": bitio.packed_nbytes(n_elems, p.m),
        "high": bitio.packed_nbytes(n_elems, p.n - p.m),
        "raw": bitio.packed_nbytes(n_elems, fmt.raw_bits),
    }


def encode_blocks(bits, fmt: FloatFormat, p: EnecParams,
                  b_vec=None) -> BlockStreams:
    """bits: (B, N) unsigned int view of the floats.

    Shapes are static in (N, p.n, p.m, p.L); the linear-map parameter enters
    only the arithmetic, so ``b_vec`` (a traced (B,) per-block vector) can
    override the static ``p.b`` — the batched pipeline uses this to encode
    stacks with different searched ``b`` in one compiled dispatch.
    """
    nblocks, n = bits.shape
    g = n // p.L
    assert n % p.L == 0 and g % 8 == 0, (n, p.L)

    exp, raw = split_fields(bits, fmt)
    b_sel = p.b if b_vec is None else b_vec
    y = transform.forward(exp.astype(jnp.uint16), b_sel, p.n)  # (B, N), < 2**n

    yg = y.reshape(nblocks, g, p.L)
    # §V-B: bitwise-OR replaces reduction-max — group is anomalous iff any
    # element has a bit at position >= m.
    gor = jax.lax.reduce(yg, jnp.uint16(0), jnp.bitwise_or, (2,))
    anom = (gor >> p.m) != 0  # (B, G)

    mask = bitio.pack_bool_mask(anom)

    low = bitio.pack_fixed(y & jnp.uint16((1 << p.m) - 1), p.m)

    # Rank-ordered dense scatter of anomalous groups' high bits.  Non-anomalous
    # groups have y >> m == 0 everywhere, so their (colliding) writes into the
    # overflow row G are all zeros — deterministic by construction.
    rank = jnp.cumsum(anom, axis=1, dtype=jnp.int32) - anom.astype(jnp.int32)
    target = jnp.where(anom, rank, g)  # (B, G)
    y_high = (yg >> p.m).astype(jnp.uint16)  # (B, G, L)
    batch_ix = jnp.arange(nblocks, dtype=jnp.int32)[:, None]
    high_dense = (
        jnp.zeros((nblocks, g + 1, p.L), jnp.uint16)
        .at[batch_ix, target].set(y_high)[:, :g]
    )
    high = bitio.pack_fixed(high_dense.reshape(nblocks, n), p.n - p.m)
    high_len = (jnp.sum(anom, axis=1, dtype=jnp.int32) * (p.L * (p.n - p.m)))

    rawp = bitio.pack_fixed(raw, fmt.raw_bits)
    return BlockStreams(mask=mask, low=low, high=high, high_len=high_len, raw=rawp)


def decode_blocks(streams: BlockStreams, n_elems: int, fmt: FloatFormat,
                  p: EnecParams, b_vec=None, l_vec=None):
    """Inverse of :func:`encode_blocks` -> (B, N) unsigned int view.

    Shapes are static in (N, p.n, p.m, p.L); the inverse transform's
    ``(b, l)`` only enter the arithmetic, so ``b_vec`` / ``l_vec`` (traced
    (B,) per-block vectors) can override the static ``p.b`` / ``p.l`` — the
    batched decode pipeline uses this to decode tensors with different
    searched params in one compiled dispatch.
    """
    nblocks = streams.mask.shape[0]
    g = n_elems // p.L

    anom = bitio.unpack_bool_mask(streams.mask, g)  # (B, G)
    # Prefix sum over the mask — the paper's IDD-Scan target (§V-D).  The
    # Pallas kernel computes this with the MXU triangular-matmul scan; the
    # reference uses cumsum.
    rank = jnp.cumsum(anom, axis=1, dtype=jnp.int32) - anom.astype(jnp.int32)

    y_low = bitio.unpack_fixed(streams.low, n_elems, p.m).reshape(nblocks, g, p.L)
    high_dense = bitio.unpack_fixed(streams.high, n_elems, p.n - p.m)
    high_dense = high_dense.reshape(nblocks, g, p.L)

    # Reverse gather (paper Alg. 1 line 21): group g reads rank[g]'s row.
    gathered = jnp.take_along_axis(high_dense, rank[:, :, None], axis=1)
    gathered = jnp.where(anom[:, :, None], gathered, jnp.uint16(0))

    y = (y_low | (gathered << p.m)).reshape(nblocks, n_elems)
    b_sel = p.b if b_vec is None else b_vec
    l_sel = p.l if l_vec is None else l_vec
    exp = transform.inverse(y, b_sel, p.n, l_sel)

    raw = bitio.unpack_fixed(streams.raw, n_elems, fmt.raw_bits,
                             out_dtype=fmt.uint_dtype)
    return combine_fields(exp.astype(fmt.uint_dtype), raw, fmt)


def flatten_blocks(s: BlockStreams) -> BlockStreams:
    """Collapse every leading ``(L, [shards,] B)`` stream layout to one
    flat block axis — the layout the per-block decoder consumes.  The
    single definition keeps the device pipeline, the Pallas kernel entry,
    and the host wire path on one layout contract (works on numpy arrays
    too).  The block count is explicit (not -1): the high stream has zero
    width when m == n."""
    nblocks = 1
    for d in s.mask.shape[:-1]:
        nblocks *= int(d)
    return BlockStreams(
        mask=s.mask.reshape(nblocks, s.mask.shape[-1]),
        low=s.low.reshape(nblocks, s.low.shape[-1]),
        high=s.high.reshape(nblocks, s.high.shape[-1]),
        high_len=s.high_len.reshape(nblocks),
        raw=s.raw.reshape(nblocks, s.raw.shape[-1]))


# ---------------------------------------------------------------------------
# whole-array helpers (flatten / pad / reshape to blocks)
# ---------------------------------------------------------------------------

def pad_count(size: int, block_elems: int = DEFAULT_BLOCK_ELEMS) -> int:
    return (-size) % block_elems


def to_blocks(x, fmt: FloatFormat, block_elems: int = DEFAULT_BLOCK_ELEMS):
    """float array -> (B, N) bits with zero padding."""
    flat = jnp.ravel(x)
    pad = pad_count(flat.size, block_elems)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, flat.dtype)])
    bits = flat.view(fmt.uint_dtype)
    return bits.reshape(-1, block_elems)


def bits_to_blocks(flat_bits, block_elems: int = DEFAULT_BLOCK_ELEMS,
                   shards: int = 1, pad_value: int = 0):
    """(size,) uint bit view -> ((B, N) blocks, B) — the L=1 case of
    :func:`stacked_blocks` (single definition keeps the per-layer and
    stacked padding rules bit-identical by construction)."""
    return stacked_blocks(flat_bits[None, :], block_elems, shards, pad_value)


def stacked_blocks(bits2d, block_elems: int = DEFAULT_BLOCK_ELEMS,
                   shards: int = 1, pad_value: int = 0):
    """(L, per) uint bit view of a layer stack -> ((L*Bs, N) blocks, Bs).

    Row ``l*Bs + b`` equals block ``b`` of layer ``l``, each layer padded to
    the block size and (when ``shards > 1``) to a block count divisible by
    ``shards``, so a single encode of the stacked array is bit-identical to
    L per-layer encodes.  ``pad_value`` should be the bit pattern of the
    modal exponent (the encoder passes ``b << mant_bits``): padding with
    zeros would make every padded group anomalous (exponent 0 is far from
    ``b``) and charge full high-stream bits for data that decode slices
    away.  Device-only: the input is never copied to the host.
    """
    n_layers, per = bits2d.shape
    nblocks = (per + block_elems - 1) // block_elems
    if shards > 1:
        nblocks += (-nblocks) % shards
    total_pad = nblocks * block_elems - per
    if total_pad:
        bits2d = jnp.pad(bits2d, ((0, 0), (0, total_pad)),
                         constant_values=pad_value)
    return bits2d.reshape(n_layers * nblocks, block_elems), nblocks


def from_blocks(bits, shape, fmt: FloatFormat):
    size = 1
    for s in shape:
        size *= s
    flat = bits.reshape(-1).view(fmt.float_dtype)[:size]
    return flat.reshape(shape)
