"""Vectorized branch-free integer transformation (paper §V-C).

The exponent frequency-rank is ~linear in the exponent value (Obs. 5), so
the frequency-table gather of the basic design is replaced by the linear
map ``y = (2**n - x + b) % 2**n`` (Eq. 2).  Frequent exponents land on
small ``y`` values; two's-complement wrap-around handles ``x > b`` without
branches.  The inverse is exact whenever the exponent range seen at encode
time satisfies ``h - l < 2**n`` (guaranteed by the Eq. 1 choice of ``n``).

Everything is add/and/select on unsigned lanes: TPU-VPU friendly, exactly as
AIV-friendly on Ascend.
"""
from __future__ import annotations

import jax.numpy as jnp


def forward(x, b, n: int):
    """``y = (b - x) mod 2**n`` on unsigned integer lanes.

    ``b`` may be a static int or a traced array (broadcast against leading
    axes of ``x``): the linear-map parameter only enters the arithmetic,
    never a shape, so the batched encoder keeps it dynamic and one compiled
    program serves every ``b`` — including a per-block vector.
    """
    x = jnp.asarray(x)
    mod_mask = jnp.asarray((1 << n) - 1, x.dtype)
    bb = jnp.asarray(b, x.dtype) & mod_mask
    if bb.ndim:
        bb = bb.reshape(bb.shape + (1,) * (x.ndim - bb.ndim))
    # (b - x) mod 2**n  ==  (b + (2**n - x mod 2**n)) mod 2**n, branch free.
    return (bb - x) & mod_mask


def inverse(y, b, n: int, l):
    """Exact inverse given the minimum exponent ``l`` seen at encode time.

    ``x = l + ((b - y - l) mod 2**n)`` — picks the unique representative of
    the residue class lying in ``[l, l + 2**n)``, which contains ``[l, h]``.

    Like :func:`forward`, ``b`` and ``l`` may be static ints or traced
    arrays broadcast against leading axes of ``y``: the batched decoder
    passes per-block vectors so blocks from tensors with different
    ``(b, l)`` share one compiled decode dispatch.
    """
    y = jnp.asarray(y)
    mod_mask = jnp.asarray((1 << n) - 1, y.dtype)
    bb = jnp.asarray(b, y.dtype)
    ll = jnp.asarray(l, y.dtype)
    c = (bb - ll) & mod_mask
    if c.ndim:
        c = c.reshape(c.shape + (1,) * (y.ndim - c.ndim))
    if ll.ndim:
        ll = ll.reshape(ll.shape + (1,) * (y.ndim - ll.ndim))
    return ll + ((c - y) & mod_mask)
