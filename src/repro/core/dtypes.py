"""Float-format bit layouts used by the ENEC codec.

ENEC splits a float into its exponent field (compressed) and the
sign|mantissa residue (stored raw, paper §IV-B).  Everything here is pure
bit arithmetic on the unsigned integer view of the float buffer so the
round trip is exact for every encoding, including NaN payloads, infinities,
zeros and subnormals.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    name: str
    total_bits: int
    exp_bits: int
    mant_bits: int

    @property
    def raw_bits(self) -> int:
        """Width of the stored-raw residue: sign bit + mantissa bits."""
        return 1 + self.mant_bits

    @property
    def uint_dtype(self):
        return jnp.uint16 if self.total_bits == 16 else jnp.uint32

    @property
    def np_uint_dtype(self):
        return np.uint16 if self.total_bits == 16 else np.uint32

    @property
    def float_dtype(self):
        return {"bf16": jnp.bfloat16, "fp16": jnp.float16, "fp32": jnp.float32}[self.name]

    @property
    def exp_mask(self) -> int:
        return (1 << self.exp_bits) - 1

    @property
    def mant_mask(self) -> int:
        return (1 << self.mant_bits) - 1


BF16 = FloatFormat("bf16", 16, 8, 7)
FP16 = FloatFormat("fp16", 16, 5, 10)
FP32 = FloatFormat("fp32", 32, 8, 23)

FORMATS = {"bf16": BF16, "fp16": FP16, "fp32": FP32}


def format_for(dtype) -> FloatFormat:
    dtype = jnp.dtype(dtype)
    if dtype == jnp.bfloat16:
        return BF16
    if dtype == jnp.float16:
        return FP16
    if dtype == jnp.float32:
        return FP32
    raise ValueError(f"ENEC supports bf16/fp16/fp32, got {dtype}")


def to_bits(x):
    """Bit-cast a float array to its unsigned integer view."""
    fmt = format_for(x.dtype)
    return jnp.asarray(x).view(fmt.uint_dtype)


def from_bits(bits, fmt: FloatFormat):
    return jnp.asarray(bits, fmt.uint_dtype).view(fmt.float_dtype)


def split_fields(bits, fmt: FloatFormat):
    """bits -> (exponent, raw) where raw = sign<<mant_bits | mantissa."""
    bits = jnp.asarray(bits, fmt.uint_dtype)
    exp = (bits >> fmt.mant_bits) & fmt.exp_mask
    sign = bits >> (fmt.total_bits - 1)
    raw = (bits & fmt.mant_mask) | (sign << fmt.mant_bits)
    return exp, raw


def combine_fields(exp, raw, fmt: FloatFormat):
    """Inverse of :func:`split_fields`."""
    exp = jnp.asarray(exp, fmt.uint_dtype)
    raw = jnp.asarray(raw, fmt.uint_dtype)
    sign = raw >> fmt.mant_bits
    mant = raw & fmt.mant_mask
    return (sign << (fmt.total_bits - 1)) | (exp << fmt.mant_bits) | mant
