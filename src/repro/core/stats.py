"""Device-side tensor statistics for the batched compression pipeline.

The parameter search (params.py) only needs the exponent *histogram* — a
256-entry table for bf16/fp32, 32 for fp16 — yet the seed pipeline moved the
full tensor to the host to compute it with numpy.  This module computes the
histogram, the exact exponent min/max, and the per-layer const-tensor flags
in ONE jit'd reduction on device; only those few hundred bytes ever cross to
the host.  The existing O(256^2) search then runs on the histogram unchanged.

Correctness/speed split: scatter-add histograms are slow on backends without
fast scatters (XLA CPU serializes the updates), so above ``HIST_SAMPLE_CAP``
elements the histogram is taken over a strided sample.  That is safe by
construction: the histogram only drives parameter *quality*, while
losslessness depends on the exponent bounds — and those come from exact
vectorized min/max reductions over the full tensor, which the caller feeds
into ``params.widen_for_range`` after the search.

Everything here operates on the ``(L, per_layer_elems)`` unsigned-integer
view of a layer stack; a single tensor is the ``L == 1`` case.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dtypes import FORMATS, FloatFormat

# histogram sample cap: 2**16 samples of a <=256-way histogram leave the
# searched parameters statistically indistinguishable from the full pass
# (XLA CPU serializes scatter updates at ~75ns each, so the cap directly
# bounds the per-stack stats latency; exactness below the cap is what the
# search-parity tests rely on)
HIST_SAMPLE_CAP = 1 << 16


@dataclasses.dataclass(frozen=True)
class StackStats:
    """Host-side summary of one ``(L, ...)`` stack (a few hundred bytes)."""
    hist: np.ndarray       # (2**exp_bits,) int64 — whole-stack exponent
    #                        histogram (strided sample above HIST_SAMPLE_CAP)
    lo: int                # exact min exponent over the whole stack
    hi: int                # exact max exponent over the whole stack
    is_const: np.ndarray   # (L,) bool — layer is a single repeated bit pattern
    first: np.ndarray      # (L,) uint — first element's bit pattern per layer

    def bounds(self) -> Tuple[int, int]:
        """Exact (min, max) exponent present — from the full-tensor
        reduction, never the (possibly sampled) histogram."""
        return self.lo, self.hi


@functools.lru_cache(maxsize=None)
def _stats_fn(fmt_name: str):
    fmt = FORMATS[fmt_name]

    def f(bits2d):
        exp = (bits2d >> fmt.mant_bits) & jnp.asarray(fmt.exp_mask,
                                                      bits2d.dtype)
        flat = exp.reshape(-1)
        # static at trace time; forced odd so the stride never divides
        # power-of-two weight dims (an even stride equal to the row length
        # would sample a few columns instead of the whole tensor)
        stride = max(1, flat.size // HIST_SAMPLE_CAP) | 1
        sample = flat[::stride].astype(jnp.int32)
        hist = jnp.zeros((1 << fmt.exp_bits,), jnp.int32).at[sample].add(1)
        is_const = jnp.all(bits2d == bits2d[:, :1], axis=1)
        return hist, flat.min(), flat.max(), is_const, bits2d[:, 0]

    return jax.jit(f)


def exponent_histogram_device(x, fmt: FloatFormat) -> jax.Array:
    """EXACT exponent histogram of a float array, computed on device (jit'd).

    Matches ``params.exponent_histogram`` bin-for-bin (no sampling — the
    pipeline's :func:`stack_stats_device` may sample, this function never
    does).  The result stays on device so callers can batch the transfer.
    """
    bits = jnp.ravel(jnp.asarray(x)).view(fmt.uint_dtype)

    @functools.partial(jax.jit, static_argnames=("bins", "mant", "mask"))
    def f(b, bins, mant, mask):
        exp = ((b >> mant) & jnp.asarray(mask, b.dtype)).astype(jnp.int32)
        return jnp.zeros((bins,), jnp.int32).at[exp].add(1)

    return f(bits, bins=1 << fmt.exp_bits, mant=fmt.mant_bits,
             mask=fmt.exp_mask)


def stack_stats_device(bits2d, fmt: FloatFormat):
    """(hist, lo, hi, is_const, first) as device arrays for a ``(L, N)`` bit
    view.  One fused jit dispatch; pair with :func:`fetch_stats` to batch the
    host transfer across many stacks."""
    return _stats_fn(fmt.name)(bits2d)


def fetch_stats(device_stats: Sequence) -> list:
    """Move many ``stack_stats_device`` results to host in ONE transfer."""
    if not device_stats:
        return []
    host = jax.device_get(list(device_stats))
    return [StackStats(hist=np.asarray(h, np.int64), lo=int(lo), hi=int(hi),
                       is_const=np.asarray(c, bool), first=np.asarray(f))
            for h, lo, hi, c, f in host]


def stack_stats(bits2d, fmt: FloatFormat) -> StackStats:
    """Single-stack convenience wrapper (one dispatch + one tiny transfer)."""
    return fetch_stats([stack_stats_device(bits2d, fmt)])[0]
