"""The v1 public codec API: ``CodecConfig`` + ``Codec`` with an explicit
plan/execute split and NO module-global state.

PRs 1-4 grew ``core/api.py`` into ~20 top-level functions driven by process
globals (two backend selectors, two compile caches, two cache-stat
singletons, a wire transfer counter).  That shape cannot host two models
with different codec settings in one process, and it made the pipeline's
O(#buckets) dispatch guarantees benchmark folklore instead of API
properties.  This module is the replacement:

* :class:`CodecConfig` — the immutable policy knobs: encode/decode backend,
  default ``block_elems``, the block-count bucketing policy, and the
  parameter-search policy (per-tensor histogram search, or fixed
  ``shared_params`` for the paper's transferability mode).
* :class:`Codec` — an instance owning its OWN encoder/decoder compile
  caches, cache-stat counters, and host->device transfer counter.  Two
  codecs with different backends coexist in one process with fully
  independent state.
* **plan/execute split** — :meth:`Codec.plan_encode` /
  :meth:`Codec.plan_decode` return :class:`EncodePlan` / :class:`DecodePlan`
  objects that expose the bucket assignment (one
  ``(backend, fmt, (n, m, L), block_elems, block-count bucket)`` group per
  jit dispatch), the dispatch count, and the predicted wire bytes as
  inspectable data; :meth:`Codec.execute` runs the batched dispatches.
  ``len(plan.buckets)`` IS the number of dispatches the execute performs —
  asserted by tests, relied on by the benchmarks.

The legacy module-level functions in ``core/api.py`` remain as thin
deprecated wrappers over :func:`current_codec` (the ambient codec:
:func:`use_codec` context override, else the process :func:`default_codec`),
so existing trees, wire records, and tests keep working bit-identically.
See docs/API.md for the stability contract and the migration table.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import codec as block_codec
from . import params as params_mod
from . import stats as stats_mod
from .api import (MATMUL_TILE, CompressedTensor, _is_supported_float,
                  _raw_tensor, matmul_tiles, slice_stacked)
from .codec import BlockStreams
from .dtypes import FORMATS, FloatFormat, format_for
from .params import DEFAULT_BLOCK_ELEMS, EnecParams, expected_ratio

BACKENDS = ("reference", "pallas")

# Transfer-ledger links: every byte a codec moves is attributed to exactly
# one link, split compressed-vs-dense (paper thesis: the links should only
# ever carry compressed bytes).
#   h2d            host->device uploads (wire deserialization, raw leaves)
#   d2d_allgather  device<->device stream gathers over a mesh axis
#                  (compressed-bytes all-gather for FSDP-style weights)
#   d2d_psum       device<->device gradient collectives
#                  (optim.grad_compress.compressed_allreduce)
#   disk           checkpoint pack-file record reads
LINKS = ("h2d", "d2d_allgather", "d2d_psum", "disk")

_flatten_streams = block_codec.flatten_blocks


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Immutable policy for one :class:`Codec` instance.

    encode_backend / decode_backend
        ``"reference"`` (pure-jnp codec, any backend — default) or
        ``"pallas"`` (the TPU kernels; ``interpret=True`` elsewhere).
    block_elems
        Default ENEC block size when a call does not override it
        (paper §VI-D: 16384 == one 128x128 MXU tile).
    bucket_pow2_max / bucket_multiple
        The block-count bucketing policy for the compile caches: counts are
        rounded up to powers of two up to ``bucket_pow2_max``, then to
        multiples of ``bucket_multiple`` — bounding distinct compiles while
        keeping pad waste small.
    shared_params
        ``None`` (default) searches parameters per tensor from its exponent
        histogram; a fixed :class:`EnecParams` selects the paper's
        transferability mode (every tensor encodes under these params,
        widened to its exact exponent range for unconditional losslessness).
    max_cached_programs
        Safety valve on each compile cache (never hit in practice).
    """
    encode_backend: str = "reference"
    decode_backend: str = "reference"
    block_elems: int = DEFAULT_BLOCK_ELEMS
    bucket_pow2_max: int = 64
    bucket_multiple: int = 64
    shared_params: Optional[EnecParams] = None
    max_cached_programs: int = 512

    def __post_init__(self):
        for field in ("encode_backend", "decode_backend"):
            name = getattr(self, field)
            if name not in BACKENDS:
                raise ValueError(f"unknown {field} {name!r}; "
                                 f"expected one of {BACKENDS}")
        if self.block_elems < 1 or self.bucket_pow2_max < 1 \
                or self.bucket_multiple < 1:
            raise ValueError("block_elems / bucket policy must be >= 1")


# ---------------------------------------------------------------------------
# plan objects: the bucket assignment as inspectable data
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EncodeBucket:
    """One encode dispatch: every member tensor shares this compiled
    encoder.  ``key`` is the compile-cache key
    ``(backend, fmt, params-key, block_elems, block-count bucket)``."""
    backend: str
    fmt_name: str
    params_key: tuple        # (n, m, L) on reference; full tuple on pallas
    block_elems: int
    block_bucket: int        # bucketed (padded) block count of the dispatch
    nblocks: int             # true flat blocks across all members
    n_tensors: int           # member stacks encoded by this dispatch
    predicted_wire_bytes: int

    @property
    def key(self) -> tuple:
        return (self.backend, self.fmt_name, self.params_key,
                self.block_elems, self.block_bucket)


@dataclasses.dataclass(frozen=True)
class DecodeBucket:
    """One decode dispatch; mirror of :class:`EncodeBucket`."""
    backend: str
    fmt_name: str
    params_key: tuple
    block_elems: int
    block_bucket: int
    nblocks: int
    n_tensors: int

    @property
    def key(self) -> tuple:
        return (self.backend, self.fmt_name, self.params_key,
                self.block_elems, self.block_bucket)


@dataclasses.dataclass
class EncodePlan:
    """Inspectable encode schedule for one input tree.

    ``len(buckets)`` == the exact number of encode dispatches
    :meth:`Codec.execute` will launch; ``n_fallback`` counts inputs that
    skip the encoder entirely (unsupported dtype, empty, or constant —
    resolved per the calling mode's escape rules at execute time).
    """
    config: CodecConfig
    buckets: Tuple[EncodeBucket, ...]
    n_inputs: int
    n_fallback: int
    stacked: bool
    shards: int
    block_elems: int = DEFAULT_BLOCK_ELEMS
    # -- internal execution state (not part of the stable surface) --------
    _treedef: Any = dataclasses.field(repr=False, default=None)
    _groups: list = dataclasses.field(repr=False, default_factory=list)
    _fallbacks: dict = dataclasses.field(repr=False, default_factory=dict)
    _leaves: list = dataclasses.field(repr=False, default_factory=list)

    @property
    def dispatch_count(self) -> int:
        return len(self.buckets)

    @property
    def predicted_wire_bytes(self) -> int:
        return sum(b.predicted_wire_bytes for b in self.buckets)


@dataclasses.dataclass
class DecodePlan:
    """Inspectable decode schedule; mirror of :class:`EncodePlan`.

    ``n_passthrough`` counts const/raw/non-compressed leaves that restore
    without a decode dispatch.
    """
    config: CodecConfig
    buckets: Tuple[DecodeBucket, ...]
    n_inputs: int
    n_passthrough: int
    # exact=True skips block-count bucket rounding: each dispatch decodes
    # its true block count with zero pad waste (the overlap prefetch path,
    # which decodes the same per-layer leaf set every step, so the compile
    # cache sees one stable exact count instead of unbounded variety)
    exact: bool = False
    _treedef: Any = dataclasses.field(repr=False, default=None)
    _groups: list = dataclasses.field(repr=False, default_factory=list)
    _passthrough: dict = dataclasses.field(repr=False, default_factory=dict)
    _leaves: list = dataclasses.field(repr=False, default_factory=list)

    @property
    def dispatch_count(self) -> int:
        return len(self.buckets)


def _is_ct(x) -> bool:
    return isinstance(x, CompressedTensor)


def _stack_dim(ct: CompressedTensor) -> Optional[int]:
    """Leading layer count of a stacked tensor, or ``None`` for a per-leaf
    tensor (whose metadata already describes the whole array)."""
    base = 3 if ct.shards > 1 else 2
    return ct.streams.mask.shape[0] if ct.streams.mask.ndim == base + 1 \
        else None


def _stacked_from_bits(ct: CompressedTensor, n_layers: int, bits):
    """(L*B, N) decoded bits -> the dense ``(L,) + ct.shape`` stack."""
    per = int(np.prod(ct.shape))
    flat_layers = bits.reshape(n_layers, -1)[:, :per]
    return flat_layers.view(ct.fmt.float_dtype).reshape(
        (n_layers,) + ct.shape).astype(jnp.dtype(ct.dtype_str))


# ---------------------------------------------------------------------------
# the codec
# ---------------------------------------------------------------------------

class Codec:
    """One ENEC codec instance: config + compile caches + counters.

    All state is instance-scoped — construct one per model/tenant and pass
    it explicitly (``CheckpointManager(codec=...)``,
    ``assign_weight_modes(..., codec=...)``), or install it as the ambient
    codec with :func:`use_codec`.  Every compression entry point either
    takes the plan/execute route (:meth:`plan_encode` -> :meth:`execute`)
    or is a thin convenience over it.
    """

    def __init__(self, config: Optional[CodecConfig] = None, **overrides):
        if config is None:
            config = CodecConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self._encode_cache: dict = {}
        self._decode_cache: dict = {}
        self._encode_stats = {"compiles": 0, "cache_hits": 0,
                              "dispatches": 0, "padded_blocks": 0}
        self._decode_stats = {"compiles": 0, "cache_hits": 0,
                              "dispatches": 0, "padded_blocks": 0}
        self._transfer = {"h2d_bytes": 0, "h2d_arrays": 0}
        self._links = {link: {"compressed_bytes": 0, "dense_bytes": 0,
                              "ops": 0} for link in LINKS}

    def __repr__(self):
        c = self.config
        return (f"Codec(encode={c.encode_backend!r}, "
                f"decode={c.decode_backend!r}, block_elems={c.block_elems})")

    # -- configuration ----------------------------------------------------

    def configure(self, config: CodecConfig) -> "Codec":
        """Swap the config in place, clearing only the compile caches whose
        keys the change invalidates.  Returns ``self``."""
        old = self.config
        if config == old:
            return self
        self.config = config
        if (config.encode_backend, config.bucket_pow2_max,
                config.bucket_multiple) != (old.encode_backend,
                                            old.bucket_pow2_max,
                                            old.bucket_multiple):
            self._encode_cache.clear()
        if (config.decode_backend, config.bucket_pow2_max,
                config.bucket_multiple) != (old.decode_backend,
                                            old.bucket_pow2_max,
                                            old.bucket_multiple):
            self._decode_cache.clear()
        return self

    def set_encode_backend(self, name: str) -> None:
        """Legacy-compat mutator; prefer constructing
        ``Codec(encode_backend=...)``."""
        self.configure(dataclasses.replace(self.config, encode_backend=name))

    def set_decode_backend(self, name: str) -> None:
        """Legacy-compat mutator; prefer constructing
        ``Codec(decode_backend=...)``."""
        self.configure(dataclasses.replace(self.config, decode_backend=name))

    # -- counters ---------------------------------------------------------

    def encode_cache_stats(self) -> dict:
        """Counters for the jit'd-encoder cache: ``compiles`` distinct
        encoder instantiations, ``dispatches`` encode calls,
        ``padded_blocks`` zero blocks added by block-count bucketing."""
        return dict(self._encode_stats,
                    cached_encoders=len(self._encode_cache),
                    backend=self.config.encode_backend)

    def decode_cache_stats(self) -> dict:
        """Mirror of :meth:`encode_cache_stats` for the decoder cache."""
        return dict(self._decode_stats,
                    cached_decoders=len(self._decode_cache),
                    backend=self.config.decode_backend)

    def reset_encode_cache_stats(self, clear_cache: bool = False) -> None:
        for k in self._encode_stats:
            self._encode_stats[k] = 0
        if clear_cache:
            self._encode_cache.clear()

    def reset_decode_cache_stats(self, clear_cache: bool = False) -> None:
        for k in self._decode_stats:
            self._decode_stats[k] = 0
        if clear_cache:
            self._decode_cache.clear()

    def transfer_stats(self) -> dict:
        """Bytes this codec moved, per link (see :data:`LINKS`).

        The flat ``h2d_bytes`` / ``h2d_arrays`` keys are the legacy h2d-only
        view (the compressed-restore acceptance test uses them to prove no
        dense weight ever crossed the host->device link); ``links`` is the
        full per-link ledger with a compressed-vs-dense split per link.
        """
        out = dict(self._transfer)
        out["links"] = self.link_stats()
        return out

    def link_stats(self) -> dict:
        """The per-link transfer ledger alone:
        ``{link: {compressed_bytes, dense_bytes, ops}}``."""
        return {link: dict(v) for link, v in self._links.items()}

    def reset_transfer_stats(self) -> None:
        for k in self._transfer:
            self._transfer[k] = 0
        for entry in self._links.values():
            for k in entry:
                entry[k] = 0

    def count_link(self, link: str, nbytes: int, *, dense: bool = False,
                   ops: int = 1) -> None:
        """Attribute ``nbytes`` moved over ``link`` (one of :data:`LINKS`).
        ``dense=True`` marks payloads that are NOT fixed-length wire
        streams (raw checkpoint leaves, incompressible escapes) — the
        quantity the per-link acceptance gates require to stay zero on the
        collective links."""
        if link not in self._links:
            raise ValueError(f"unknown transfer link {link!r}; "
                             f"expected one of {LINKS}")
        entry = self._links[link]
        entry["dense_bytes" if dense else "compressed_bytes"] += int(nbytes)
        entry["ops"] += int(ops)
        if link == "h2d":
            self._transfer["h2d_bytes"] += int(nbytes)
            self._transfer["h2d_arrays"] += int(ops)

    def count_h2d(self, nbytes: int, arrays: int = 1, *,
                  dense: bool = False) -> None:
        """Record a host->device upload (``core.wire.h2d`` calls this).
        Thin alias for ``count_link("h2d", ...)``."""
        self.count_link("h2d", nbytes, dense=dense, ops=arrays)

    # -- bucketing / compile caches --------------------------------------

    def _block_bucket(self, nblocks: int) -> int:
        """Round the block count up so a 48-layer model hits a handful of
        compiled codecs instead of one per distinct tensor shape: powers of
        two up to ``bucket_pow2_max`` blocks, multiples of
        ``bucket_multiple`` above (pure pow2 would pad up to 2x the work
        for large stacks)."""
        cfg = self.config
        if nblocks <= 1:
            return 1
        if nblocks <= cfg.bucket_pow2_max:
            return 1 << (nblocks - 1).bit_length()
        return -(-nblocks // cfg.bucket_multiple) * cfg.bucket_multiple

    def _encoder_key(self, fmt_name: str, p: EnecParams,
                     block_elems: int) -> tuple:
        """Compile-cache key sans block count.  The reference encoder keeps
        the linear-map parameter ``b`` as a traced per-block operand (it
        never enters a shape), so one compiled program serves every ``b`` —
        the key carries only (n, m, L).  The Pallas kernel bakes the whole
        param tuple in."""
        backend = self.config.encode_backend
        if backend == "pallas":
            return (backend, fmt_name, p.astuple(), block_elems)
        return (backend, fmt_name, (p.n, p.m, p.L), block_elems)

    def _decoder_key(self, fmt_name: str, p: EnecParams,
                     block_elems: int) -> tuple:
        """Decoder mirror of :meth:`_encoder_key`: the reference decoder
        takes the inverse-transform params ``(b, l)`` as traced per-block
        operands, the Pallas kernel bakes the full tuple in."""
        backend = self.config.decode_backend
        if backend == "pallas":
            return (backend, fmt_name, p.astuple() + (p.l,), block_elems)
        return (backend, fmt_name, (p.n, p.m, p.L), block_elems)

    def _encoder_for(self, fmt_name: str, p: EnecParams, block_elems: int,
                     bucket: int):
        key = self._encoder_key(fmt_name, p, block_elems) + (bucket,)
        fn = self._encode_cache.get(key)
        if fn is None:
            if len(self._encode_cache) >= self.config.max_cached_programs:
                self._encode_cache.clear()   # safety valve
            self._encode_stats["compiles"] += 1
            fmt = FORMATS[fmt_name]
            # encode reads (n, m, L) for shapes and b for arithmetic only;
            # normalizing the bookkeeping fields lets params that differ in
            # (l, expected_bits) — and, on the reference backend, b — share
            # one compile
            p_norm = EnecParams(b=p.b, n=p.n, m=p.m, L=p.L, l=0)
            if self.config.encode_backend == "pallas":
                from repro.kernels import ops as kernel_ops  # lazy: cycle
                fn = kernel_ops.pipeline_encoder(fmt, p_norm)
            else:
                fn = jax.jit(functools.partial(block_codec.encode_blocks,
                                               fmt=fmt, p=p_norm))
            self._encode_cache[key] = fn
        else:
            self._encode_stats["cache_hits"] += 1
        return fn

    def _decoder_for(self, fmt_name: str, p: EnecParams, block_elems: int,
                     bucket: int):
        key = self._decoder_key(fmt_name, p, block_elems) + (bucket,)
        fn = self._decode_cache.get(key)
        if fn is None:
            if len(self._decode_cache) >= self.config.max_cached_programs:
                self._decode_cache.clear()   # safety valve
            self._decode_stats["compiles"] += 1
            fmt = FORMATS[fmt_name]
            # decode reads (n, m, L) for shapes; (b, l) enter arithmetic
            # only and the reference backend always overrides them with
            # per-block vectors, so params differing in (b, l,
            # expected_bits) share one compile there
            p_norm = EnecParams(b=p.b, n=p.n, m=p.m, L=p.L, l=p.l)
            if self.config.decode_backend == "pallas":
                from repro.kernels import ops as kernel_ops  # lazy: cycle
                fn = kernel_ops.pipeline_decoder(fmt, p_norm, block_elems)
            else:
                fn = jax.jit(functools.partial(block_codec.decode_blocks,
                                               n_elems=block_elems, fmt=fmt,
                                               p=p_norm))
            self._decode_cache[key] = fn
        else:
            self._decode_stats["cache_hits"] += 1
        return fn

    def _encode_bucketed(self, bits, fmt: FloatFormat, p: EnecParams,
                         block_elems: int, b_vec=None) -> BlockStreams:
        """One encode dispatch for a (B, N) block array, compile-cached on
        the bucketed block count (pad with zero blocks, slice the result).

        ``b_vec`` optionally carries a per-block linear-map parameter so
        blocks from stacks with different searched ``b`` share the dispatch.
        """
        nblocks = bits.shape[0]
        bucket = self._block_bucket(nblocks)
        if self.config.encode_backend != "pallas" and b_vec is None:
            b_vec = jnp.full((nblocks,), p.b, jnp.int32)
        if bucket != nblocks:
            self._encode_stats["padded_blocks"] += bucket - nblocks
            bits = jnp.concatenate(
                [bits,
                 jnp.zeros((bucket - nblocks, bits.shape[1]), bits.dtype)])
            if b_vec is not None:
                b_vec = jnp.concatenate(
                    [b_vec, jnp.full((bucket - nblocks,), p.b, jnp.int32)])
        fn = self._encoder_for(fmt.name, p, block_elems, bucket)
        self._encode_stats["dispatches"] += 1
        streams = fn(bits) if b_vec is None else fn(bits, b_vec=b_vec)
        if bucket != nblocks:
            streams = jax.tree.map(lambda a: a[:nblocks], streams)
        return streams

    def _decode_bucketed(self, streams: BlockStreams, fmt: FloatFormat,
                         p: EnecParams, block_elems: int,
                         b_vec=None, l_vec=None, exact=False):
        """One decode dispatch for flat (B, ...) block streams; mirror of
        :meth:`_encode_bucketed` (per-block ``b_vec`` / ``l_vec`` let
        tensors with different searched ``(b, l)`` share the dispatch).
        ``exact=True`` decodes the true block count without bucket
        rounding (zero pad waste; see :meth:`plan_decode`)."""
        nblocks = streams.mask.shape[0]
        bucket = nblocks if exact else self._block_bucket(nblocks)
        if self.config.decode_backend != "pallas":
            if b_vec is None:
                b_vec = jnp.full((nblocks,), p.b, jnp.int32)
            if l_vec is None:
                l_vec = jnp.full((nblocks,), p.l, jnp.int32)
        if bucket != nblocks:
            self._decode_stats["padded_blocks"] += bucket - nblocks
            pad = bucket - nblocks
            streams = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]), streams)
            if b_vec is not None:
                b_vec = jnp.concatenate(
                    [b_vec, jnp.full((pad,), p.b, jnp.int32)])
                l_vec = jnp.concatenate(
                    [l_vec, jnp.full((pad,), p.l, jnp.int32)])
        fn = self._decoder_for(fmt.name, p, block_elems, bucket)
        self._decode_stats["dispatches"] += 1
        bits = (fn(streams) if b_vec is None
                else fn(streams, b_vec=b_vec, l_vec=l_vec))
        return bits[:nblocks] if bucket != nblocks else bits

    # -- plan_encode ------------------------------------------------------

    def plan_encode(self, tree, *, stacked: bool = False,
                    p: Optional[EnecParams] = None,
                    block_elems: Optional[int] = None,
                    shards: int = 1) -> EncodePlan:
        """Build the encode schedule for every array leaf of ``tree``.

        ``stacked=False`` (default) compresses each leaf as one tensor
        (:meth:`compress_tree` semantics — escapes produce const/raw
        tensors); ``stacked=True`` treats each leaf as an ``(L, ...)``
        layer stack (:meth:`compress_stacked_many` semantics — escapes
        resolve to ``None``: the stack must stay dense).

        The plan is pure data + staged device blocks: statistics are one
        jit dispatch per leaf with ONE batched host transfer, the host-side
        histogram search runs here, and leaves sharing an encoder bucket
        ``(backend, fmt, params-key, block_elems, block-count bucket)`` are
        assigned to one :class:`EncodeBucket` == one future jit dispatch.
        Nothing is encoded until :meth:`execute`.
        """
        if p is None:
            p = self.config.shared_params
        if block_elems is None:
            block_elems = self.config.block_elems
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        fallbacks: dict = {}    # slot -> ("dense" | "const", host_first)
        prepared = []           # (slot, fmt, bits2d, layer_shape, dtype, dev)
        for slot, x in enumerate(leaves):
            x = jnp.asarray(x)
            leaves[slot] = x
            xs = x if stacked else x[None]
            if xs.ndim < 1 or not _is_supported_float(xs) or xs.size == 0:
                fallbacks[slot] = ("dense", None)
                continue
            fmt = format_for(xs.dtype)
            bits2d = xs.reshape(xs.shape[0], -1).view(fmt.uint_dtype)
            prepared.append((slot, fmt, bits2d, xs.shape[1:], str(xs.dtype),
                             stats_mod.stack_stats_device(bits2d, fmt)))
        host_stats = stats_mod.fetch_stats([pr[-1] for pr in prepared])

        # host search + block layout, grouped by encoder key
        groups: Dict[tuple, list] = {}
        for (slot, fmt, bits2d, layer_shape, dtype_str, _), st in zip(
                prepared, host_stats):
            if st.is_const.any():
                # parity with the per-leaf const escape: a constant layer
                # keeps the whole stack dense (stacked) / stores the single
                # value (per-leaf)
                fallbacks[slot] = ("const", st.first)
                continue
            pi = (params_mod.search(st.hist, fmt, block_elems=block_elems)
                  if p is None else p)
            # one widen to the stack's exact bounds: covers transferred
            # params and sampled histograms
            pi = params_mod.widen_for_range(pi, *st.bounds())
            blocks, per_layer_blocks = block_codec.stacked_blocks(
                bits2d, block_elems, shards,
                pad_value=pi.b << fmt.mant_bits)
            key = self._encoder_key(fmt.name, pi, block_elems)
            groups.setdefault(key, []).append(dict(
                slot=slot, fmt=fmt, p=pi, blocks=blocks,
                n_layers=bits2d.shape[0], layer_shape=layer_shape,
                dtype_str=dtype_str, per_layer_blocks=per_layer_blocks,
                raw_bytes=bits2d.size * jnp.dtype(dtype_str).itemsize))

        buckets = []
        for key, members in groups.items():
            nblocks = sum(m["blocks"].shape[0] for m in members)
            predicted = sum(
                int(m["raw_bytes"] / expected_ratio(m["p"], m["fmt"]))
                for m in members)
            buckets.append(EncodeBucket(
                backend=key[0], fmt_name=key[1], params_key=key[2],
                block_elems=key[3], block_bucket=self._block_bucket(nblocks),
                nblocks=nblocks, n_tensors=len(members),
                predicted_wire_bytes=predicted))
        return EncodePlan(
            config=self.config, buckets=tuple(buckets),
            n_inputs=len(leaves), n_fallback=len(fallbacks),
            stacked=stacked, shards=shards, block_elems=block_elems,
            _treedef=treedef, _groups=list(groups.values()),
            _fallbacks=fallbacks, _leaves=leaves)

    # -- plan_decode ------------------------------------------------------

    def plan_decode(self, tree, *, exact: bool = False) -> DecodePlan:
        """Build the decode schedule for every :class:`CompressedTensor` in
        ``tree`` (any pytree; a plain list of tensors — with ``None`` holes
        — works too).  Tensors sharing a decoder bucket are assigned to one
        :class:`DecodeBucket` == one future jit dispatch; const/raw tensors
        and non-compressed leaves restore without any dispatch
        (``n_passthrough``).  ``exact=True`` disables block-count bucket
        rounding — each dispatch decodes its true block count (no pad
        waste), at the cost of one compiled decoder per distinct count;
        use it when the same tensor set decodes repeatedly (the overlap
        scheduler's per-layer prefetch)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_ct)
        passthrough: dict = {}   # slot -> "ct" (const/raw) | "identity"
        groups: Dict[tuple, list] = {}
        for slot, leaf in enumerate(leaves):
            if not _is_ct(leaf):
                passthrough[slot] = "identity"
                continue
            if leaf.mode != "enec":
                passthrough[slot] = "ct"
                continue
            key = self._decoder_key(leaf.fmt_name, leaf.params,
                                    leaf.block_elems)
            groups.setdefault(key, []).append(dict(
                slot=slot, ct=leaf, stack=_stack_dim(leaf),
                flat=_flatten_streams(leaf.streams)))
        buckets = []
        for key, members in groups.items():
            nblocks = sum(m["flat"].mask.shape[0] for m in members)
            buckets.append(DecodeBucket(
                backend=key[0], fmt_name=key[1], params_key=key[2],
                block_elems=key[3],
                block_bucket=nblocks if exact
                else self._block_bucket(nblocks),
                nblocks=nblocks, n_tensors=len(members)))
        return DecodePlan(
            config=self.config, buckets=tuple(buckets),
            n_inputs=len(leaves), n_passthrough=len(passthrough),
            exact=exact, _treedef=treedef, _groups=list(groups.values()),
            _passthrough=passthrough, _leaves=leaves)

    # -- execute ----------------------------------------------------------

    def execute(self, plan):
        """Run a plan's batched dispatches and return the output tree.

        Launches EXACTLY ``len(plan.buckets)`` jit dispatches (one per
        bucket) plus, for encode plans, one batched host transfer for the
        never-worse wire-size escape.  The plan must have been built by a
        codec with this configuration (compile-cache keys depend on it).
        """
        if isinstance(plan, EncodePlan):
            if plan.config != self.config:
                raise ValueError(
                    "plan was built under a different CodecConfig — "
                    "re-plan with this codec before executing")
            return self._execute_encode(plan)
        if isinstance(plan, DecodePlan):
            if plan.config != self.config:
                raise ValueError(
                    "plan was built under a different CodecConfig — "
                    "re-plan with this codec before executing")
            return self._execute_decode(plan)
        raise TypeError(f"not a plan: {type(plan).__name__}")

    def _execute_encode(self, plan: EncodePlan):
        results: List[Optional[CompressedTensor]] = [None] * plan.n_inputs
        shards = plan.shards
        for members in plan._groups:
            if len(members) == 1:
                all_blocks = members[0]["blocks"]
            else:
                all_blocks = jnp.concatenate([m["blocks"] for m in members])
            b_vec = None
            if self.config.encode_backend != "pallas":
                b_vec = jnp.concatenate(
                    [jnp.full((m["blocks"].shape[0],), m["p"].b, jnp.int32)
                     for m in members])
            # block arrays are (B, block_elems), so the group's block size
            # is simply the trailing dim
            streams = self._encode_bucketed(
                all_blocks, members[0]["fmt"], members[0]["p"],
                members[0]["blocks"].shape[1], b_vec=b_vec)
            offset = 0
            for m in members:
                nb = m["blocks"].shape[0]
                s = jax.tree.map(lambda a: a[offset:offset + nb], streams)
                offset += nb
                n_layers, plb = m["n_layers"], m["per_layer_blocks"]
                lead = ((n_layers, shards, plb // shards) if shards > 1
                        else (n_layers, plb))
                s = jax.tree.map(lambda a: a.reshape(lead + a.shape[1:]), s)
                results[m["slot"]] = CompressedTensor(
                    streams=s, raw_bytes=None, fmt_name=m["fmt"].name,
                    params=m["p"], shape=tuple(m["layer_shape"]),
                    dtype_str=m["dtype_str"],
                    block_elems=m["blocks"].shape[1],
                    shards=shards, mode="enec")

        # never-worse escape: ONE batched transfer for every stack's
        # high_len, which also fills the nbytes_wire caches
        pending = [(slot, ct) for slot, ct in enumerate(results)
                   if ct is not None]
        if pending:
            high_lens = jax.device_get(
                [ct.streams.high_len for _, ct in pending])
            for (slot, ct), hl in zip(pending, high_lens):
                n_layers = ct.streams.mask.shape[0]
                wire = ct._set_wire_bytes(hl)
                if wire >= n_layers * ct.nbytes_raw():
                    results[slot] = None

        if not plan.stacked:
            results = self._finish_per_leaf(plan, results)
        return jax.tree_util.tree_unflatten(plan._treedef, results)

    def _finish_per_leaf(self, plan: EncodePlan, results):
        """Per-leaf (compress_tree) semantics: unwrap the L=1 stacks and
        resolve escapes to const/raw tensors instead of ``None``."""
        out = []
        for slot, ct in enumerate(results):
            if ct is not None:
                wire_bytes = ct._wire_bytes        # survives the unstack
                ct = slice_stacked(ct, 0)
                ct._wire_bytes = wire_bytes
                out.append(ct)
                continue
            x = plan._leaves[slot]
            kind, first = plan._fallbacks.get(slot, ("dense", None))
            if kind == "const":
                fmt = format_for(x.dtype)
                out.append(CompressedTensor(
                    streams=None,
                    raw_bytes=jnp.asarray(first[:1]).view(jnp.uint8),
                    fmt_name=fmt.name, params=None, shape=tuple(x.shape),
                    dtype_str=str(x.dtype), block_elems=plan.block_elems,
                    shards=plan.shards, mode="const"))
            else:
                # unsupported dtype / empty / incompressible: raw escape
                out.append(_raw_tensor(x, plan.shards))
        return out

    def _execute_decode(self, plan: DecodePlan):
        results: List[Optional[Any]] = [None] * plan.n_inputs
        # passthrough leaves: identity for non-tensors, direct expansion
        # for const/raw tensors (no dispatch either way)
        for slot, kind in plan._passthrough.items():
            leaf = plan._leaves[slot]
            results[slot] = (self.decompress_array(leaf) if kind == "ct"
                             else leaf)
        for members in plan._groups:
            if len(members) == 1:
                flat = members[0]["flat"]
            else:
                flat = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                    *[m["flat"] for m in members])
            p0 = members[0]["ct"].params
            b_vec = l_vec = None
            if self.config.decode_backend != "pallas":
                b_vec = jnp.concatenate(
                    [jnp.full((m["flat"].mask.shape[0],), m["ct"].params.b,
                              jnp.int32) for m in members])
                l_vec = jnp.concatenate(
                    [jnp.full((m["flat"].mask.shape[0],), m["ct"].params.l,
                              jnp.int32) for m in members])
            bits = self._decode_bucketed(flat, members[0]["ct"].fmt, p0,
                                         members[0]["ct"].block_elems,
                                         b_vec=b_vec, l_vec=l_vec,
                                         exact=plan.exact)
            offset = 0
            for m in members:
                nb = m["flat"].mask.shape[0]
                bits_m = bits[offset:offset + nb]
                offset += nb
                ct = m["ct"]
                results[m["slot"]] = (
                    block_codec.from_blocks(bits_m, ct.shape, ct.fmt)
                    if m["stack"] is None
                    else _stacked_from_bits(ct, m["stack"], bits_m))
        return jax.tree_util.tree_unflatten(plan._treedef, results)

    # -- single-array convenience (direct ports of the legacy functions) --

    def compress_array(self, x, p: Optional[EnecParams] = None,
                       block_elems: Optional[int] = None,
                       shards: int = 1) -> CompressedTensor:
        """Compress one array. ``p=None`` uses the config's params policy
        (per-tensor histogram search unless ``shared_params`` is set).

        Device-resident: statistics are one jit'd reduction, only the
        histogram crosses to the host, and the full tensor is never
        transferred.
        """
        if p is None:
            p = self.config.shared_params
        if block_elems is None:
            block_elems = self.config.block_elems
        x = jnp.asarray(x)
        if not _is_supported_float(x) or x.size == 0:
            return _raw_tensor(x, shards)
        fmt = format_for(x.dtype)
        flat_bits = jnp.ravel(x).view(fmt.uint_dtype)
        st = stats_mod.stack_stats(flat_bits[None, :], fmt)
        # constant-tensor escape (RZE-style, LC framework §II-C)
        if bool(st.is_const[0]):
            return CompressedTensor(
                streams=None,
                raw_bytes=jnp.asarray(st.first[:1]).view(jnp.uint8),
                fmt_name=fmt.name, params=None, shape=tuple(x.shape),
                dtype_str=str(x.dtype), block_elems=block_elems,
                shards=shards, mode="const")
        if p is None:
            p = params_mod.search(st.hist, fmt, block_elems=block_elems)
        # widen to the EXACT exponent bounds: a no-op for freshly searched
        # params on an exact histogram, the lossless escape for transferred
        # params, and the correctness guarantee for sampled histograms
        p = params_mod.widen_for_range(p, *st.bounds())
        bits, _ = block_codec.bits_to_blocks(flat_bits, block_elems, shards,
                                             pad_value=p.b << fmt.mant_bits)
        streams = self._encode_bucketed(bits, fmt, p, block_elems)
        if shards > 1:
            streams = jax.tree.map(
                lambda a: a.reshape((shards, a.shape[0] // shards)
                                    + a.shape[1:]),
                streams)
        ct = CompressedTensor(
            streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
            shape=tuple(x.shape), dtype_str=str(x.dtype),
            block_elems=block_elems, shards=shards, mode="enec")
        if ct.nbytes_wire() >= ct.nbytes_raw():
            return _raw_tensor(x, shards)  # incompressible: raw escape
        return ct

    def decompress_array(self, ct: CompressedTensor):
        """Exact inverse of :meth:`compress_array` (jit-compatible).

        Rides the bucketed, compile-cached decoder, so even per-leaf calls
        share compiled decode programs across tensors; use
        :meth:`decompress_stacked_many` / :meth:`plan_decode` to share the
        *dispatch* too.
        """
        dtype = jnp.dtype(ct.dtype_str)
        if ct.mode == "const":
            value = ct.raw_bytes.view(dtype)[0]
            return jnp.broadcast_to(value, ct.shape)
        if ct.mode == "raw":
            return ct.raw_bytes.view(dtype).reshape(ct.shape)
        bits = self._decode_bucketed(_flatten_streams(ct.streams), ct.fmt,
                                     ct.params, ct.block_elems)
        return block_codec.from_blocks(bits, ct.shape, ct.fmt)

    # -- stacked (layer-stack) API ---------------------------------------

    def compress_stacked_many(self, stacks: Sequence[Any],
                              p: Optional[EnecParams] = None,
                              block_elems: Optional[int] = None,
                              shards: int = 1
                              ) -> List[Optional[CompressedTensor]]:
        """Compress many ``(L, ...)`` layer stacks with O(#buckets)
        dispatches: :meth:`plan_encode` + :meth:`execute`.  Returns one
        entry per stack — a stacked :class:`CompressedTensor`, or ``None``
        when the stack must stay dense (unsupported dtype, a constant
        layer, or incompressible data)."""
        plan = self.plan_encode(list(stacks), stacked=True, p=p,
                                block_elems=block_elems, shards=shards)
        return self.execute(plan)

    def compress_stacked(self, x, p: Optional[EnecParams] = None,
                         block_elems: Optional[int] = None,
                         shards: int = 1) -> Optional[CompressedTensor]:
        """Compress one ``(L, ...)`` layer stack in a single encode
        dispatch; ``None`` when the stack must stay dense."""
        return self.compress_stacked_many([x], p, block_elems, shards)[0]

    def decompress_stacked(self, ct: CompressedTensor):
        """Inverse of :meth:`compress_stacked`: one dispatch -> (L, ...)."""
        n_layers = ct.streams.mask.shape[0]
        bits = self._decode_bucketed(_flatten_streams(ct.streams), ct.fmt,
                                     ct.params, ct.block_elems)
        return _stacked_from_bits(ct, n_layers, bits)

    def decompress_stacked_many(self, cts: Sequence[Optional[CompressedTensor]],
                                *, exact: bool = False
                                ) -> List[Optional[Any]]:
        """Decompress many tensors with O(#buckets) decode dispatches:
        :meth:`plan_decode` + :meth:`execute`.  Accepts any mix of per-leaf
        and stacked tensors plus ``const`` / ``raw`` / ``None`` entries;
        outputs are bit-identical to the per-leaf path (``exact`` only
        drops the pad blocks a bucketed dispatch would decode and slice
        away — see :meth:`plan_decode`)."""
        plan = self.plan_decode(list(cts), exact=exact)
        return self.execute(plan)

    # -- pytree API -------------------------------------------------------

    def compress_tree(self, tree, shared_params: Optional[EnecParams] = None,
                      block_elems: Optional[int] = None, shards: int = 1):
        """Compress every leaf with O(#buckets) encode dispatches; float
        leaves get per-tensor searched params (or ``shared_params`` /
        the config's params policy)."""
        plan = self.plan_encode(tree, stacked=False, p=shared_params,
                                block_elems=block_elems, shards=shards)
        return self.execute(plan)

    def decompress_tree(self, ctree):
        """Inverse of :meth:`compress_tree` with O(#buckets) dispatches."""
        return self.execute(self.plan_decode(ctree))

    # -- tile-wise compression for the fused decompress+matmul kernel -----

    def tile_weights_for_fusion_many(self, ws: Sequence[Any],
                                     p: Optional[EnecParams] = None,
                                     shards: int = 1
                                     ) -> List[Optional[CompressedTensor]]:
        """Compress many (L, K, N) / (K, N) matmul weights tile-wise for
        the fused kernel, riding :meth:`compress_stacked_many`: per-stack
        searched params, one encode dispatch per bucket, never-worse
        escape intact (``None`` entries must stay dense).

        ``shards > 1`` splits each layer's tile-block axis into contiguous
        TP shard ranges; the flat (n-major) tile order is preserved, so the
        fused kernel consumes the re-flattened streams unchanged.  Every
        weight's tile-block count must divide by ``shards`` (no pad blocks
        are allowed inside a fused stream — see
        :func:`repro.runtime.streaming.fused_shards`)."""
        tiles = [matmul_tiles(w) for w in ws]
        if shards > 1:
            for w, t in zip(ws, tiles):
                blocks = t.shape[-1] // DEFAULT_BLOCK_ELEMS
                if blocks % shards:
                    raise ValueError(
                        f"fused tile stream of {tuple(jnp.shape(w))} has "
                        f"{blocks} tile blocks — not divisible into "
                        f"{shards} shards (pad blocks would corrupt the "
                        f"kernel's flat tile order)")
        return self.compress_stacked_many(
            tiles, p=p, block_elems=DEFAULT_BLOCK_ELEMS, shards=shards)

    def tile_weights_for_fusion(self, w, p: Optional[EnecParams] = None
                                ) -> CompressedTensor:
        """Compress one weight tile-wise for the fused kernel; raises on
        the incompressible escape (callers that need the fallback use
        :meth:`tile_weights_for_fusion_many`)."""
        squeeze = jnp.asarray(w).ndim == 2
        ct = self.tile_weights_for_fusion_many([w], p)[0]
        if ct is None:
            raise ValueError(
                "weight is incompressible or constant — serve dense")
        if squeeze:
            ct = dataclasses.replace(
                ct, streams=jax.tree.map(lambda a: a[0], ct.streams))
        return ct

    def untile_matmul_weight(self, ct: CompressedTensor, k: int, n: int):
        """Inverse of :func:`core.api.matmul_tiles` for ONE layer slice of
        a tile-wise tensor: decompress, un-permute, strip the padding."""
        t = MATMUL_TILE
        kp, np_ = -(-k // t) * t, -(-n // t) * t
        flat = self.decompress_array(ct)
        tiles = flat.reshape(np_ // t, kp // t, t, t)
        return tiles.transpose(1, 2, 0, 3).reshape(kp, np_)[:k, :n]


# ---------------------------------------------------------------------------
# the ambient codec: process default + context override
# ---------------------------------------------------------------------------

_default_lock = threading.Lock()
_default: Optional[Codec] = None
_ambient: contextvars.ContextVar = contextvars.ContextVar(
    "repro_enec_codec", default=None)


def default_codec() -> Codec:
    """The lazily-created process-default :class:`Codec` — the instance the
    legacy ``core.api`` wrappers operate on."""
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = Codec()
    return _default


def set_default_codec(codec: Codec) -> Codec:
    """Replace the process-default codec; returns the previous one (which
    may be freshly created if none existed yet)."""
    global _default
    prev = default_codec()
    _default = codec
    return prev


def current_codec() -> Codec:
    """The ambient codec: the innermost :func:`use_codec` context if one is
    active, else :func:`default_codec`."""
    return _ambient.get() or default_codec()


@contextlib.contextmanager
def use_codec(codec: Codec):
    """Context manager installing ``codec`` as the ambient codec — every
    legacy wrapper and codec-default consumer inside the block uses it."""
    token = _ambient.set(codec)
    try:
        yield codec
    finally:
        _ambient.reset(token)
