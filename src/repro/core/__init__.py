"""ENEC core: the paper's contribution as a composable JAX module."""
from .api import (CompressedTensor, abstract_compressed, compress_array,
                  compress_stacked, compress_stacked_many, compress_tree,
                  decode_cache_stats, decompress_array, decompress_stacked,
                  decompress_stacked_many, decompress_tree,
                  encode_cache_stats, precompute_wire_bytes,
                  reset_decode_cache_stats, reset_encode_cache_stats,
                  set_decode_backend, set_encode_backend, slice_stacked,
                  tree_ratio)
from .codec import BlockStreams, decode_blocks, encode_blocks
from .dtypes import BF16, FORMATS, FP16, FP32, FloatFormat, format_for
from .params import (DEFAULT_BLOCK_ELEMS, EnecParams, expected_ratio, search,
                     search_for_array)
from .stats import StackStats, exponent_histogram_device, stack_stats

__all__ = [
    "CompressedTensor", "abstract_compressed", "compress_array",
    "compress_stacked", "compress_stacked_many", "compress_tree",
    "decode_cache_stats", "decompress_array", "decompress_stacked",
    "decompress_stacked_many", "decompress_tree",
    "encode_cache_stats", "precompute_wire_bytes",
    "reset_decode_cache_stats", "reset_encode_cache_stats",
    "set_decode_backend", "set_encode_backend", "slice_stacked", "tree_ratio",
    "BlockStreams", "decode_blocks", "encode_blocks",
    "BF16", "FORMATS", "FP16", "FP32", "FloatFormat", "format_for",
    "DEFAULT_BLOCK_ELEMS", "EnecParams", "expected_ratio", "search",
    "search_for_array",
    "StackStats", "exponent_histogram_device", "stack_stats",
]
