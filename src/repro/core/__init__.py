"""ENEC core: the paper's contribution as a composable JAX module."""
from .api import (CompressedTensor, abstract_compressed, compress_array,
                  compress_tree, decompress_array, decompress_tree, tree_ratio)
from .codec import BlockStreams, decode_blocks, encode_blocks
from .dtypes import BF16, FORMATS, FP16, FP32, FloatFormat, format_for
from .params import (DEFAULT_BLOCK_ELEMS, EnecParams, expected_ratio, search,
                     search_for_array)

__all__ = [
    "CompressedTensor", "abstract_compressed", "compress_array",
    "compress_tree", "decompress_array", "decompress_tree", "tree_ratio",
    "BlockStreams", "decode_blocks", "encode_blocks",
    "BF16", "FORMATS", "FP16", "FP32", "FloatFormat", "format_for",
    "DEFAULT_BLOCK_ELEMS", "EnecParams", "expected_ratio", "search",
    "search_for_array",
]
