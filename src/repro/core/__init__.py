"""ENEC core: the paper's contribution as a composable JAX module.

The v1 public API is :class:`Codec` / :class:`CodecConfig` with the
plan/execute split (``plan_encode`` / ``plan_decode`` / ``execute``) — see
docs/API.md for the stability contract.  The module-level compression
functions (``compress_array`` et al.) are deprecated wrappers over the
ambient codec (:func:`current_codec`), kept for pre-Codec callers.
"""
from .api import (DEPRECATED_WRAPPERS, CompressedTensor, abstract_compressed,
                  compress_array, compress_stacked, compress_stacked_many,
                  compress_tree, decode_cache_stats, decompress_array,
                  decompress_stacked, decompress_stacked_many,
                  decompress_tree, encode_cache_stats, matmul_tiles,
                  precompute_wire_bytes, reset_decode_cache_stats,
                  reset_encode_cache_stats, set_decode_backend,
                  set_encode_backend, slice_stacked, tile_weights_for_fusion,
                  tile_weights_for_fusion_many, tree_ratio,
                  untile_matmul_weight)
from .codec import BlockStreams, decode_blocks, encode_blocks
from .codec_api import (BACKENDS, Codec, CodecConfig, DecodeBucket,
                        DecodePlan, EncodeBucket, EncodePlan, current_codec,
                        default_codec, set_default_codec, use_codec)
from .dtypes import BF16, FORMATS, FP16, FP32, FloatFormat, format_for
from .params import (DEFAULT_BLOCK_ELEMS, EnecParams, expected_ratio, search,
                     search_for_array)
from .stats import StackStats, exponent_histogram_device, stack_stats

__all__ = [
    # -- v1 public API: instance-scoped codec + plan/execute --------------
    "BACKENDS", "Codec", "CodecConfig",
    "DecodeBucket", "DecodePlan", "EncodeBucket", "EncodePlan",
    "current_codec", "default_codec", "set_default_codec", "use_codec",
    # -- data model + stateless utilities ---------------------------------
    "CompressedTensor", "abstract_compressed", "matmul_tiles",
    "precompute_wire_bytes", "slice_stacked", "tree_ratio",
    # -- deprecated module-level wrappers (DEPRECATED_WRAPPERS lists them) -
    "DEPRECATED_WRAPPERS",
    "compress_array", "compress_stacked", "compress_stacked_many",
    "compress_tree", "decode_cache_stats", "decompress_array",
    "decompress_stacked", "decompress_stacked_many", "decompress_tree",
    "encode_cache_stats", "reset_decode_cache_stats",
    "reset_encode_cache_stats", "set_decode_backend", "set_encode_backend",
    "tile_weights_for_fusion", "tile_weights_for_fusion_many",
    "untile_matmul_weight",
    # -- block codec / formats / params / stats ----------------------------
    "BlockStreams", "decode_blocks", "encode_blocks",
    "BF16", "FORMATS", "FP16", "FP32", "FloatFormat", "format_for",
    "DEFAULT_BLOCK_ELEMS", "EnecParams", "expected_ratio", "search",
    "search_for_array",
    "StackStats", "exponent_histogram_device", "stack_stats",
]
