"""ENEC parameter tuning (paper §V-E): offline histogram-driven search.

Phase 1: exponent histogram -> p(x), l, h.
Phase 2: exhaustive search of the linear-map parameter ``b``; base width
         ``n`` from Eq. 1; cost ``D = sum p(x) * y`` (Eq. 3).
Phase 3: joint search of threshold ``m`` and group length ``L`` minimizing
         expected bits  B_exp = 1/L + n + (m - n) * p(m)**L   (Eq. 4).

Host-side numpy only — runs once per tensor in O(256^2), negligible next to
any real compression job (the paper runs this offline too, §VI).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .dtypes import FloatFormat

# Group lengths must be >= 16 (32-byte alignment on Ascend; a (8,128) vreg
# quantum on TPU points the same way) and divide the block size.
CANDIDATE_GROUP_LENGTHS = (16, 32, 64, 128)
DEFAULT_BLOCK_ELEMS = 16384  # paper §VI-D: best block size that fits local memory


@dataclasses.dataclass(frozen=True)
class EnecParams:
    """The (b, n, m, L) tuple of paper Table IV plus bookkeeping fields."""
    b: int          # linear mapping parameter
    n: int          # base bit-width (incl. the wrap sign bit, Eq. 1)
    m: int          # encoding threshold bit-width (m <= n)
    L: int          # group length
    l: int          # min exponent at search time (needed for exact inverse)
    expected_bits: float = 0.0   # predicted exponent bits/element (Eq. 4)

    def astuple(self):
        return (self.b, self.n, self.m, self.L)


def exponent_histogram(exp: np.ndarray, exp_bits: int) -> np.ndarray:
    return np.bincount(exp.reshape(-1).astype(np.int64), minlength=1 << exp_bits)


def _bits_for(v: int) -> int:
    """floor(log2(v)) + 1 for v >= 1, else 0."""
    return int(v).bit_length()


def _bits_ceil(v: int) -> int:
    """ceil(log2(v)) for v >= 1, else 0."""
    if v <= 0:
        return 0
    return int(math.ceil(math.log2(v))) if v > 1 else 0


def base_width_for(b: int, l: int, h: int) -> int:
    """Eq. 1: minimal n such that y = (b - x) mod 2**n is injective on [l, h]."""
    n = max(_bits_for(b - l), _bits_ceil(h - b)) + 1
    # Guard the paper's formula with the exact injectivity condition.
    while (h - l) >= (1 << n):
        n += 1
    return n


def _phase3(p: np.ndarray, b: int, n: int, block_elems: int,
            group_lengths) -> tuple:
    """Eq. 4 joint (m, L) search for a fixed (b, n). Returns (B_exp, m, L)."""
    xs = np.arange(p.shape[0], dtype=np.int64)
    y = (b - xs) % (1 << n)
    widths = np.array([_bits_for(int(v)) for v in y])
    p_le = np.array([float(p[widths <= m].sum()) for m in range(n + 1)])
    best = (1.0 / max(group_lengths) + n, n, max(group_lengths))
    for L in group_lengths:
        if L > block_elems or block_elems % L or (block_elems // L) % 8:
            continue
        for m in range(1, n + 1):
            bexp = 1.0 / L + n + (m - n) * (p_le[m] ** L)
            if bexp < best[0]:
                best = (bexp, m, L)
    return best


def search(hist: np.ndarray, fmt: FloatFormat,
           block_elems: int = DEFAULT_BLOCK_ELEMS,
           group_lengths=CANDIDATE_GROUP_LENGTHS,
           mode: str = "paper") -> EnecParams:
    """Full §V-E search. ``hist``: exponent histogram (len 2**exp_bits).

    mode="paper": faithful two-phase search — Phase 2 minimizes the
    probability-weighted transformed value D (Eq. 3), Phase 3 then picks
    (m, L) via Eq. 4.
    mode="joint": beyond-paper — minimize the *final* objective B_exp over
    (b, n, m, L) directly (still O(256·n·m·L), trivial offline).  Strictly
    at least as good as the two-phase search; see bench_ablation.
    """
    total = int(hist.sum())
    if total == 0:
        return EnecParams(b=0, n=1, m=1, L=group_lengths[0], l=0, expected_bits=1.0)
    nz = np.nonzero(hist)[0]
    l, h = int(nz[0]), int(nz[-1])
    p = hist / total
    xs = np.arange(hist.shape[0], dtype=np.int64)

    if mode == "paper":
        # -- Phase 2: exhaustive b, n from Eq. 1, minimize D = sum p(x)*y --
        best = None
        for b in range(l, h + 1):
            n = base_width_for(b, l, h)
            y = (b - xs) % (1 << n)
            d = float(np.dot(p, y))
            key = (d, n)
            if best is None or key < best[0]:
                best = (key, b, n)
        _, b_star, n_star = best
        bexp, m_star, l_star = _phase3(p, b_star, n_star, block_elems,
                                       group_lengths)
    elif mode == "joint":
        best = None
        for b in range(l, h + 1):
            n_min = base_width_for(b, l, h)
            for n in (n_min, n_min + 1):  # a wider n can enable a better m
                if n > fmt.exp_bits + 1:
                    continue
                bexp, m, L = _phase3(p, b, n, block_elems, group_lengths)
                if best is None or bexp < best[0]:
                    best = (bexp, b, n, m, L)
        bexp, b_star, n_star, m_star, l_star = best
    else:
        raise ValueError(f"unknown search mode {mode!r}")
    return EnecParams(b=b_star, n=n_star, m=m_star, L=l_star, l=l,
                      expected_bits=float(bexp))


def search_for_array(x: np.ndarray, fmt: FloatFormat, **kw) -> EnecParams:
    """Search params for a concrete weight array (host path)."""
    bits = np.ascontiguousarray(x).view(fmt.np_uint_dtype)
    exp = (bits >> fmt.mant_bits) & fmt.exp_mask
    return search(exponent_histogram(exp, fmt.exp_bits), fmt, **kw)


def widen_for_range(params: EnecParams, l: int, h: int) -> EnecParams:
    """Widening escape for transferred params (DESIGN.md §2.iii).

    Decode recovers ``x = params.l + ((b - y - params.l) mod 2**n)``, so the
    round trip is exact iff every exponent lies in the window
    ``[params.l, params.l + 2**n)``.  When this tensor's observed range
    ``[l, h]`` escapes that window — below, above, or on BOTH ends — lower
    ``l`` and/or grow ``n`` by the minimum that restores coverage, keeping
    (b, m, L) untouched; losslessness is unconditional.  ``m <= n`` is
    preserved because ``n`` only ever grows.

    (Historical note: this used to route through :func:`base_width_for`,
    whose Eq. 1 search-time formula carries a +1 wrap-sign margin — it
    widened tensors whose range the decode window already covered, and
    overshot ``n`` when it did widen.)
    """
    if l >= params.l and (h - params.l) < (1 << params.n):
        return params                      # window already covers [l, h]
    l2 = min(params.l, l)
    n = params.n
    while (h - l2) >= (1 << n):
        n += 1
    return dataclasses.replace(params, n=n, l=l2)


def expected_ratio(params: EnecParams, fmt: FloatFormat) -> float:
    """Predicted compression ratio from Eq. 4 ('Formula Avg CR' in the AE)."""
    bits_per_elem = params.expected_bits + fmt.raw_bits
    return fmt.total_bits / bits_per_elem
