"""Pure-host (numpy) ENEC record decode — no device, no jit, no uploads.

The expert-streaming fetch (``runtime/experts.py``) runs inside an ordered
``io_callback`` while the outer jitted step program occupies the device.
Launching device compute from that callback — eager ops or a nested jit —
deadlocks on a single-device backend: the inner decode queues behind the
very program that is blocked waiting for the callback to return.  So the
callback must decode entirely on the host.

This module is the bit-exact numpy port of the reference decode pipeline
(``core.codec.decode_blocks`` + ``from_blocks``): every step is integer
shift/mask/cumsum/gather arithmetic, so the numpy and jax paths produce
identical bits by construction (regression-tested in
``tests/test_experts.py``).  It also owns the host-side record parse — the
same wire layout :func:`core.wire.from_wire` reads, minus the ``h2d``
uploads — and a bucketed batch decode that mirrors the codec's
``plan_decode`` grouping: records sharing ``(fmt, params, block_elems)``
concatenate into ONE vectorized decode call, so a fetch of R records costs
O(#buckets) decode dispatches, not O(R).
"""
from __future__ import annotations

import struct
from typing import NamedTuple, Optional

import numpy as np

from . import bitio
from .codec import stream_shapes
from .dtypes import FORMATS, FloatFormat
from .params import EnecParams
from .wire import MAGIC, WireError, _FMT_FROM_TAG, _MODE_FROM_TAG


class HostRecord(NamedTuple):
    """One parsed wire record, every stream a host numpy array.  ``high``
    is kept in its DENSE per-block form (``(B, block_elems) uint16``) —
    the exact-bit wire stream is unpacked once at parse time and the
    decode consumes it directly, skipping the device path's pad/repack
    round trip (bit-identical: the packed form is a pure relayout)."""
    mode: str
    fmt_name: str
    params: Optional[EnecParams]
    shape: tuple
    dtype_str: str
    block_elems: int
    nblocks: int
    mask: Optional[np.ndarray]
    low: Optional[np.ndarray]
    high: Optional[np.ndarray]
    raw: Optional[np.ndarray]
    raw_bytes: Optional[np.ndarray]   # raw/const modes only


def parse_record(buf, *, record=None) -> HostRecord:
    """Parse one EXACT wire-record slice into host arrays.

    Same validation surface as :func:`core.wire.from_wire` (bad magic,
    truncation, trailing bytes and impossible lengths raise
    :class:`~core.wire.WireError`) but nothing touches the device and no
    transfer counter moves — this is the decode-cache ingest path.
    """
    def _err(msg):
        return WireError(msg, record=record)

    view = memoryview(buf)
    total = len(view)
    off = 0
    try:
        magic, mode_tag, fmt_tag, stack = struct.unpack_from("<IBBH", view, off)
        off += 8
        if magic != MAGIC:
            raise _err(f"bad ENEC wire magic {magic:#x}")
        if mode_tag not in _MODE_FROM_TAG:
            raise _err(f"unknown mode tag {mode_tag}")
        mode = _MODE_FROM_TAG[mode_tag]
        (ndim,) = struct.unpack_from("<I", view, off); off += 4
        if ndim > 16:
            raise _err(f"implausible ndim {ndim}")
        if off + 8 * ndim > total:
            raise _err(f"record truncated in the {ndim}-dim shape")
        shape = tuple(np.frombuffer(view, np.int64, ndim, off).tolist())
        off += 8 * ndim
        (dtype_raw,) = struct.unpack_from("<8s", view, off); off += 8
        dtype_str = bytes(dtype_raw).rstrip(b"\x00").decode()
        np.dtype(_np_dtype(dtype_str))   # must name a real dtype
        block_elems, shards = struct.unpack_from("<II", view, off); off += 8
    except WireError:
        raise
    except (struct.error, UnicodeDecodeError, TypeError, ValueError) as e:
        raise _err(f"corrupt record header: {e}") from None

    if mode in ("raw", "const"):
        raw = np.frombuffer(view, np.uint8, -1, off)
        itemsize = np.dtype(_np_dtype(dtype_str)).itemsize
        expect = (itemsize if mode == "const"
                  else int(np.prod(shape, dtype=np.int64)) * itemsize)
        if raw.nbytes != expect:
            raise _err(
                f"{mode} record carries {raw.nbytes} payload bytes, "
                f"expected {expect} for shape {shape} dtype {dtype_str}")
        return HostRecord(mode, _FMT_FROM_TAG.get(fmt_tag, "bf16"), None,
                          shape, dtype_str, block_elems, 0,
                          None, None, None, None, raw)

    if fmt_tag not in _FMT_FROM_TAG:
        raise _err(f"unknown float format tag {fmt_tag}")
    fmt = FORMATS[_FMT_FROM_TAG[fmt_tag]]
    try:
        b, n, m, L, l = struct.unpack_from("<5i", view, off); off += 20
        (nblocks,) = struct.unpack_from("<I", view, off); off += 4
    except struct.error as e:
        raise _err(f"record truncated in params: {e}") from None
    p = EnecParams(b=b, n=n, m=m, L=L, l=l)
    if not (0 <= m <= n <= 32 and L >= 1 and block_elems >= 1):
        raise _err(f"implausible params {p.astuple()} "
                   f"block_elems={block_elems}")
    if shards < 1 or nblocks % (max(stack, 1) * shards):
        raise _err(f"nblocks={nblocks} not divisible by "
                   f"stack={stack} * shards={shards} — corrupt header")

    def take(nb, what):
        nonlocal off
        need = nblocks * nb
        if off + need > total:
            raise _err(
                f"{what} stream truncated: need {need} bytes at offset "
                f"{off}, record has {total - off} left")
        arr = np.frombuffer(view, np.uint8, need, off).reshape(nblocks, nb)
        off += need
        return arr

    if off + 4 * nblocks > total:
        raise _err("high_len vector truncated")
    high_len = np.frombuffer(view, np.uint32, nblocks, off)
    off += 4 * nblocks
    widths = stream_shapes(block_elems, fmt, p)
    mask = take(widths["mask"], "mask")
    low = take(widths["low"], "low")
    raw = take(widths["raw"], "raw")
    width = p.n - p.m
    dense = np.zeros((nblocks, block_elems), np.uint16)
    if width:
        max_bits = block_elems * width
        for blk in range(nblocks):
            bits = int(high_len[blk])
            if bits < 0 or bits > max_bits:
                raise _err(
                    f"block {blk}: high_len {bits} bits exceeds the "
                    f"{max_bits}-bit block bound — corrupt record")
            nbytes = (bits + 7) // 8
            if off + nbytes > total:
                raise _err(f"block {blk}: high stream truncated")
            count = bits // width
            try:
                dense[blk, :count] = bitio.np_unpack_bits_exact(
                    view[off : off + nbytes], count, width)
            except ValueError as e:
                raise _err(f"block {blk}: {e}") from None
            off += nbytes
    if off != total:
        raise _err(
            f"record has {total - off} trailing bytes after the high "
            f"stream — length mismatch (corrupt or mis-framed)")
    return HostRecord("enec", fmt.name, p, shape, dtype_str, block_elems,
                      nblocks, mask, low, dense, raw, None)


# ---------------------------------------------------------------------------
# numpy ports of the decode kernels (bit-exact vs core.codec / transform)
# ---------------------------------------------------------------------------

def _np_dtype(dtype_str: str):
    """Host dtype for a wire dtype tag; bf16 resolves via ml_dtypes (the
    same registration jax uses, so views/astype agree bit for bit)."""
    if dtype_str == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(dtype_str)


def _unpack_bool_mask_np(mask_bytes: np.ndarray, g: int) -> np.ndarray:
    """numpy port of ``bitio.unpack_bool_mask`` (little-endian bits)."""
    shifts = np.arange(8, dtype=np.uint8)
    bits = (mask_bytes[..., :, None] >> shifts) & np.uint8(1)
    return bits.reshape(mask_bytes.shape[:-1] + (g,)).astype(bool)


def _inverse_np(y: np.ndarray, b, n: int, l) -> np.ndarray:
    """numpy port of ``transform.inverse``: ``x = l + ((b - y - l) mod
    2**n)`` on unsigned lanes.  ``b`` and ``l`` are scalars or per-block
    ``(nblocks, 1)`` columns — like the reference decoder, which takes
    them as traced operands so blocks with different transform offsets
    share one decode program (``Codec._decoder_key``)."""
    mod = y.dtype.type((1 << n) - 1)
    b = np.asarray(b, y.dtype)
    l = np.asarray(l, y.dtype)
    c = (b - l) & mod
    return l + ((c - y) & mod)


def _combine_fields_np(exp: np.ndarray, raw: np.ndarray,
                       fmt: FloatFormat) -> np.ndarray:
    """numpy port of ``dtypes.combine_fields``."""
    ud = fmt.np_uint_dtype
    exp = exp.astype(ud)
    raw = raw.astype(ud)
    sign = raw >> fmt.mant_bits
    mant = raw & ud(fmt.mant_mask)
    return (sign << (fmt.total_bits - 1)) | (exp << fmt.mant_bits) | mant


def decode_blocks_np(mask: np.ndarray, low: np.ndarray, high: np.ndarray,
                     raw: np.ndarray, n_elems: int, fmt: FloatFormat,
                     p: EnecParams, b=None, l=None) -> np.ndarray:
    """numpy port of ``core.codec.decode_blocks`` -> (B, N) uint bits.

    ``high`` arrives DENSE (``(B, N//L, L)``-able uint16, rank-ordered) —
    the parse already unpacked the exact wire bits, so no fixed-width
    unpack round trip is needed here.  ``b``/``l`` override the transform
    offsets per block (``(B, 1)`` columns) when the batch mixes records
    whose searched params share ``(n, m, L)`` but not ``(b, l)``.
    """
    nblocks = mask.shape[0]
    g = n_elems // p.L

    anom = _unpack_bool_mask_np(mask, g)                       # (B, G)
    rank = np.cumsum(anom, axis=1, dtype=np.int32) - anom.astype(np.int32)

    y_low = bitio.unpack_fixed(low, n_elems, p.m, xp=np)
    y_low = np.asarray(y_low).reshape(nblocks, g, p.L)
    high_dense = high.reshape(nblocks, g, p.L)

    gathered = np.take_along_axis(high_dense, rank[:, :, None], axis=1)
    gathered = np.where(anom[:, :, None], gathered, np.uint16(0))

    y = (y_low | (gathered << p.m)).reshape(nblocks, n_elems)
    exp = _inverse_np(y, p.b if b is None else b, p.n,
                      p.l if l is None else l)

    rawv = bitio.unpack_fixed(raw, n_elems, fmt.raw_bits,
                              out_dtype=fmt.np_uint_dtype, xp=np)
    return _combine_fields_np(exp, np.asarray(rawv), fmt)


def _from_blocks_np(bits: np.ndarray, shape: tuple,
                    dtype_str: str) -> np.ndarray:
    size = int(np.prod(shape, dtype=np.int64))
    flat = np.ascontiguousarray(bits).reshape(-1).view(_np_dtype(dtype_str))
    return flat[:size].reshape(shape)


def _decode_trivial(rec: HostRecord) -> np.ndarray:
    dt = _np_dtype(rec.dtype_str)
    if rec.mode == "const":
        return np.broadcast_to(rec.raw_bytes.view(dt), rec.shape).copy()
    return rec.raw_bytes.view(dt).reshape(rec.shape).copy()


def decode_many(recs):
    """Decode parsed records with ONE vectorized numpy decode per bucket.

    Bucket key = ``(fmt, (n, m, L), block_elems)`` — the host mirror of
    the codec's ``plan_decode`` grouping (``Codec._decoder_key``, whose
    reference backend takes the transform offsets ``(b, l)`` as traced
    per-block operands): records whose searched params differ only in
    ``(b, l)`` still share a bucket, concatenate along the block axis,
    and decode in a single vectorized call with per-block offset columns,
    so R records cost O(#buckets) decode dispatches.
    Returns ``(arrays, n_buckets)`` with ``arrays`` aligned to ``recs``;
    raw/const records are relayouts, not dispatches, and don't count.
    """
    out = [None] * len(recs)
    buckets = {}
    for i, rec in enumerate(recs):
        if rec.mode != "enec":
            out[i] = _decode_trivial(rec)
            continue
        if np.dtype(_np_dtype(rec.dtype_str)).itemsize != \
                FORMATS[rec.fmt_name].total_bits // 8:
            raise WireError(
                f"record dtype {rec.dtype_str} does not match float "
                f"format {rec.fmt_name}", record=None)
        p = rec.params
        key = (rec.fmt_name, (p.n, p.m, p.L), rec.block_elems)
        buckets.setdefault(key, []).append(i)
    for (fmt_name, _, block_elems), idxs in buckets.items():
        fmt = FORMATS[fmt_name]
        p = recs[idxs[0]].params
        mask = np.concatenate([recs[i].mask for i in idxs], axis=0)
        low = np.concatenate([recs[i].low for i in idxs], axis=0)
        high = np.concatenate([recs[i].high for i in idxs], axis=0)
        raw = np.concatenate([recs[i].raw for i in idxs], axis=0)
        b_col = np.concatenate(
            [np.full((recs[i].nblocks, 1), recs[i].params.b, np.int64)
             for i in idxs])
        l_col = np.concatenate(
            [np.full((recs[i].nblocks, 1), recs[i].params.l, np.int64)
             for i in idxs])
        bits = decode_blocks_np(mask, low, high, raw, block_elems, fmt, p,
                                b=b_col, l=l_col)
        off = 0
        for i in idxs:
            nb = recs[i].nblocks
            out[i] = _from_blocks_np(bits[off : off + nb], recs[i].shape,
                                     recs[i].dtype_str)
            off += nb
    return out, len(buckets)


def decode_record(rec: HostRecord) -> np.ndarray:
    """Decode one parsed record (single-bucket convenience)."""
    arrs, _ = decode_many([rec])
    return arrs[0]
