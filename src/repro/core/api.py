"""ENEC data model + the legacy module-level compression facade.

``CompressedTensor`` is a registered pytree, so compressed weights flow
through ``jax.jit`` / ``pjit`` / shardings like any other parameters — this
is what makes weight-streaming serving and compressed checkpointing
first-class citizens of the framework rather than host-side tools.

The pipeline itself lives on :class:`repro.core.Codec`
(``core/codec_api.py``): an instance-scoped object owning its own
encoder/decoder compile caches, cache stats, and transfer counters, with an
explicit plan/execute split.  The module-level functions below —
``compress_array`` / ``compress_stacked_many`` / ``set_encode_backend`` and
friends — are **deprecated** thin wrappers over the ambient codec
(:func:`repro.core.current_codec`); they keep pre-Codec callers, trees, and
wire records working bit-identically.  New code should construct a
``Codec`` and call its methods (docs/API.md has the migration table).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import codec
from .codec import BlockStreams
from .dtypes import FORMATS, FloatFormat, format_for
from .params import DEFAULT_BLOCK_ELEMS, EnecParams


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressedTensor:
    """ENEC-compressed view of one tensor.

    mode == "enec": ``streams`` carries the block streams.
    mode == "raw":  ``raw_bytes`` carries the original buffer (escape for
    incompressible / non-float tensors — ratio floor of ~1.0).
    Leading ``shards`` dimension on every stream makes per-device placement
    trivial: shard axis 0 over the TP axis and each device owns its blocks.

    A stacked tensor (from :meth:`Codec.compress_stacked`) carries one extra
    leading ``(L,)`` dimension on every stream while the static metadata
    still describes a single layer — ``lax.scan`` slices the leading dim
    away and each slice is a valid per-layer ``CompressedTensor``.
    """
    streams: Optional[BlockStreams]
    raw_bytes: Optional[jax.Array]
    # -- static metadata -------------------------------------------------
    fmt_name: str = dataclasses.field(metadata=dict(static=True))
    params: Optional[EnecParams] = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))
    block_elems: int = dataclasses.field(metadata=dict(static=True))
    shards: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(metadata=dict(static=True))

    @property
    def fmt(self) -> FloatFormat:
        return FORMATS[self.fmt_name]

    @property
    def nblocks(self) -> int:
        return self.streams.mask.shape[0] * (self.shards or 1) if self.mode == "enec" else 0

    def nbytes_device(self) -> int:
        """Bytes of the padded device layout."""
        leaves = jax.tree_util.tree_leaves(
            self.streams if self.mode == "enec" else self.raw_bytes)
        return sum(l.size * l.dtype.itemsize for l in leaves)

    def _overhead(self) -> int:
        # exact framed-record overhead (enec-v2 frame + record header) —
        # single source of truth in core/wire.py; lazy import breaks the
        # api <- wire module cycle (wire needs CompressedTensor at load)
        from . import wire
        return wire.record_overhead_bytes(self.mode, len(self.shape))

    def nbytes_wire(self) -> int:
        """Exact compressed size: ``len(wire.frame(wire.to_wire(self)))``.

        Accounts the REAL enec-v2 frame layout (frame header + record
        header + per-block byte-padded high streams), regression-tested
        against the serializer.  The first call on an "enec" tensor
        transfers the (tiny) per-block ``high_len`` vector and caches the
        result; use :func:`precompute_wire_bytes` to batch that transfer
        over a whole tree instead of syncing once per tensor.
        """
        if self.mode == "const":
            return jnp.dtype(self.dtype_str).itemsize + self._overhead()
        if self.mode == "raw":
            return int(np.prod(self.shape)) * jnp.dtype(self.dtype_str).itemsize \
                + self._overhead()
        cached = getattr(self, "_wire_bytes", None)
        if cached is not None:
            return cached
        return self._set_wire_bytes(jax.device_get(self.streams.high_len))

    def _set_wire_bytes(self, high_len_bits) -> int:
        """Fill the wire-size cache from an already-transferred per-block
        ``high_len`` vector (bits per block).  The wire format byte-pads the
        high stream PER BLOCK, so the exact size needs the vector — summing
        the bits first and rounding once undercounts by up to
        ``nblocks - 1`` bytes."""
        s = self.streams
        hl = np.asarray(high_len_bits, np.int64).reshape(-1)
        fixed = (s.mask.size + s.low.size + s.raw.size)
        true_high = int(((hl + 7) // 8).sum())
        nblocks = int(np.prod(s.mask.shape[:-1]))  # per-block high length: 4B each
        self._wire_bytes = fixed + true_high + 4 * nblocks + self._overhead()
        return self._wire_bytes

    def nbytes_raw(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype_str).itemsize

    def ratio(self) -> float:
        return self.nbytes_raw() / max(self.nbytes_wire(), 1)


# the formats the codec understands — single source of truth for every
# consumer's eligibility check (streaming policy, checkpointing)
SUPPORTED_FLOAT_DTYPES = tuple(jnp.dtype(d) for d in (jnp.bfloat16,
                                                      jnp.float16,
                                                      jnp.float32))


def _is_supported_float(x) -> bool:
    return jnp.asarray(x).dtype in SUPPORTED_FLOAT_DTYPES


def _raw_tensor(x, shards: int) -> CompressedTensor:
    flat = jnp.ravel(x)
    buf = flat.view(jnp.uint8) if flat.dtype != jnp.uint8 else flat
    return CompressedTensor(
        streams=None, raw_bytes=buf, fmt_name="bf16", params=None,
        shape=tuple(x.shape), dtype_str=str(jnp.asarray(x).dtype),
        block_elems=0, shards=shards, mode="raw")


# ---------------------------------------------------------------------------
# tile layout for the fused decompress+matmul kernel (stateless)
# ---------------------------------------------------------------------------

MATMUL_TILE = 128
# One 128x128 MXU weight tile holds 16,384 elements == exactly one ENEC
# block, so the paper's preferred block size doubles as the matmul tile.
assert MATMUL_TILE * MATMUL_TILE == DEFAULT_BLOCK_ELEMS


def matmul_tiles(w):
    """(L, K, N) or (K, N) weight -> (L, n_tiles * k_tiles * TILE*TILE) bits.

    Tile ``t = n_tile * k_tiles + k_tile`` of layer ``l`` is stored row-major
    at block ``(l, t)`` — the layout ``kernels.decompress_matmul`` consumes.
    Ragged K/N are zero-padded up to the tile size (the kernel zero-pads the
    activations to match and slices the padded output columns away, so any
    2-D matmul weight is tileable; the pad must be zeros, not the modal
    exponent, for the padded contributions to vanish exactly).
    """
    t = MATMUL_TILE
    w = jnp.asarray(w)
    if w.ndim == 2:
        w = w[None]
    n_layers, k, n = w.shape
    kp, np_ = -(-k // t) * t, -(-n // t) * t
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, 0), (0, kp - k), (0, np_ - n)))
    tiles = w.reshape(n_layers, kp // t, t, np_ // t, t)
    return tiles.transpose(0, 3, 1, 2, 4).reshape(n_layers, -1)


# ---------------------------------------------------------------------------
# wire-size utilities (stateless — no codec needed)
# ---------------------------------------------------------------------------

def precompute_wire_bytes(cts: Sequence[CompressedTensor]) -> None:
    """Fill the ``nbytes_wire`` cache for many tensors with ONE transfer.

    Without this every ``nbytes_wire()`` call forces its own blocking
    ``device_get`` of that tensor's ``high_len`` vector.
    """
    pending = [c for c in cts if c.mode == "enec"
               and getattr(c, "_wire_bytes", None) is None]
    if not pending:
        return
    high_lens = jax.device_get([c.streams.high_len for c in pending])
    for c, hl in zip(pending, high_lens):
        c._set_wire_bytes(hl)


def tree_ratio(ctree) -> dict:
    """Aggregate compression accounting over a compressed pytree (at most
    one host transfer for the whole tree)."""
    cts = [c for c in jax.tree.leaves(
        ctree, is_leaf=lambda x: isinstance(x, CompressedTensor))
        if isinstance(c, CompressedTensor)]
    precompute_wire_bytes(cts)
    raw = sum(c.nbytes_raw() for c in cts)
    wire = sum(c.nbytes_wire() for c in cts)
    return {
        "tensors": len(cts),
        "raw_bytes": raw,
        "compressed_bytes": wire,
        "ratio": raw / max(wire, 1),
    }


# ---------------------------------------------------------------------------
# abstract (ShapeDtypeStruct) compressed weights — used by the dry-run
# ---------------------------------------------------------------------------

def abstract_compressed(shape, dtype, p: EnecParams,
                        block_elems: int = DEFAULT_BLOCK_ELEMS,
                        shards: int = 1) -> CompressedTensor:
    """Build a CompressedTensor of ShapeDtypeStructs (no allocation) matching
    what :meth:`Codec.compress_array` would produce — lets ``jit(...).lower``
    see the exact compressed layout for the production dry-run."""
    fmt = format_for(dtype)
    size = 1
    for s in shape:
        size *= s
    nblocks = (size + block_elems - 1) // block_elems
    nblocks += (-nblocks) % shards
    widths = codec.stream_shapes(block_elems, fmt, p)
    lead = (shards, nblocks // shards) if shards > 1 else (nblocks,)
    sds = jax.ShapeDtypeStruct
    streams = BlockStreams(
        mask=sds(lead + (widths["mask"],), jnp.uint8),
        low=sds(lead + (widths["low"],), jnp.uint8),
        high=sds(lead + (widths["high"],), jnp.uint8),
        high_len=sds(lead, jnp.int32),
        raw=sds(lead + (widths["raw"],), jnp.uint8),
    )
    return CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=tuple(shape), dtype_str=str(jnp.dtype(dtype)),
        block_elems=block_elems, shards=shards, mode="enec")


# ---------------------------------------------------------------------------
# DEPRECATED module-level facade over the ambient codec
# ---------------------------------------------------------------------------
# Every function below delegates to repro.core.current_codec() and emits
# exactly one DeprecationWarning per call.  They exist so pre-Codec callers
# keep working bit-identically; new code uses Codec methods (docs/API.md).

#: the legacy wrapper surface — the deprecation tests iterate this
DEPRECATED_WRAPPERS = (
    "compress_array", "decompress_array",
    "compress_stacked", "compress_stacked_many",
    "decompress_stacked", "decompress_stacked_many",
    "compress_tree", "decompress_tree",
    "tile_weights_for_fusion", "tile_weights_for_fusion_many",
    "untile_matmul_weight",
    "set_encode_backend", "set_decode_backend",
    "encode_cache_stats", "decode_cache_stats",
    "reset_encode_cache_stats", "reset_decode_cache_stats",
)


def _codec():
    from .codec_api import current_codec  # lazy: api loads before codec_api
    return current_codec()


def _deprecated(name: str) -> None:
    warnings.warn(
        f"repro.core.{name} is deprecated; use the {name} method of a "
        f"repro.core.Codec instance (migration table: docs/API.md)",
        DeprecationWarning, stacklevel=3)


def compress_array(x, p: Optional[EnecParams] = None,
                   block_elems: Optional[int] = None,
                   shards: int = 1) -> CompressedTensor:
    """DEPRECATED: :meth:`Codec.compress_array` on the ambient codec."""
    _deprecated("compress_array")
    return _codec().compress_array(x, p, block_elems, shards)


def decompress_array(ct: CompressedTensor):
    """DEPRECATED: :meth:`Codec.decompress_array` on the ambient codec."""
    _deprecated("decompress_array")
    return _codec().decompress_array(ct)


def compress_stacked(x, p: Optional[EnecParams] = None,
                     block_elems: Optional[int] = None,
                     shards: int = 1) -> Optional[CompressedTensor]:
    """DEPRECATED: :meth:`Codec.compress_stacked` on the ambient codec."""
    _deprecated("compress_stacked")
    return _codec().compress_stacked(x, p, block_elems, shards)


def compress_stacked_many(stacks: Sequence[Any],
                          p: Optional[EnecParams] = None,
                          block_elems: Optional[int] = None,
                          shards: int = 1) -> List[Optional[CompressedTensor]]:
    """DEPRECATED: :meth:`Codec.compress_stacked_many` on the ambient codec."""
    _deprecated("compress_stacked_many")
    return _codec().compress_stacked_many(stacks, p, block_elems, shards)


def decompress_stacked(ct: CompressedTensor):
    """DEPRECATED: :meth:`Codec.decompress_stacked` on the ambient codec."""
    _deprecated("decompress_stacked")
    return _codec().decompress_stacked(ct)


def decompress_stacked_many(cts: Sequence[Optional[CompressedTensor]]
                            ) -> List[Optional[Any]]:
    """DEPRECATED: :meth:`Codec.decompress_stacked_many` on the ambient
    codec."""
    _deprecated("decompress_stacked_many")
    return _codec().decompress_stacked_many(cts)


def compress_tree(tree, shared_params: Optional[EnecParams] = None,
                  block_elems: Optional[int] = None, shards: int = 1):
    """DEPRECATED: :meth:`Codec.compress_tree` on the ambient codec."""
    _deprecated("compress_tree")
    return _codec().compress_tree(tree, shared_params, block_elems, shards)


def decompress_tree(ctree):
    """DEPRECATED: :meth:`Codec.decompress_tree` on the ambient codec."""
    _deprecated("decompress_tree")
    return _codec().decompress_tree(ctree)


def tile_weights_for_fusion(w, p: Optional[EnecParams] = None
                            ) -> CompressedTensor:
    """DEPRECATED: :meth:`Codec.tile_weights_for_fusion` on the ambient
    codec."""
    _deprecated("tile_weights_for_fusion")
    return _codec().tile_weights_for_fusion(w, p)


def tile_weights_for_fusion_many(ws: Sequence[Any],
                                 p: Optional[EnecParams] = None
                                 ) -> List[Optional[CompressedTensor]]:
    """DEPRECATED: :meth:`Codec.tile_weights_for_fusion_many` on the
    ambient codec."""
    _deprecated("tile_weights_for_fusion_many")
    return _codec().tile_weights_for_fusion_many(ws, p)


def untile_matmul_weight(ct: CompressedTensor, k: int, n: int):
    """DEPRECATED: :meth:`Codec.untile_matmul_weight` on the ambient codec."""
    _deprecated("untile_matmul_weight")
    return _codec().untile_matmul_weight(ct, k, n)


def set_encode_backend(name: str) -> None:
    """DEPRECATED: construct ``Codec(encode_backend=...)`` instead.  This
    wrapper mutates the AMBIENT codec's config (and clears its encoder
    cache) — the old process-global is gone, so the change is scoped to
    that instance and the autouse test fixture can restore it."""
    _deprecated("set_encode_backend")
    _codec().set_encode_backend(name)


def set_decode_backend(name: str) -> None:
    """DEPRECATED: construct ``Codec(decode_backend=...)`` instead (see
    :func:`set_encode_backend`)."""
    _deprecated("set_decode_backend")
    _codec().set_decode_backend(name)


def encode_cache_stats() -> dict:
    """DEPRECATED: :meth:`Codec.encode_cache_stats` on the ambient codec."""
    _deprecated("encode_cache_stats")
    return _codec().encode_cache_stats()


def decode_cache_stats() -> dict:
    """DEPRECATED: :meth:`Codec.decode_cache_stats` on the ambient codec."""
    _deprecated("decode_cache_stats")
    return _codec().decode_cache_stats()


def reset_encode_cache_stats(clear_cache: bool = False) -> None:
    """DEPRECATED: :meth:`Codec.reset_encode_cache_stats` on the ambient
    codec."""
    _deprecated("reset_encode_cache_stats")
    _codec().reset_encode_cache_stats(clear_cache)


def reset_decode_cache_stats(clear_cache: bool = False) -> None:
    """DEPRECATED: :meth:`Codec.reset_decode_cache_stats` on the ambient
    codec."""
    _deprecated("reset_decode_cache_stats")
    _codec().reset_decode_cache_stats(clear_cache)


def slice_stacked(ct: CompressedTensor, index: int) -> CompressedTensor:
    """Layer ``index`` of a stacked tensor as a standalone CompressedTensor
    (stateless; also exported as ``repro.core.slice_stacked``)."""
    streams = jax.tree.map(lambda a: a[index], ct.streams)
    return dataclasses.replace(ct, streams=streams)


def _encoder_key(fmt_name: str, p: EnecParams, block_elems: int) -> tuple:
    """Ambient codec's encoder-bucket key (kept for the dispatch-count
    tests; prefer ``Codec.plan_encode`` for bucket inspection)."""
    return _codec()._encoder_key(fmt_name, p, block_elems)


def _decoder_key(fmt_name: str, p: EnecParams, block_elems: int) -> tuple:
    """Ambient codec's decoder-bucket key (see :func:`_encoder_key`)."""
    return _codec()._decoder_key(fmt_name, p, block_elems)


# Legacy jit'd entry points: one fused program around the whole inverse
# (decode + reshape + astype), bound to the ambient codec at trace time.
def _decompress_array_ambient(ct: CompressedTensor):
    return _codec().decompress_array(ct)


def _decompress_stacked_ambient(ct: CompressedTensor):
    return _codec().decompress_stacked(ct)


decompress_on_device = jax.jit(_decompress_array_ambient)
decompress_stacked_on_device = jax.jit(_decompress_stacked_ambient)
