"""Public ENEC API: compress/decompress arrays and pytrees.

``CompressedTensor`` is a registered pytree, so compressed weights flow
through ``jax.jit`` / ``pjit`` / shardings like any other parameters — this
is what makes weight-streaming serving and compressed checkpointing
first-class citizens of the framework rather than host-side tools.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import codec, params as params_mod
from .codec import BlockStreams
from .dtypes import FORMATS, FloatFormat, format_for
from .params import DEFAULT_BLOCK_ELEMS, EnecParams

HEADER_BYTES = 48  # nominal per-tensor wire header for ratio accounting


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressedTensor:
    """ENEC-compressed view of one tensor.

    mode == "enec": ``streams`` carries the block streams.
    mode == "raw":  ``raw_bytes`` carries the original buffer (escape for
    incompressible / non-float tensors — ratio floor of ~1.0).
    Leading ``shards`` dimension on every stream makes per-device placement
    trivial: shard axis 0 over the TP axis and each device owns its blocks.
    """
    streams: Optional[BlockStreams]
    raw_bytes: Optional[jax.Array]
    # -- static metadata -------------------------------------------------
    fmt_name: str = dataclasses.field(metadata=dict(static=True))
    params: Optional[EnecParams] = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))
    block_elems: int = dataclasses.field(metadata=dict(static=True))
    shards: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(metadata=dict(static=True))

    @property
    def fmt(self) -> FloatFormat:
        return FORMATS[self.fmt_name]

    @property
    def nblocks(self) -> int:
        return self.streams.mask.shape[0] * (self.shards or 1) if self.mode == "enec" else 0

    def nbytes_device(self) -> int:
        """Bytes of the padded device layout."""
        leaves = jax.tree_util.tree_leaves(
            self.streams if self.mode == "enec" else self.raw_bytes)
        return sum(l.size * l.dtype.itemsize for l in leaves)

    def nbytes_wire(self) -> int:
        """Exact compressed size (paper's file-based accounting)."""
        if self.mode == "const":
            return jnp.dtype(self.dtype_str).itemsize + HEADER_BYTES
        if self.mode == "raw":
            return int(np.prod(self.shape)) * jnp.dtype(self.dtype_str).itemsize + HEADER_BYTES
        s = self.streams
        fixed = (s.mask.size + s.low.size + s.raw.size)
        true_high = int(np.ceil(np.asarray(jax.device_get(s.high_len), np.int64).sum() / 8))
        nblocks = int(np.prod(s.mask.shape[:-1]))  # per-block high length: 4B each
        return fixed + true_high + 4 * nblocks + HEADER_BYTES

    def nbytes_raw(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype_str).itemsize

    def ratio(self) -> float:
        return self.nbytes_raw() / max(self.nbytes_wire(), 1)


def _is_supported_float(x) -> bool:
    return jnp.asarray(x).dtype in (jnp.bfloat16, jnp.float16, jnp.float32)


import functools


@functools.lru_cache(maxsize=512)
def _jit_encode(fmt_name: str, p: EnecParams):
    fmt = FORMATS[fmt_name]
    return jax.jit(lambda bits: codec.encode_blocks(bits, fmt, p))


def compress_array(x, p: Optional[EnecParams] = None,
                   block_elems: int = DEFAULT_BLOCK_ELEMS,
                   shards: int = 1) -> CompressedTensor:
    """Compress one array. ``p=None`` searches parameters on the host."""
    x = jnp.asarray(x)
    if not _is_supported_float(x):
        return _raw_tensor(x, shards)
    fmt = format_for(x.dtype)
    host = np.asarray(jax.device_get(x))
    # constant-tensor escape (RZE-style, LC framework §II-C): fresh optimizer
    # moments / padding tensors are all one value — store it once.
    flat_host = np.ascontiguousarray(host).view(fmt.np_uint_dtype).reshape(-1)
    if flat_host.size and (flat_host == flat_host[0]).all():
        return CompressedTensor(
            streams=None,
            raw_bytes=jnp.asarray(flat_host[:1]).view(jnp.uint8),
            fmt_name=fmt.name, params=None, shape=tuple(x.shape),
            dtype_str=str(x.dtype), block_elems=block_elems, shards=shards,
            mode="const")
    if p is None:
        p = params_mod.search_for_array(host, fmt, block_elems=block_elems)
    else:
        # transferred params: widen if this tensor's range escapes (lossless
        # guarantee, DESIGN.md §2.iii)
        bits = np.ascontiguousarray(host).view(fmt.np_uint_dtype)
        exp = (bits >> fmt.mant_bits) & fmt.exp_mask
        if exp.size:
            p = params_mod.widen_for_range(p, int(exp.min()), int(exp.max()))
    bits = codec.to_blocks(x, fmt, block_elems)
    nblocks = bits.shape[0]
    if shards > 1:
        if nblocks % shards:
            extra = (-nblocks) % shards
            bits = jnp.concatenate(
                [bits, jnp.zeros((extra, block_elems), bits.dtype)])
            nblocks += extra
        bits = bits.reshape(shards * (nblocks // shards), block_elems)
    streams = _jit_encode(fmt.name, p)(bits)
    if shards > 1:
        streams = jax.tree.map(
            lambda a: a.reshape((shards, a.shape[0] // shards) + a.shape[1:]),
            streams)
    ct = CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=tuple(x.shape), dtype_str=str(x.dtype), block_elems=block_elems,
        shards=shards, mode="enec")
    if ct.nbytes_wire() >= ct.nbytes_raw():
        return _raw_tensor(x, shards)  # incompressible: raw escape
    return ct


def _raw_tensor(x, shards: int) -> CompressedTensor:
    flat = jnp.ravel(x)
    buf = flat.view(jnp.uint8) if flat.dtype != jnp.uint8 else flat
    return CompressedTensor(
        streams=None, raw_bytes=buf, fmt_name="bf16", params=None,
        shape=tuple(x.shape), dtype_str=str(jnp.asarray(x).dtype),
        block_elems=0, shards=shards, mode="raw")


def decompress_array(ct: CompressedTensor):
    """Exact inverse of :func:`compress_array` (jit-compatible)."""
    dtype = jnp.dtype(ct.dtype_str)
    if ct.mode == "const":
        value = ct.raw_bytes.view(dtype)[0]
        return jnp.broadcast_to(value, ct.shape)
    if ct.mode == "raw":
        return ct.raw_bytes.view(dtype).reshape(ct.shape)
    streams = ct.streams
    if ct.shards > 1:
        streams = jax.tree.map(
            lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), streams)
    bits = codec.decode_blocks(streams, ct.block_elems, ct.fmt, ct.params)
    return codec.from_blocks(bits, ct.shape, ct.fmt)


# ---------------------------------------------------------------------------
# pytree-level API
# ---------------------------------------------------------------------------

def compress_tree(tree, shared_params: Optional[EnecParams] = None,
                  block_elems: int = DEFAULT_BLOCK_ELEMS, shards: int = 1):
    """Compress every leaf; float leaves get per-tensor searched params
    (or ``shared_params`` for the paper's transferability mode)."""
    return jax.tree.map(
        lambda x: compress_array(x, shared_params, block_elems, shards), tree)


def decompress_tree(ctree):
    return jax.tree.map(
        decompress_array, ctree,
        is_leaf=lambda x: isinstance(x, CompressedTensor))


def tree_ratio(ctree) -> dict:
    """Aggregate compression accounting over a compressed pytree."""
    cts = [c for c in jax.tree.leaves(
        ctree, is_leaf=lambda x: isinstance(x, CompressedTensor))
        if isinstance(c, CompressedTensor)]
    raw = sum(c.nbytes_raw() for c in cts)
    wire = sum(c.nbytes_wire() for c in cts)
    return {
        "tensors": len(cts),
        "raw_bytes": raw,
        "compressed_bytes": wire,
        "ratio": raw / max(wire, 1),
    }


# ---------------------------------------------------------------------------
# abstract (ShapeDtypeStruct) compressed weights — used by the dry-run
# ---------------------------------------------------------------------------

def abstract_compressed(shape, dtype, p: EnecParams,
                        block_elems: int = DEFAULT_BLOCK_ELEMS,
                        shards: int = 1) -> CompressedTensor:
    """Build a CompressedTensor of ShapeDtypeStructs (no allocation) matching
    what :func:`compress_array` would produce — lets ``jit(...).lower`` see
    the exact compressed layout for the production dry-run."""
    fmt = format_for(dtype)
    size = 1
    for s in shape:
        size *= s
    nblocks = (size + block_elems - 1) // block_elems
    nblocks += (-nblocks) % shards
    widths = codec.stream_shapes(block_elems, fmt, p)
    lead = (shards, nblocks // shards) if shards > 1 else (nblocks,)
    sds = jax.ShapeDtypeStruct
    streams = BlockStreams(
        mask=sds(lead + (widths["mask"],), jnp.uint8),
        low=sds(lead + (widths["low"],), jnp.uint8),
        high=sds(lead + (widths["high"],), jnp.uint8),
        high_len=sds(lead, jnp.int32),
        raw=sds(lead + (widths["raw"],), jnp.uint8),
    )
    return CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=tuple(shape), dtype_str=str(jnp.dtype(dtype)),
        block_elems=block_elems, shards=shards, mode="enec")
