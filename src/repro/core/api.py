"""Public ENEC API: compress/decompress arrays, layer stacks, and pytrees.

``CompressedTensor`` is a registered pytree, so compressed weights flow
through ``jax.jit`` / ``pjit`` / shardings like any other parameters — this
is what makes weight-streaming serving and compressed checkpointing
first-class citizens of the framework rather than host-side tools.

The encode pipeline is device-resident (docs/PIPELINE.md): per-tensor
statistics are a single jit'd reduction whose 256-bin histogram is the only
thing that crosses to the host, the host-side O(256^2) parameter search runs
on that histogram, and the encode itself is one jit dispatch per
(format, params, block-count bucket) — a whole ``(L, ...)`` layer stack is
encoded as one ``(L*B, N)`` block array via :func:`compress_stacked`.
``compress_array`` never calls ``jax.device_get`` on the full tensor.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import codec, params as params_mod, stats as stats_mod
from .codec import BlockStreams
from .dtypes import FORMATS, FloatFormat, format_for
from .params import DEFAULT_BLOCK_ELEMS, EnecParams

HEADER_BYTES = 48  # nominal per-tensor wire header for ratio accounting


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressedTensor:
    """ENEC-compressed view of one tensor.

    mode == "enec": ``streams`` carries the block streams.
    mode == "raw":  ``raw_bytes`` carries the original buffer (escape for
    incompressible / non-float tensors — ratio floor of ~1.0).
    Leading ``shards`` dimension on every stream makes per-device placement
    trivial: shard axis 0 over the TP axis and each device owns its blocks.

    A stacked tensor (from :func:`compress_stacked`) carries one extra
    leading ``(L,)`` dimension on every stream while the static metadata
    still describes a single layer — ``lax.scan`` slices the leading dim
    away and each slice is a valid per-layer ``CompressedTensor``.
    """
    streams: Optional[BlockStreams]
    raw_bytes: Optional[jax.Array]
    # -- static metadata -------------------------------------------------
    fmt_name: str = dataclasses.field(metadata=dict(static=True))
    params: Optional[EnecParams] = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype_str: str = dataclasses.field(metadata=dict(static=True))
    block_elems: int = dataclasses.field(metadata=dict(static=True))
    shards: int = dataclasses.field(metadata=dict(static=True))
    mode: str = dataclasses.field(metadata=dict(static=True))

    @property
    def fmt(self) -> FloatFormat:
        return FORMATS[self.fmt_name]

    @property
    def nblocks(self) -> int:
        return self.streams.mask.shape[0] * (self.shards or 1) if self.mode == "enec" else 0

    def nbytes_device(self) -> int:
        """Bytes of the padded device layout."""
        leaves = jax.tree_util.tree_leaves(
            self.streams if self.mode == "enec" else self.raw_bytes)
        return sum(l.size * l.dtype.itemsize for l in leaves)

    def nbytes_wire(self) -> int:
        """Exact compressed size (paper's file-based accounting).

        The first call on an "enec" tensor transfers the (tiny) per-block
        ``high_len`` vector and caches the result; use
        :func:`precompute_wire_bytes` to batch that transfer over a whole
        tree instead of syncing once per tensor.
        """
        if self.mode == "const":
            return jnp.dtype(self.dtype_str).itemsize + HEADER_BYTES
        if self.mode == "raw":
            return int(np.prod(self.shape)) * jnp.dtype(self.dtype_str).itemsize + HEADER_BYTES
        cached = getattr(self, "_wire_bytes", None)
        if cached is not None:
            return cached
        high_bits = int(np.asarray(
            jax.device_get(self.streams.high_len), np.int64).sum())
        return self._set_wire_bytes(high_bits)

    def _set_wire_bytes(self, total_high_bits: int) -> int:
        """Fill the wire-size cache from an already-transferred high_len sum."""
        s = self.streams
        fixed = (s.mask.size + s.low.size + s.raw.size)
        nblocks = int(np.prod(s.mask.shape[:-1]))  # per-block high length: 4B each
        true_high = int(np.ceil(total_high_bits / 8))
        self._wire_bytes = fixed + true_high + 4 * nblocks + HEADER_BYTES
        return self._wire_bytes

    def nbytes_raw(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype_str).itemsize

    def ratio(self) -> float:
        return self.nbytes_raw() / max(self.nbytes_wire(), 1)


# the formats the codec understands — single source of truth for every
# consumer's eligibility check (streaming policy, checkpointing)
SUPPORTED_FLOAT_DTYPES = tuple(jnp.dtype(d) for d in (jnp.bfloat16,
                                                      jnp.float16,
                                                      jnp.float32))


def _is_supported_float(x) -> bool:
    return jnp.asarray(x).dtype in SUPPORTED_FLOAT_DTYPES


# ---------------------------------------------------------------------------
# encoder compile cache (fmt, params, block_elems, block-count bucket)
# ---------------------------------------------------------------------------

_ENCODE_BACKENDS = ("reference", "pallas")
_encode_backend = "reference"
_encode_cache: dict = {}
_encode_stats = {"compiles": 0, "cache_hits": 0, "dispatches": 0,
                 "padded_blocks": 0}


def set_encode_backend(name: str) -> None:
    """Select the encoder the pipeline dispatches: the pure-jnp reference
    codec (default, any backend) or the Pallas kernel (TPU hot path,
    ``interpret=True`` elsewhere)."""
    global _encode_backend
    if name not in _ENCODE_BACKENDS:
        raise ValueError(f"unknown encode backend {name!r}; "
                         f"expected one of {_ENCODE_BACKENDS}")
    if name != _encode_backend:
        _encode_backend = name
        _encode_cache.clear()


def encode_cache_stats() -> dict:
    """Counters for the jit'd-encoder cache (benchmarks + dispatch tests).

    ``compiles`` counts distinct (backend, fmt, params, block_elems, bucket)
    encoder instantiations (each traces/compiles once), ``dispatches`` counts
    encode calls, ``padded_blocks`` the zero blocks added by power-of-two
    bucketing.
    """
    return dict(_encode_stats, cached_encoders=len(_encode_cache),
                backend=_encode_backend)


def reset_encode_cache_stats(clear_cache: bool = False) -> None:
    for k in _encode_stats:
        _encode_stats[k] = 0
    if clear_cache:
        _encode_cache.clear()


_BUCKET_POW2_MAX = 64


def _block_bucket(nblocks: int) -> int:
    """Round the block count up so a 48-layer model hits a handful of
    compiled encoders instead of one per distinct tensor shape: powers of
    two up to 64 blocks, multiples of 64 above (pure pow2 would pad up to 2x
    the encode work for large stacks; 64-multiples keep the pad waste small
    while still bounding the number of distinct compiles)."""
    if nblocks <= 1:
        return 1
    if nblocks <= _BUCKET_POW2_MAX:
        return 1 << (nblocks - 1).bit_length()
    return -(-nblocks // _BUCKET_POW2_MAX) * _BUCKET_POW2_MAX


def _encoder_key(fmt_name: str, p: EnecParams, block_elems: int) -> tuple:
    """Compile-cache key sans block count.  The reference encoder keeps the
    linear-map parameter ``b`` as a traced per-block operand (it never enters
    a shape), so one compiled program serves every ``b`` — the key carries
    only (n, m, L).  The Pallas kernel bakes the whole param tuple in."""
    if _encode_backend == "pallas":
        return (_encode_backend, fmt_name, p.astuple(), block_elems)
    return (_encode_backend, fmt_name, (p.n, p.m, p.L), block_elems)


def _encoder_for(fmt_name: str, p: EnecParams, block_elems: int, bucket: int):
    key = _encoder_key(fmt_name, p, block_elems) + (bucket,)
    fn = _encode_cache.get(key)
    if fn is None:
        if len(_encode_cache) >= 512:   # safety valve; never hit in practice
            _encode_cache.clear()
        _encode_stats["compiles"] += 1
        fmt = FORMATS[fmt_name]
        # encode reads (n, m, L) for shapes and b for arithmetic only;
        # normalizing the bookkeeping fields lets params that differ in
        # (l, expected_bits) — and, on the reference backend, b — share
        # one compile
        p_norm = EnecParams(b=p.b, n=p.n, m=p.m, L=p.L, l=0)
        if _encode_backend == "pallas":
            from repro.kernels import ops as kernel_ops  # lazy: avoids cycle
            fn = kernel_ops.pipeline_encoder(fmt, p_norm)
        else:
            fn = jax.jit(functools.partial(codec.encode_blocks,
                                           fmt=fmt, p=p_norm))
        _encode_cache[key] = fn
    else:
        _encode_stats["cache_hits"] += 1
    return fn


def _encode_bucketed(bits, fmt: FloatFormat, p: EnecParams, block_elems: int,
                     b_vec=None) -> BlockStreams:
    """One encode dispatch for a (B, N) block array, compile-cached on the
    bucketed block count (pad with zero blocks, slice the result).

    ``b_vec`` optionally carries a per-block linear-map parameter so blocks
    from stacks with different searched ``b`` share the dispatch.
    """
    nblocks = bits.shape[0]
    bucket = _block_bucket(nblocks)
    if _encode_backend != "pallas" and b_vec is None:
        b_vec = jnp.full((nblocks,), p.b, jnp.int32)
    if bucket != nblocks:
        _encode_stats["padded_blocks"] += bucket - nblocks
        bits = jnp.concatenate(
            [bits, jnp.zeros((bucket - nblocks, bits.shape[1]), bits.dtype)])
        if b_vec is not None:
            b_vec = jnp.concatenate(
                [b_vec, jnp.full((bucket - nblocks,), p.b, jnp.int32)])
    fn = _encoder_for(fmt.name, p, block_elems, bucket)
    _encode_stats["dispatches"] += 1
    streams = fn(bits) if b_vec is None else fn(bits, b_vec=b_vec)
    if bucket != nblocks:
        streams = jax.tree.map(lambda a: a[:nblocks], streams)
    return streams


# ---------------------------------------------------------------------------
# decoder compile cache — the decode-side mirror of the encoder cache
# ---------------------------------------------------------------------------

_decode_backend = "reference"
_decode_cache: dict = {}
_decode_stats = {"compiles": 0, "cache_hits": 0, "dispatches": 0,
                 "padded_blocks": 0}


def set_decode_backend(name: str) -> None:
    """Select the decoder the pipeline dispatches: the pure-jnp reference
    codec (default, any backend) or the Pallas kernel (TPU hot path,
    ``interpret=True`` elsewhere).  Mirror of :func:`set_encode_backend`."""
    global _decode_backend
    if name not in _ENCODE_BACKENDS:
        raise ValueError(f"unknown decode backend {name!r}; "
                         f"expected one of {_ENCODE_BACKENDS}")
    if name != _decode_backend:
        _decode_backend = name
        _decode_cache.clear()


def decode_cache_stats() -> dict:
    """Counters for the jit'd-decoder cache (benchmarks + dispatch tests).

    ``compiles`` counts distinct (backend, fmt, params, block_elems, bucket)
    decoder instantiations, ``dispatches`` counts decode calls,
    ``padded_blocks`` the zero blocks added by block-count bucketing.
    Mirror of :func:`encode_cache_stats`.
    """
    return dict(_decode_stats, cached_decoders=len(_decode_cache),
                backend=_decode_backend)


def reset_decode_cache_stats(clear_cache: bool = False) -> None:
    for k in _decode_stats:
        _decode_stats[k] = 0
    if clear_cache:
        _decode_cache.clear()


def _decoder_key(fmt_name: str, p: EnecParams, block_elems: int) -> tuple:
    """Compile-cache key sans block count.  The reference decoder keeps the
    inverse-transform params ``(b, l)`` as traced per-block operands (they
    never enter a shape), so one compiled program serves every searched
    param set — the key carries only (n, m, L).  The Pallas kernel bakes
    the whole tuple in."""
    if _decode_backend == "pallas":
        return (_decode_backend, fmt_name, p.astuple() + (p.l,), block_elems)
    return (_decode_backend, fmt_name, (p.n, p.m, p.L), block_elems)


def _decoder_for(fmt_name: str, p: EnecParams, block_elems: int, bucket: int):
    key = _decoder_key(fmt_name, p, block_elems) + (bucket,)
    fn = _decode_cache.get(key)
    if fn is None:
        if len(_decode_cache) >= 512:   # safety valve; never hit in practice
            _decode_cache.clear()
        _decode_stats["compiles"] += 1
        fmt = FORMATS[fmt_name]
        # decode reads (n, m, L) for shapes; (b, l) enter arithmetic only
        # and the reference backend always overrides them with per-block
        # vectors, so params differing in (b, l, expected_bits) share one
        # compile there
        p_norm = EnecParams(b=p.b, n=p.n, m=p.m, L=p.L, l=p.l)
        if _decode_backend == "pallas":
            from repro.kernels import ops as kernel_ops  # lazy: avoids cycle
            fn = kernel_ops.pipeline_decoder(fmt, p_norm, block_elems)
        else:
            fn = jax.jit(functools.partial(codec.decode_blocks,
                                           n_elems=block_elems, fmt=fmt,
                                           p=p_norm))
        _decode_cache[key] = fn
    else:
        _decode_stats["cache_hits"] += 1
    return fn


def _decode_bucketed(streams: BlockStreams, fmt: FloatFormat, p: EnecParams,
                     block_elems: int, b_vec=None, l_vec=None):
    """One decode dispatch for flat (B, ...) block streams, compile-cached
    on the bucketed block count (pad with zero blocks, slice the result).

    ``b_vec`` / ``l_vec`` optionally carry per-block inverse-transform
    params so blocks from tensors with different searched ``(b, l)`` share
    the dispatch.
    """
    nblocks = streams.mask.shape[0]
    bucket = _block_bucket(nblocks)
    if _decode_backend != "pallas":
        if b_vec is None:
            b_vec = jnp.full((nblocks,), p.b, jnp.int32)
        if l_vec is None:
            l_vec = jnp.full((nblocks,), p.l, jnp.int32)
    if bucket != nblocks:
        _decode_stats["padded_blocks"] += bucket - nblocks
        pad = bucket - nblocks
        streams = jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]), streams)
        if b_vec is not None:
            b_vec = jnp.concatenate([b_vec, jnp.full((pad,), p.b, jnp.int32)])
            l_vec = jnp.concatenate([l_vec, jnp.full((pad,), p.l, jnp.int32)])
    fn = _decoder_for(fmt.name, p, block_elems, bucket)
    _decode_stats["dispatches"] += 1
    bits = (fn(streams) if b_vec is None
            else fn(streams, b_vec=b_vec, l_vec=l_vec))
    return bits[:nblocks] if bucket != nblocks else bits


_flat_streams = codec.flatten_blocks


def _stack_dim(ct: "CompressedTensor") -> Optional[int]:
    """Leading layer count of a stacked tensor, or ``None`` for a per-leaf
    tensor (whose metadata already describes the whole array)."""
    base = 3 if ct.shards > 1 else 2
    return ct.streams.mask.shape[0] if ct.streams.mask.ndim == base + 1 \
        else None


# ---------------------------------------------------------------------------
# single-array API
# ---------------------------------------------------------------------------

def compress_array(x, p: Optional[EnecParams] = None,
                   block_elems: int = DEFAULT_BLOCK_ELEMS,
                   shards: int = 1) -> CompressedTensor:
    """Compress one array. ``p=None`` searches parameters on the host.

    Device-resident: statistics (exponent histogram + const check) are one
    jit'd reduction, only the histogram crosses to the host, and the full
    tensor is never transferred.
    """
    x = jnp.asarray(x)
    if not _is_supported_float(x) or x.size == 0:
        return _raw_tensor(x, shards)
    fmt = format_for(x.dtype)
    flat_bits = jnp.ravel(x).view(fmt.uint_dtype)
    st = stats_mod.stack_stats(flat_bits[None, :], fmt)
    # constant-tensor escape (RZE-style, LC framework §II-C): fresh optimizer
    # moments / padding tensors are all one value — store it once.
    if bool(st.is_const[0]):
        return CompressedTensor(
            streams=None,
            raw_bytes=jnp.asarray(st.first[:1]).view(jnp.uint8),
            fmt_name=fmt.name, params=None, shape=tuple(x.shape),
            dtype_str=str(x.dtype), block_elems=block_elems, shards=shards,
            mode="const")
    if p is None:
        p = params_mod.search(st.hist, fmt, block_elems=block_elems)
    # widen to the EXACT exponent bounds: a no-op for freshly searched params
    # on an exact histogram, the lossless escape for transferred params, and
    # the correctness guarantee when the histogram was sampled
    p = params_mod.widen_for_range(p, *st.bounds())
    bits, _ = codec.bits_to_blocks(flat_bits, block_elems, shards,
                                   pad_value=p.b << fmt.mant_bits)
    streams = _encode_bucketed(bits, fmt, p, block_elems)
    if shards > 1:
        streams = jax.tree.map(
            lambda a: a.reshape((shards, a.shape[0] // shards) + a.shape[1:]),
            streams)
    ct = CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=tuple(x.shape), dtype_str=str(x.dtype), block_elems=block_elems,
        shards=shards, mode="enec")
    if ct.nbytes_wire() >= ct.nbytes_raw():
        return _raw_tensor(x, shards)  # incompressible: raw escape
    return ct


def _raw_tensor(x, shards: int) -> CompressedTensor:
    flat = jnp.ravel(x)
    buf = flat.view(jnp.uint8) if flat.dtype != jnp.uint8 else flat
    return CompressedTensor(
        streams=None, raw_bytes=buf, fmt_name="bf16", params=None,
        shape=tuple(x.shape), dtype_str=str(jnp.asarray(x).dtype),
        block_elems=0, shards=shards, mode="raw")


def decompress_array(ct: CompressedTensor):
    """Exact inverse of :func:`compress_array` (jit-compatible).

    Rides the bucketed, compile-cached decoder of the batched pipeline, so
    even per-leaf calls share compiled decode programs across tensors; use
    :func:`decompress_stacked_many` to share the *dispatch* too.
    """
    dtype = jnp.dtype(ct.dtype_str)
    if ct.mode == "const":
        value = ct.raw_bytes.view(dtype)[0]
        return jnp.broadcast_to(value, ct.shape)
    if ct.mode == "raw":
        return ct.raw_bytes.view(dtype).reshape(ct.shape)
    bits = _decode_bucketed(_flat_streams(ct.streams), ct.fmt, ct.params,
                            ct.block_elems)
    return codec.from_blocks(bits, ct.shape, ct.fmt)


# ---------------------------------------------------------------------------
# stacked (layer-stack) API — one dispatch per stack
# ---------------------------------------------------------------------------

def compress_stacked_many(stacks: Sequence[Any],
                          p: Optional[EnecParams] = None,
                          block_elems: int = DEFAULT_BLOCK_ELEMS,
                          shards: int = 1) -> List[Optional[CompressedTensor]]:
    """Compress many ``(L, ...)`` layer stacks with O(#buckets) dispatches.

    Pipeline (docs/PIPELINE.md): one stats dispatch per stack, ONE host
    transfer for all statistics, host-side parameter search per stack, then
    stacks sharing an encoder bucket (fmt, params, block_elems) are
    concatenated and encoded in a single dispatch.  Wire-size accounting for
    the never-worse escape is one more batched transfer of the per-block
    ``high_len`` vectors.

    Returns one entry per input stack: a ``CompressedTensor`` whose stream
    arrays carry a leading ``(L, ...)`` layout (metadata describes a single
    layer, matching what per-layer :func:`compress_array` + ``jnp.stack``
    used to produce), or ``None`` when the stack must stay dense
    (unsupported dtype, a constant layer, or incompressible data).
    """
    results: List[Optional[CompressedTensor]] = [None] * len(stacks)
    prepared = []   # (slot, fmt, bits2d, layer_shape, device_stats)
    for slot, x in enumerate(stacks):
        x = jnp.asarray(x)
        if x.ndim < 1 or not _is_supported_float(x) or x.size == 0:
            continue
        fmt = format_for(x.dtype)
        bits2d = x.reshape(x.shape[0], -1).view(fmt.uint_dtype)
        prepared.append((slot, fmt, bits2d, x.shape[1:], str(x.dtype),
                         stats_mod.stack_stats_device(bits2d, fmt)))
    host_stats = stats_mod.fetch_stats([pr[-1] for pr in prepared])

    # host search + block layout, grouped by encoder key
    groups: dict = {}   # key -> list of plan dicts
    for (slot, fmt, bits2d, layer_shape, dtype_str, _), st in zip(
            prepared, host_stats):
        if st.is_const.any():
            continue    # parity with the per-layer const escape: stay dense
        pi = (params_mod.search(st.hist, fmt, block_elems=block_elems)
              if p is None else p)
        # one widen to the stack's exact bounds: covers transferred params
        # and sampled histograms, and — unlike the retired per-layer loop —
        # cannot end up with layers encoded under different params than the
        # stack metadata advertises
        pi = params_mod.widen_for_range(pi, *st.bounds())
        blocks, per_layer_blocks = codec.stacked_blocks(
            bits2d, block_elems, shards, pad_value=pi.b << fmt.mant_bits)
        key = _encoder_key(fmt.name, pi, block_elems)
        groups.setdefault(key, []).append(dict(
            slot=slot, fmt=fmt, p=pi, blocks=blocks,
            n_layers=bits2d.shape[0], layer_shape=layer_shape,
            dtype_str=dtype_str, per_layer_blocks=per_layer_blocks))

    for members in groups.values():
        if len(members) == 1:
            all_blocks = members[0]["blocks"]
        else:
            all_blocks = jnp.concatenate([m["blocks"] for m in members])
        b_vec = None
        if _encode_backend != "pallas":
            b_vec = jnp.concatenate(
                [jnp.full((m["blocks"].shape[0],), m["p"].b, jnp.int32)
                 for m in members])
        streams = _encode_bucketed(all_blocks, members[0]["fmt"],
                                   members[0]["p"], block_elems, b_vec=b_vec)
        offset = 0
        for m in members:
            nb = m["blocks"].shape[0]
            s = jax.tree.map(lambda a: a[offset:offset + nb], streams)
            offset += nb
            n_layers, plb = m["n_layers"], m["per_layer_blocks"]
            lead = ((n_layers, shards, plb // shards) if shards > 1
                    else (n_layers, plb))
            s = jax.tree.map(lambda a: a.reshape(lead + a.shape[1:]), s)
            results[m["slot"]] = CompressedTensor(
                streams=s, raw_bytes=None, fmt_name=m["fmt"].name,
                params=m["p"], shape=tuple(m["layer_shape"]),
                dtype_str=m["dtype_str"], block_elems=block_elems,
                shards=shards, mode="enec")

    # never-worse escape, one batched transfer for every stack's high_len
    pending = [(slot, ct) for slot, ct in enumerate(results) if ct is not None]
    if pending:
        high_lens = jax.device_get([ct.streams.high_len for _, ct in pending])
        for (slot, ct), hl in zip(pending, high_lens):
            n_layers = ct.streams.mask.shape[0]
            wire = ct._set_wire_bytes(int(np.asarray(hl, np.int64).sum()))
            if wire >= n_layers * ct.nbytes_raw():
                results[slot] = None
    return results


def compress_stacked(x, p: Optional[EnecParams] = None,
                     block_elems: int = DEFAULT_BLOCK_ELEMS,
                     shards: int = 1) -> Optional[CompressedTensor]:
    """Compress one ``(L, ...)`` layer stack in a single encode dispatch.

    Bit-identical to compressing each layer with :func:`compress_array`
    under the same params and stacking the streams, without the L dispatches
    or the stream-pytree copy.  Returns ``None`` when the stack must stay
    dense (see :func:`compress_stacked_many`).
    """
    return compress_stacked_many([x], p, block_elems, shards)[0]


def _stacked_from_bits(ct: CompressedTensor, n_layers: int, bits):
    """(L*B, N) decoded bits -> the dense ``(L,) + ct.shape`` stack."""
    per = int(np.prod(ct.shape))
    flat_layers = bits.reshape(n_layers, -1)[:, :per]
    return flat_layers.view(ct.fmt.float_dtype).reshape(
        (n_layers,) + ct.shape).astype(jnp.dtype(ct.dtype_str))


def decompress_stacked(ct: CompressedTensor):
    """Inverse of :func:`compress_stacked`: one decode dispatch -> (L, ...)."""
    n_layers = ct.streams.mask.shape[0]
    bits = _decode_bucketed(_flat_streams(ct.streams), ct.fmt, ct.params,
                            ct.block_elems)
    return _stacked_from_bits(ct, n_layers, bits)


def decompress_stacked_many(cts: Sequence[Optional[CompressedTensor]]
                            ) -> List[Optional[Any]]:
    """Decompress many CompressedTensors with O(#buckets) decode dispatches
    — the decode-side mirror of :func:`compress_stacked_many`.

    Tensors sharing a decoder bucket ``(backend, fmt, (n, m, L),
    block_elems, block-count bucket)`` are concatenated and decoded in ONE
    jit dispatch; the inverse-transform params ``(b, l)`` ride as traced
    per-block vectors, so tensors with *different* searched params share
    the dispatch too (the Pallas backend bakes params in and buckets on the
    full tuple instead).  Outputs are bit-identical to the per-leaf path.

    Accepts any mix of per-leaf and stacked tensors plus ``const`` / ``raw``
    / ``None`` entries: each output slot is exactly what
    :func:`decompress_array` (per-leaf) or :func:`decompress_stacked`
    (stacked) would return, or ``None`` for ``None`` inputs.
    """
    results: List[Optional[Any]] = [None] * len(cts)
    groups: dict = {}   # decoder key -> list of plan dicts
    for slot, ct in enumerate(cts):
        if ct is None:
            continue
        if ct.mode != "enec":
            results[slot] = decompress_array(ct)    # const/raw: no dispatch
            continue
        groups.setdefault(
            _decoder_key(ct.fmt_name, ct.params, ct.block_elems), []
        ).append(dict(slot=slot, ct=ct, stack=_stack_dim(ct),
                      flat=_flat_streams(ct.streams)))

    for members in groups.values():
        if len(members) == 1:
            flat = members[0]["flat"]
        else:
            flat = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                *[m["flat"] for m in members])
        p0 = members[0]["ct"].params
        b_vec = l_vec = None
        if _decode_backend != "pallas":
            b_vec = jnp.concatenate(
                [jnp.full((m["flat"].mask.shape[0],), m["ct"].params.b,
                          jnp.int32) for m in members])
            l_vec = jnp.concatenate(
                [jnp.full((m["flat"].mask.shape[0],), m["ct"].params.l,
                          jnp.int32) for m in members])
        bits = _decode_bucketed(flat, members[0]["ct"].fmt, p0,
                                members[0]["ct"].block_elems,
                                b_vec=b_vec, l_vec=l_vec)
        offset = 0
        for m in members:
            nb = m["flat"].mask.shape[0]
            bits_m = bits[offset:offset + nb]
            offset += nb
            ct = m["ct"]
            results[m["slot"]] = (
                codec.from_blocks(bits_m, ct.shape, ct.fmt)
                if m["stack"] is None
                else _stacked_from_bits(ct, m["stack"], bits_m))
    return results


def slice_stacked(ct: CompressedTensor, index: int) -> CompressedTensor:
    """Layer ``index`` of a stacked tensor as a standalone CompressedTensor."""
    return dataclasses.replace(
        ct, streams=jax.tree.map(lambda a: a[index], ct.streams))


# Legacy jit'd entry points.  decompress_array / decompress_stacked now ride
# the bucketed decoder cache directly (the decode runs where the streams
# live, never on the host), and the batched consumers (checkpoint restore,
# whole-tree materialization) group tensors into shared dispatches via
# decompress_stacked_many — these aliases remain for callers that want one
# fused program around the whole inverse (decode + reshape + astype).
decompress_on_device = jax.jit(decompress_array)
decompress_stacked_on_device = jax.jit(decompress_stacked)


# ---------------------------------------------------------------------------
# tile-wise compression for the fused decompress+matmul kernel
# ---------------------------------------------------------------------------

MATMUL_TILE = 128
# One 128x128 MXU weight tile holds 16,384 elements == exactly one ENEC
# block, so the paper's preferred block size doubles as the matmul tile.
assert MATMUL_TILE * MATMUL_TILE == DEFAULT_BLOCK_ELEMS


def matmul_tiles(w):
    """(L, K, N) or (K, N) weight -> (L, n_tiles * k_tiles * TILE*TILE) bits.

    Tile ``t = n_tile * k_tiles + k_tile`` of layer ``l`` is stored row-major
    at block ``(l, t)`` — the layout ``kernels.decompress_matmul`` consumes.
    Ragged K/N are zero-padded up to the tile size (the kernel zero-pads the
    activations to match and slices the padded output columns away, so any
    2-D matmul weight is tileable; the pad must be zeros, not the modal
    exponent, for the padded contributions to vanish exactly).
    """
    t = MATMUL_TILE
    w = jnp.asarray(w)
    if w.ndim == 2:
        w = w[None]
    n_layers, k, n = w.shape
    kp, np_ = -(-k // t) * t, -(-n // t) * t
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, 0), (0, kp - k), (0, np_ - n)))
    tiles = w.reshape(n_layers, kp // t, t, np_ // t, t)
    return tiles.transpose(0, 3, 1, 2, 4).reshape(n_layers, -1)


def untile_matmul_weight(ct: CompressedTensor, k: int, n: int):
    """Inverse of :func:`matmul_tiles` for ONE layer slice of a tile-wise
    tensor: decompress, un-permute the tile order, strip the padding."""
    t = MATMUL_TILE
    kp, np_ = -(-k // t) * t, -(-n // t) * t
    flat = decompress_array(ct)
    tiles = flat.reshape(np_ // t, kp // t, t, t)
    return tiles.transpose(1, 2, 0, 3).reshape(kp, np_)[:k, :n]


def tile_weights_for_fusion_many(ws: Sequence[Any], p: Optional[EnecParams]
                                 = None) -> List[Optional[CompressedTensor]]:
    """Compress many (L, K, N) / (K, N) matmul weights tile-wise for the
    fused kernel, riding :func:`compress_stacked_many`: per-stack searched
    params, one encode dispatch per (fmt, params, block-bucket) group, and
    the never-worse escape intact (``None`` entries must stay dense)."""
    return compress_stacked_many([matmul_tiles(w) for w in ws], p=p,
                                 block_elems=DEFAULT_BLOCK_ELEMS, shards=1)


def tile_weights_for_fusion(w, p: Optional[EnecParams] = None
                            ) -> CompressedTensor:
    """Compress one weight tile-wise for the fused kernel.

    2-D input returns a per-layer tensor (streams lead with the tile dim);
    3-D ``(L, K, N)`` input keeps the extra leading ``(L,)`` so ``lax.scan``
    can slice the streams per layer.  Raises on the incompressible escape —
    callers that need the fallback use :func:`tile_weights_for_fusion_many`.
    """
    squeeze = jnp.asarray(w).ndim == 2
    ct = tile_weights_for_fusion_many([w], p)[0]
    if ct is None:
        raise ValueError("weight is incompressible or constant — serve dense")
    if squeeze:
        ct = dataclasses.replace(
            ct, streams=jax.tree.map(lambda a: a[0], ct.streams))
    return ct


# ---------------------------------------------------------------------------
# pytree-level API
# ---------------------------------------------------------------------------

def compress_tree(tree, shared_params: Optional[EnecParams] = None,
                  block_elems: int = DEFAULT_BLOCK_ELEMS, shards: int = 1):
    """Compress every leaf; float leaves get per-tensor searched params
    (or ``shared_params`` for the paper's transferability mode)."""
    return jax.tree.map(
        lambda x: compress_array(x, shared_params, block_elems, shards), tree)


def decompress_tree(ctree):
    """Inverse of :func:`compress_tree` with O(#decoder buckets) decode
    dispatches (leaves sharing a bucket decode together)."""
    flat, treedef = jax.tree_util.tree_flatten(
        ctree, is_leaf=lambda x: isinstance(x, CompressedTensor))
    slots = [i for i, l in enumerate(flat) if isinstance(l, CompressedTensor)]
    outs = decompress_stacked_many([flat[i] for i in slots])
    for i, out in zip(slots, outs):
        flat[i] = out
    return jax.tree_util.tree_unflatten(treedef, flat)


def precompute_wire_bytes(cts: Sequence[CompressedTensor]) -> None:
    """Fill the ``nbytes_wire`` cache for many tensors with ONE transfer.

    Without this every ``nbytes_wire()`` call forces its own blocking
    ``device_get`` of that tensor's ``high_len`` vector.
    """
    pending = [c for c in cts if c.mode == "enec"
               and getattr(c, "_wire_bytes", None) is None]
    if not pending:
        return
    high_lens = jax.device_get([c.streams.high_len for c in pending])
    for c, hl in zip(pending, high_lens):
        c._set_wire_bytes(int(np.asarray(hl, np.int64).sum()))


def tree_ratio(ctree) -> dict:
    """Aggregate compression accounting over a compressed pytree (at most
    one host transfer for the whole tree)."""
    cts = [c for c in jax.tree.leaves(
        ctree, is_leaf=lambda x: isinstance(x, CompressedTensor))
        if isinstance(c, CompressedTensor)]
    precompute_wire_bytes(cts)
    raw = sum(c.nbytes_raw() for c in cts)
    wire = sum(c.nbytes_wire() for c in cts)
    return {
        "tensors": len(cts),
        "raw_bytes": raw,
        "compressed_bytes": wire,
        "ratio": raw / max(wire, 1),
    }


# ---------------------------------------------------------------------------
# abstract (ShapeDtypeStruct) compressed weights — used by the dry-run
# ---------------------------------------------------------------------------

def abstract_compressed(shape, dtype, p: EnecParams,
                        block_elems: int = DEFAULT_BLOCK_ELEMS,
                        shards: int = 1) -> CompressedTensor:
    """Build a CompressedTensor of ShapeDtypeStructs (no allocation) matching
    what :func:`compress_array` would produce — lets ``jit(...).lower`` see
    the exact compressed layout for the production dry-run."""
    fmt = format_for(dtype)
    size = 1
    for s in shape:
        size *= s
    nblocks = (size + block_elems - 1) // block_elems
    nblocks += (-nblocks) % shards
    widths = codec.stream_shapes(block_elems, fmt, p)
    lead = (shards, nblocks // shards) if shards > 1 else (nblocks,)
    sds = jax.ShapeDtypeStruct
    streams = BlockStreams(
        mask=sds(lead + (widths["mask"],), jnp.uint8),
        low=sds(lead + (widths["low"],), jnp.uint8),
        high=sds(lead + (widths["high"],), jnp.uint8),
        high_len=sds(lead, jnp.int32),
        raw=sds(lead + (widths["raw"],), jnp.uint8),
    )
    return CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=tuple(shape), dtype_str=str(jnp.dtype(dtype)),
        block_elems=block_elems, shards=shards, mode="enec")
