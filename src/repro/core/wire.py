"""Exact packed wire/file format for ENEC-compressed tensors (host side).

The device layout pads the per-block high stream to its static bound so XLA
sees fixed shapes; the wire layout stores the *exact* bits (the paper's
file-based accounting).  This module converts between the two.  numpy only —
it runs on the checkpoint/host path, never inside jit.

Record layout per tensor (little endian, "enec-v1"-compatible inner body):
  magic  u32 = 0xE47C0DEC
  mode   u8 (0=enec, 1=raw, 2=const), fmt u8, stack u16 (0 = plain record;
         else the leading layer-stack length L of every stream)
  ndim u32, shape i64[ndim], dtype tag u8[8]
  block_elems u32, shards u32
  params: b i32, n i32, m i32, L i32, l i32  (enec mode)
  nblocks u32                      (TOTAL flat blocks: stack * shards * B)
  high_len u32[nblocks]            (bits)
  mask | low | raw                 (fixed-size streams, concatenated)
  high                             (exact bit stream, byte padded per block)

enec-v2 frame (the self-delimiting container unit): records are wrapped in

  frame_magic u32 = 0xE47C0DF2
  version u16 = 2, flags u16 (reserved, must be 0)
  payload_len u64
  payload_crc u32                  (CRC32 of the payload bytes)
  payload bytes

so frames can be concatenated into per-shard pack files, located by
(offset, length) from a manifest, and validated (length bounds + CRC) on
read.  The seed's raw/const records read to end-of-buffer and therefore
could not be framed at all; with the explicit ``payload_len`` every record
is parsed from an exact slice and any truncation or bit flip is rejected
with :class:`WireError` instead of being silently misdecoded.

All host->device uploads made while deserializing go through a transfer
counter (:func:`transfer_stats`) — the serving-restore path asserts that
only *compressed* bytes ever cross to the device.
"""
from __future__ import annotations

import struct
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from . import bitio
from . import codec as block_codec
from .api import CompressedTensor
from .codec import BlockStreams
from .dtypes import FORMATS
from .params import EnecParams

MAGIC = 0xE47C0DEC
_FMT_TAGS = {"bf16": 0, "fp16": 1, "fp32": 2}
_FMT_FROM_TAG = {v: k for k, v in _FMT_TAGS.items()}
_MODE_TAGS = {"enec": 0, "raw": 1, "const": 2}
_MODE_FROM_TAG = {v: k for k, v in _MODE_TAGS.items()}


class WireError(ValueError):
    """A wire record or frame failed validation (truncated, corrupt, or not
    an ENEC record at all).

    Carries optional record context — ``record`` (leaf name), ``pack``
    (pack file name), ``offset`` (absolute byte offset of the frame in the
    pack) — so a checkpoint quarantine line is actionable, not just "bad
    frame magic".  Raise sites that know only part of the context fill
    what they have; outer layers add the rest via :meth:`with_context`
    (first writer wins, so the most precise coordinates survive).
    """

    def __init__(self, message, *, record=None, pack=None, offset=None):
        super().__init__(message)
        self.record = record
        self.pack = pack
        self.offset = offset

    def with_context(self, *, record=None, pack=None, offset=None):
        """Fill any UNSET context fields and return self (chainable at
        ``except`` sites)."""
        if self.record is None:
            self.record = record
        if self.pack is None:
            self.pack = pack
        if self.offset is None:
            self.offset = offset
        return self

    def __str__(self):
        base = self.args[0] if self.args else ""
        ctx = []
        if self.record is not None:
            ctx.append(f"record={self.record}")
        if self.pack is not None:
            ctx.append(f"pack={self.pack}")
        if self.offset is not None:
            ctx.append(f"offset={self.offset}")
        return f"{base} [{', '.join(ctx)}]" if ctx else str(base)


# ---------------------------------------------------------------------------
# host<->device transfer accounting (instance-scoped on the codec)
# ---------------------------------------------------------------------------

def _ambient_codec():
    from .codec_api import current_codec  # lazy: wire loads before codec_api
    return current_codec()


def reset_transfer_stats() -> None:
    """Reset the AMBIENT codec's transfer counter (module-level
    convenience; prefer :meth:`Codec.reset_transfer_stats`)."""
    _ambient_codec().reset_transfer_stats()


def transfer_stats() -> dict:
    """Bytes staged host->device by wire deserialization (and the checkpoint
    loader's raw-leaf uploads) through the AMBIENT codec.  The compressed-
    restore acceptance test uses this to prove no dense weight ever crossed
    the host->device link.  Prefer :meth:`Codec.transfer_stats` — each codec
    instance owns its own counter."""
    return _ambient_codec().transfer_stats()


def h2d(arr, codec=None, *, dense=False, place=None):
    """Upload one host array, counting its bytes on ``codec`` (default: the
    ambient codec).  ``dense=True`` attributes the bytes to the ledger's
    dense column (raw checkpoint leaves — payloads that are not fixed-length
    wire streams).  ``place``, when given, is a callable
    ``place(host_array) -> jax.Array`` that performs the upload instead of
    the default whole-array ``jnp.asarray`` — the mesh restore path uses it
    to send each stream shard to its owning device only."""
    arr = np.asarray(arr)
    (codec or _ambient_codec()).count_h2d(arr.nbytes, dense=dense)
    if place is not None:
        return place(arr)
    return jnp.asarray(arr)


# ---------------------------------------------------------------------------
# enec-v2 framing: self-delimiting, CRC-checked record container
# ---------------------------------------------------------------------------

FRAME_MAGIC = 0xE47C0DF2
FRAME_VERSION = 2
_FRAME_HDR = struct.Struct("<IHHQI")   # magic, version, flags, len, crc
FRAME_HEADER_BYTES = _FRAME_HDR.size


def frame(payload: bytes) -> bytes:
    """Wrap one record payload in a self-delimiting, CRC-checked frame."""
    return _FRAME_HDR.pack(FRAME_MAGIC, FRAME_VERSION, 0, len(payload),
                           zlib.crc32(payload)) + payload


def framed_nbytes(payload_len: int) -> int:
    return FRAME_HEADER_BYTES + payload_len


# record header layout, matching to_wire byte for byte: magic/mode/fmt/stack
# ("<IBBH"=8) + ndim ("<I"=4) + shape (8*ndim) + dtype tag ("<8s"=8) +
# block_elems/shards ("<II"=8); enec records add params ("<5i"=20) and the
# nblocks field ("<I"=4)
_RECORD_COMMON_BYTES = 8 + 4 + 8 + 8
_RECORD_PARAMS_BYTES = 20 + 4


def record_overhead_bytes(mode: str, ndim: int) -> int:
    """Exact per-record overhead of a FRAMED wire record: frame header plus
    the record header for ``ndim`` shape dims.  Everything in
    ``frame(to_wire(ct))`` that is not stream/payload bytes — the single
    source of truth for ``CompressedTensor.nbytes_wire`` accounting,
    regression-tested against the serializer in tests/test_codec_api.py."""
    base = FRAME_HEADER_BYTES + _RECORD_COMMON_BYTES + 8 * ndim
    return base + (_RECORD_PARAMS_BYTES if mode == "enec" else 0)


def read_frame(buf, off: int = 0, *, record=None, pack=None,
               base_offset=None):
    """Validate and return ``(payload, next_off)`` for the frame at ``off``.

    Checks magic, version, that the declared payload length fits the buffer,
    and the payload CRC32.  Raises :class:`WireError` on any mismatch — a
    truncated pack file or a flipped bit can never be silently decoded.
    ``record``/``pack``/``base_offset`` are optional caller context: the
    checkpoint loader passes the leaf name, pack file, and the frame's
    absolute pack offset so every raise carries actionable coordinates.
    """
    def _err(msg):
        return WireError(
            msg, record=record, pack=pack,
            offset=None if base_offset is None else base_offset)

    view = memoryview(buf)
    if off + FRAME_HEADER_BYTES > len(view):
        raise _err(
            f"frame header truncated at offset {off}: need "
            f"{FRAME_HEADER_BYTES} bytes, have {len(view) - off}")
    magic, version, flags, length, crc = _FRAME_HDR.unpack_from(view, off)
    if magic != FRAME_MAGIC:
        raise _err(f"bad frame magic {magic:#x} at offset {off} "
                   f"(expected {FRAME_MAGIC:#x})")
    if version != FRAME_VERSION:
        raise _err(f"unsupported frame version {version} at offset {off}")
    if flags != 0:
        raise _err(f"unknown frame flags {flags:#x} at offset {off}")
    start = off + FRAME_HEADER_BYTES
    if start + length > len(view):
        raise _err(
            f"frame payload truncated at offset {off}: declares {length} "
            f"bytes, only {len(view) - start} available")
    payload = view[start : start + length]
    got = zlib.crc32(payload)
    if got != crc:
        raise _err(
            f"frame CRC mismatch at offset {off}: stored {crc:#010x}, "
            f"computed {got:#010x} — record is corrupt")
    return payload, start + length


def iter_frames(buf):
    """Yield ``(offset, payload)`` for every frame in a concatenated pack."""
    off = 0
    view = memoryview(buf)
    while off < len(view):
        start = off
        payload, off = read_frame(view, off)
        yield start, payload


# ---------------------------------------------------------------------------
# record serialization
# ---------------------------------------------------------------------------

def _flat_streams(ct: CompressedTensor) -> BlockStreams:
    """Host copies of the streams with every leading (stack/shard) dim
    flattened into the block dim (shared layout contract:
    ``codec.flatten_blocks``)."""
    s = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), ct.streams)
    return block_codec.flatten_blocks(s)


def to_wire(ct: CompressedTensor, *, stacked: bool = False) -> bytes:
    """Serialize one tensor (or one stacked ``(L, ...)`` stream bundle).

    ``stacked=True`` records the leading layer-stack dim of the streams in
    the header so :func:`from_wire` can restore the exact ``(L[, S], B)``
    layout — this is how serving handles' stream bundles hit the disk
    without being re-laid-out.
    """
    stack = 0
    if stacked:
        if ct.mode != "enec":
            raise WireError("only enec-mode tensors can be stacked on wire")
        stack = int(ct.streams.mask.shape[0])
        if not 0 < stack <= 0xFFFF:
            raise WireError(f"stack length {stack} out of range")
    out = [struct.pack("<IBBH", MAGIC, _MODE_TAGS[ct.mode],
                       _FMT_TAGS[ct.fmt_name], stack)]
    out.append(struct.pack("<I", len(ct.shape)))
    out.append(np.asarray(ct.shape, np.int64).tobytes())
    out.append(struct.pack("<8s", ct.dtype_str.encode()[:8]))
    out.append(struct.pack("<II", ct.block_elems, ct.shards))
    if ct.mode in ("raw", "const"):
        out.append(np.asarray(jax.device_get(ct.raw_bytes), np.uint8).tobytes())
        return b"".join(out)

    p = ct.params
    out.append(struct.pack("<5i", p.b, p.n, p.m, p.L, p.l))
    s = _flat_streams(ct)
    nblocks = s.mask.shape[0]
    out.append(struct.pack("<I", nblocks))
    out.append(np.asarray(s.high_len, np.uint32).tobytes())
    out.append(s.mask.tobytes())
    out.append(s.low.tobytes())
    out.append(s.raw.tobytes())
    # exact high stream: per block, unpack the padded device form and re-pack
    # only the true values with straight bit concatenation — entirely on the
    # host (bitio's xp=np path), no device round-trip on the save path
    width = p.n - p.m
    if width:
        dense = bitio.unpack_fixed(s.high, ct.block_elems, width, xp=np)
        for blk in range(nblocks):
            count = int(s.high_len[blk]) // width
            out.append(bitio.np_pack_bits_exact(dense[blk, :count], width))
    return b"".join(out)


def _expected_raw_nbytes(mode: str, shape, dtype_str: str) -> int:
    if mode == "const":
        return jnp.dtype(dtype_str).itemsize
    return int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype_str).itemsize


def from_wire(buf, codec=None, *, record=None, pack=None,
              offset=None, stream_place=None) -> CompressedTensor:
    """Parse one record from an EXACT buffer slice (a framed payload or a
    whole v1 blob file).  Every field is validated; short buffers, trailing
    garbage, unknown tags and impossible stream lengths raise
    :class:`WireError`.  Streams are uploaded through :func:`h2d`, so
    ``codec``'s transfer counter (default: the ambient codec's) sees
    exactly the compressed bytes.  ``record``/``pack``/``offset`` are
    optional caller context attached to every raise (leaf name, pack file,
    absolute pack offset — what a quarantine line needs).

    ``stream_place``, when given, is a callable
    ``stream_place(host_array, shard_dim) -> jax.Array`` used to upload the
    enec stream leaves instead of the default single-device ``jnp.asarray``;
    ``shard_dim`` is the axis index of the TP shard dim in the device
    layout, or ``None`` for unsharded records.  The mesh restore path
    (``CheckpointManager.load_for_serving(mesh=...)``) uses it to place each
    shard's wire bytes on its owning devices only — the per-shard pack never
    fans out to the whole mesh over h2d.  Raw/const payloads always upload
    replicated (they are consumed on every device).
    """
    def _err(msg):
        return WireError(msg, record=record, pack=pack, offset=offset)

    view = memoryview(buf)
    total = len(view)
    off = 0
    try:
        magic, mode_tag, fmt_tag, stack = struct.unpack_from("<IBBH", view, off)
        off += 8
        if magic != MAGIC:
            raise _err(f"bad ENEC wire magic {magic:#x}")
        if mode_tag not in _MODE_FROM_TAG:
            raise _err(f"unknown mode tag {mode_tag}")
        mode = _MODE_FROM_TAG[mode_tag]
        (ndim,) = struct.unpack_from("<I", view, off); off += 4
        if ndim > 16:
            raise _err(f"implausible ndim {ndim}")
        if off + 8 * ndim > total:
            raise _err(f"record truncated in the {ndim}-dim shape")
        shape = tuple(np.frombuffer(view, np.int64, ndim, off).tolist())
        off += 8 * ndim
        (dtype_raw,) = struct.unpack_from("<8s", view, off); off += 8
        dtype_str = bytes(dtype_raw).rstrip(b"\x00").decode()
        jnp.dtype(dtype_str)   # must name a real dtype
        block_elems, shards = struct.unpack_from("<II", view, off); off += 8
    except WireError:
        raise
    except (struct.error, UnicodeDecodeError, TypeError) as e:
        raise _err(f"corrupt record header: {e}") from None

    if mode in ("raw", "const"):
        raw = np.frombuffer(view, np.uint8, -1, off)
        expect = _expected_raw_nbytes(mode, shape, dtype_str)
        if raw.nbytes != expect:
            raise _err(
                f"{mode} record carries {raw.nbytes} payload bytes, "
                f"expected {expect} for shape {shape} dtype {dtype_str}")
        return CompressedTensor(
            streams=None, raw_bytes=h2d(raw, codec, dense=(mode == "raw")),
            fmt_name=_FMT_FROM_TAG.get(fmt_tag, "bf16"), params=None,
            shape=shape, dtype_str=dtype_str, block_elems=block_elems,
            shards=shards, mode=mode)

    if fmt_tag not in _FMT_FROM_TAG:
        raise _err(f"unknown float format tag {fmt_tag}")
    fmt = FORMATS[_FMT_FROM_TAG[fmt_tag]]
    try:
        b, n, m, L, l = struct.unpack_from("<5i", view, off); off += 20
        (nblocks,) = struct.unpack_from("<I", view, off); off += 4
    except struct.error as e:
        raise _err(f"record truncated in params: {e}") from None
    p = EnecParams(b=b, n=n, m=m, L=L, l=l)
    if not (0 <= m <= n <= 32 and L >= 1 and block_elems >= 1):
        raise _err(f"implausible params {p.astuple()} "
                   f"block_elems={block_elems}")
    if shards < 1 or nblocks % (max(stack, 1) * shards):
        raise _err(f"nblocks={nblocks} not divisible by "
                   f"stack={stack} * shards={shards} — corrupt header")

    def take(nb, what):
        nonlocal off
        need = nblocks * nb
        if off + need > total:
            raise _err(
                f"{what} stream truncated: need {need} bytes at offset "
                f"{off}, record has {total - off} left")
        arr = np.frombuffer(view, np.uint8, need, off).reshape(nblocks, nb)
        off += need
        return arr

    if off + 4 * nblocks > total:
        raise _err("high_len vector truncated")
    high_len = np.frombuffer(view, np.uint32, nblocks, off).astype(np.int32)
    off += 4 * nblocks
    widths = block_codec.stream_shapes(block_elems, fmt, p)
    mask = take(widths["mask"], "mask")
    low = take(widths["low"], "low")
    raw = take(widths["raw"], "raw")
    width = p.n - p.m
    dense = np.zeros((nblocks, block_elems), np.uint16)
    if width:
        max_bits = block_elems * width
        for blk in range(nblocks):
            bits = int(high_len[blk])
            if bits < 0 or bits > max_bits:
                raise _err(
                    f"block {blk}: high_len {bits} bits exceeds the "
                    f"{max_bits}-bit block bound — corrupt record")
            nbytes = (bits + 7) // 8
            if off + nbytes > total:
                raise _err(f"block {blk}: high stream truncated")
            count = bits // width
            try:
                dense[blk, :count] = bitio.np_unpack_bits_exact(
                    view[off : off + nbytes], count, width)
            except ValueError as e:
                raise _err(f"block {blk}: {e}") from None
            off += nbytes
    if off != total:
        raise _err(
            f"record has {total - off} trailing bytes after the high "
            f"stream — length mismatch (corrupt or mis-framed)")
    high = bitio.pack_fixed(dense, width, xp=np)

    lead = ()
    if stack:
        lead += (stack,)
    if shards > 1:
        lead += (shards,)
    flat = nblocks
    for d in lead:
        flat //= d

    shard_dim = len(lead) - 1 if shards > 1 else None

    def relayout(a):
        tail = a.shape[1:]
        host = np.ascontiguousarray(a.reshape(lead + (flat,) + tail))
        place = (None if stream_place is None
                 else lambda h: stream_place(h, shard_dim))
        return h2d(host, codec, place=place)

    streams = BlockStreams(
        mask=relayout(mask), low=relayout(low), high=relayout(high),
        high_len=relayout(high_len), raw=relayout(raw))
    ct = CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=shape, dtype_str=dtype_str, block_elems=block_elems,
        shards=shards, mode="enec")
    # the exact high bits are in hand — prefill the wire-size cache so later
    # nbytes_wire() calls never force a device sync
    ct._set_wire_bytes(high_len)
    return ct


def wire_stack(ct: CompressedTensor) -> int:
    """Leading stream stack length of a deserialized stacked record (the
    metadata describes one layer; the streams carry (L, ...))."""
    if ct.mode != "enec":
        return 0
    lead = ct.streams.mask.ndim - (3 if ct.shards > 1 else 2)
    return int(ct.streams.mask.shape[0]) if lead == 1 else 0
