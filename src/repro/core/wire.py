"""Exact packed wire/file format for ENEC-compressed tensors (host side).

The device layout pads the per-block high stream to its static bound so XLA
sees fixed shapes; the wire layout stores the *exact* bits (the paper's
file-based accounting).  This module converts between the two.  numpy only —
it runs on the checkpoint/host path, never inside jit.

Layout per tensor (little endian):
  magic  u32 = 0xE47C0DEC
  mode   u8 (0=enec, 1=raw), fmt u8, reserved u16
  ndim u32, shape i64[ndim], dtype tag u8[8]
  block_elems u32, shards u32
  params: b i32, n i32, m i32, L i32, l i32  (enec mode)
  nblocks u32
  high_len u32[nblocks]            (bits)
  mask | low | raw                 (fixed-size streams, concatenated)
  high                             (exact bit stream, byte padded per block)
"""
from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from . import bitio, codec
from .api import CompressedTensor
from .codec import BlockStreams
from .dtypes import FORMATS
from .params import EnecParams

MAGIC = 0xE47C0DEC
_FMT_TAGS = {"bf16": 0, "fp16": 1, "fp32": 2}
_FMT_FROM_TAG = {v: k for k, v in _FMT_TAGS.items()}


def _flat_streams(ct: CompressedTensor) -> BlockStreams:
    s = ct.streams
    if ct.shards > 1:
        s = jax.tree.map(
            lambda a: np.asarray(jax.device_get(a)).reshape(
                (a.shape[0] * a.shape[1],) + a.shape[2:]), s)
    else:
        s = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), s)
    return s


_MODE_TAGS = {"enec": 0, "raw": 1, "const": 2}


def to_wire(ct: CompressedTensor) -> bytes:
    out = [struct.pack("<IBBH", MAGIC, _MODE_TAGS[ct.mode],
                       _FMT_TAGS[ct.fmt_name], 0)]
    out.append(struct.pack("<I", len(ct.shape)))
    out.append(np.asarray(ct.shape, np.int64).tobytes())
    out.append(struct.pack("<8s", ct.dtype_str.encode()[:8]))
    out.append(struct.pack("<II", ct.block_elems, ct.shards))
    if ct.mode in ("raw", "const"):
        out.append(np.asarray(jax.device_get(ct.raw_bytes), np.uint8).tobytes())
        return b"".join(out)

    p = ct.params
    out.append(struct.pack("<5i", p.b, p.n, p.m, p.L, p.l))
    s = _flat_streams(ct)
    nblocks = s.mask.shape[0]
    out.append(struct.pack("<I", nblocks))
    out.append(np.asarray(s.high_len, np.uint32).tobytes())
    out.append(s.mask.tobytes())
    out.append(s.low.tobytes())
    out.append(s.raw.tobytes())
    # exact high stream: per block, unpack the padded device form and re-pack
    # only the true values with straight bit concatenation
    width = p.n - p.m
    if width:
        n_elems = ct.block_elems
        dense = np.asarray(
            jax.device_get(bitio.unpack_fixed(jnp.asarray(s.high), n_elems, width)))
        for blk in range(nblocks):
            count = int(s.high_len[blk]) // width
            out.append(bitio.np_pack_bits_exact(dense[blk, :count], width))
    return b"".join(out)


def from_wire(buf: bytes) -> CompressedTensor:
    off = 0
    magic, mode, fmt_tag, _ = struct.unpack_from("<IBBH", buf, off); off += 8
    assert magic == MAGIC, "bad ENEC wire magic"
    (ndim,) = struct.unpack_from("<I", buf, off); off += 4
    shape = tuple(np.frombuffer(buf, np.int64, ndim, off).tolist()); off += 8 * ndim
    (dtype_raw,) = struct.unpack_from("<8s", buf, off); off += 8
    dtype_str = dtype_raw.rstrip(b"\x00").decode()
    block_elems, shards = struct.unpack_from("<II", buf, off); off += 8
    if mode in (1, 2):
        raw = jnp.asarray(np.frombuffer(buf, np.uint8, -1, off))
        return CompressedTensor(
            streams=None, raw_bytes=raw,
            fmt_name=_FMT_FROM_TAG.get(fmt_tag, "bf16"), params=None,
            shape=shape, dtype_str=dtype_str, block_elems=block_elems,
            shards=shards, mode="raw" if mode == 1 else "const")

    fmt = FORMATS[_FMT_FROM_TAG[fmt_tag]]
    b, n, m, L, l = struct.unpack_from("<5i", buf, off); off += 20
    p = EnecParams(b=b, n=n, m=m, L=L, l=l)
    (nblocks,) = struct.unpack_from("<I", buf, off); off += 4
    high_len = np.frombuffer(buf, np.uint32, nblocks, off).astype(np.int32)
    off += 4 * nblocks
    widths = codec.stream_shapes(block_elems, fmt, p)

    def take(nb):
        nonlocal off
        arr = np.frombuffer(buf, np.uint8, nblocks * nb, off).reshape(nblocks, nb)
        off += nblocks * nb
        return arr

    mask = take(widths["mask"])
    low = take(widths["low"])
    raw = take(widths["raw"])
    width = p.n - p.m
    dense = np.zeros((nblocks, block_elems), np.uint16)
    if width:
        for blk in range(nblocks):
            nbytes = (int(high_len[blk]) + 7) // 8
            count = int(high_len[blk]) // width
            dense[blk, :count] = bitio.np_unpack_bits_exact(
                buf[off : off + nbytes], count, width)
            off += nbytes
    high = np.asarray(jax.device_get(
        bitio.pack_fixed(jnp.asarray(dense), width)))

    def reshard(a):
        a = jnp.asarray(a)
        if shards > 1:
            a = a.reshape((shards, a.shape[0] // shards) + a.shape[1:])
        return a

    streams = BlockStreams(
        mask=reshard(mask), low=reshard(low), high=reshard(high),
        high_len=reshard(high_len), raw=reshard(raw))
    return CompressedTensor(
        streams=streams, raw_bytes=None, fmt_name=fmt.name, params=p,
        shape=shape, dtype_str=dtype_str, block_elems=block_elems,
        shards=shards, mode="enec")
