"""Hierarchical halving bit-packing with byte normalization (paper §V-B, Alg. 2).

Packs fixed-width integer lanes into a byte stream using only vector
shift/OR and power-of-two slicing — no multiplies, divides, branches or
per-lane gathers.  The fold step merges the upper half of the lanes into
the lower half (``data[i] |= data[i + len/2] << width``), doubling the
effective width; once the width crosses the byte boundary the low byte of
every lane is emitted ("byte normalization") and the overflow recurses.

All functions operate on the LAST axis and broadcast over leading batch
dimensions, and all shapes/offsets are static functions of ``(N, width)``
— the whole codec is jit/pallas friendly.  The fixed-width pack/unpack pair
also accepts ``xp=numpy`` so the checkpoint/wire path can convert between
the exact and padded layouts entirely on the host (no device round-trip on
save/load).

Widths up to 32 are supported by peeling whole byte planes first and
running the halving fold on the sub-byte residue (the paper's Alg. 2 covers
``0 < a <= 8``; byte planes are its natural extension and are what the
paper itself does for the raw sign|mantissa stream).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["pack_fixed", "unpack_fixed", "packed_nbytes"]


def _mask(width: int, dtype, xp=jnp):
    return xp.asarray((1 << width) - 1, dtype)


# ---------------------------------------------------------------------------
# sub-byte halving fold
# ---------------------------------------------------------------------------

def _fold_plan(a: int, n: int):
    """Replay Alg. 2's fold loop: (width, length) at the emit point."""
    width, length = a, n
    while width < 8 and length > 1:
        width *= 2
        length //= 2
    return width, length


def _halving_pack(vals, a: int, xp=jnp):
    """vals: (..., N) uint16 lanes each < 2**a, 1 <= a < 8, N power of two.

    Returns a list of uint8 byte-plane arrays (concatenated by the caller).
    """
    assert 1 <= a < 8
    n = vals.shape[-1]
    width, length = a, n
    while width < 8 and length > 1:
        half = length // 2
        vals = vals[..., :half] | (vals[..., half:] << width)
        width *= 2
        length = half
    if width < 8:  # degenerate tiny input: single partial byte
        return [vals.astype(jnp.uint8)]
    emitted = (vals & 0xFF).astype(jnp.uint8)
    residual_width = width - 8
    if residual_width == 0:
        return [emitted]
    residual = (vals >> 8).astype(jnp.uint16)
    return [emitted] + _halving_pack(residual, residual_width, xp)


def _halving_unpack(stream, offset: int, a: int, n: int, xp=jnp):
    """Inverse of :func:`_halving_pack`. Returns (vals (..., N) uint16, offset)."""
    width, length = _fold_plan(a, n)
    if width < 8:
        vals = stream[..., offset : offset + 1].astype(jnp.uint16)
        offset += 1
    else:
        emitted = stream[..., offset : offset + length].astype(jnp.uint16)
        offset += length
        residual_width = width - 8
        if residual_width:
            residual, offset = _halving_unpack(stream, offset, residual_width,
                                               length, xp)
            vals = emitted | (residual << 8)
        else:
            vals = emitted
    while width > a:
        w2 = width // 2
        lo = vals & _mask(w2, vals.dtype, xp)
        hi = vals >> w2
        vals = xp.concatenate([lo, hi], axis=-1)
        width = w2
        length *= 2
    return vals, offset


def _halving_nbytes(a: int, n: int) -> int:
    width, length = _fold_plan(a, n)
    if width < 8:
        return 1
    total = length
    if width - 8:
        total += _halving_nbytes(width - 8, length)
    return total


# ---------------------------------------------------------------------------
# public fixed-width API (byte planes + sub-byte fold)
# ---------------------------------------------------------------------------

def packed_nbytes(n: int, width: int) -> int:
    """Exact byte length of ``pack_fixed`` output for N lanes of ``width`` bits."""
    if width == 0:
        return 0
    total = (width // 8) * n
    sub = width % 8
    if sub:
        total += _halving_nbytes(sub, n)
    return total


def pack_fixed(vals, width: int, xp=jnp):
    """Pack (..., N) unsigned lanes of ``width`` significant bits into uint8.

    N must be a power of two (pad upstream).  Output shape:
    (..., packed_nbytes(N, width)).  ``xp=numpy`` runs the identical layout
    on the host (wire/checkpoint path).
    """
    vals = xp.asarray(vals)
    n = vals.shape[-1]
    assert n & (n - 1) == 0, f"lane count must be a power of two, got {n}"
    if width == 0:
        return xp.zeros(vals.shape[:-1] + (0,), jnp.uint8)
    planes = []
    w = width
    while w >= 8:
        planes.append((vals & _mask(8, vals.dtype, xp)).astype(jnp.uint8))
        vals = vals >> 8
        w -= 8
    if w:
        sub = (vals & _mask(w, vals.dtype, xp)).astype(jnp.uint16)
        planes.extend(_halving_pack(sub, w, xp))
    return xp.concatenate(planes, axis=-1)


def unpack_fixed(stream, n: int, width: int, out_dtype=jnp.uint16, xp=jnp):
    """Inverse of :func:`pack_fixed`.

    stream: (..., packed_nbytes(n, width)) uint8 -> (..., n) ``out_dtype``.
    """
    stream = xp.asarray(stream, jnp.uint8)
    if width == 0:
        return xp.zeros(stream.shape[:-1] + (n,), out_dtype)
    vals = xp.zeros(stream.shape[:-1] + (n,), out_dtype)
    offset = 0
    shift = 0
    w = width
    while w >= 8:
        plane = stream[..., offset : offset + n].astype(out_dtype)
        vals = vals | (plane << shift)
        offset += n
        shift += 8
        w -= 8
    if w:
        sub, offset = _halving_unpack(stream, offset, w, n, xp)
        vals = vals | (sub.astype(out_dtype) << shift)
    return vals


# ---------------------------------------------------------------------------
# boolean mask <-> byte packing (for the per-group anomaly mask)
# ---------------------------------------------------------------------------

def pack_bool_mask(bits):
    """(..., G) bool -> (..., G//8) uint8, G multiple of 8, little-endian bits.

    Uses iota (not a captured constant) so it can trace inside Pallas kernels.
    """
    import jax

    g = bits.shape[-1]
    assert g % 8 == 0
    b = bits.astype(jnp.uint8).reshape(bits.shape[:-1] + (g // 8, 8))
    shifts = jax.lax.broadcasted_iota(jnp.uint8, b.shape, b.ndim - 1)
    return jax.lax.reduce(b << shifts, jnp.uint8(0), jnp.bitwise_or,
                          (b.ndim - 1,))


def unpack_bool_mask(bytes_, g: int):
    """Inverse of :func:`pack_bool_mask` -> (..., G) bool."""
    import jax

    expanded = bytes_[..., :, None]
    shifts = jax.lax.broadcasted_iota(
        jnp.uint8, expanded.shape[:-1] + (8,), expanded.ndim - 1)
    bits = (expanded >> shifts) & jnp.uint8(1)
    return bits.reshape(bytes_.shape[:-1] + (g,)).astype(jnp.bool_)


# ---------------------------------------------------------------------------
# host-side exact bit stream (wire format for the variable-length high stream)
# ---------------------------------------------------------------------------

def np_pack_bits_exact(vals: np.ndarray, width: int) -> bytes:
    """Host-only: straight little-endian bit concatenation, exact length."""
    if width == 0 or vals.size == 0:
        return b""
    vals = vals.astype(np.uint64)
    nbits = int(vals.size) * width
    out = np.zeros((nbits + 7) // 8, np.uint8)
    bitpos = np.arange(vals.size, dtype=np.uint64) * np.uint64(width)
    for k in range(width):
        bit = ((vals >> np.uint64(k)) & np.uint64(1)).astype(np.uint8)
        pos = bitpos + np.uint64(k)
        np.bitwise_or.at(out, (pos >> np.uint64(3)).astype(np.int64),
                         bit << (pos & np.uint64(7)).astype(np.uint8))
    return out.tobytes()


def np_unpack_bits_exact(buf: bytes, count: int, width: int) -> np.ndarray:
    """Host-only inverse of :func:`np_pack_bits_exact`.

    Raises ``ValueError`` when ``buf`` is shorter than the ``count * width``
    bits it claims to hold (a truncated wire record must fail loudly, not
    read out of bounds or silently return zeros).
    """
    if width == 0 or count == 0:
        return np.zeros(count, np.uint32)
    need = (count * width + 7) // 8
    if len(buf) < need:
        raise ValueError(
            f"bit stream truncated: need {need} bytes for {count} lanes of "
            f"{width} bits, got {len(buf)}")
    raw = np.frombuffer(buf, np.uint8)
    vals = np.zeros(count, np.uint64)
    bitpos = np.arange(count, dtype=np.uint64) * np.uint64(width)
    for k in range(width):
        pos = bitpos + np.uint64(k)
        bit = (raw[(pos >> np.uint64(3)).astype(np.int64)] >>
               (pos & np.uint64(7)).astype(np.uint8)) & np.uint8(1)
        vals |= bit.astype(np.uint64) << np.uint64(k)
    return vals.astype(np.uint32)
