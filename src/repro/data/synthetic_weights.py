"""Synthetic model-weight generators matching the paper's §III statistics.

No HuggingFace access in this container, so the Table II/III datasets are
emulated: per-tensor Gaussian bulk with moderate per-row scale mixing
(trained-weight heavy tails) plus a rare large-outlier population (the red
circle of Fig. 3).  Calibrated so the BF16 sets reproduce the paper's
searched parameters (b≈121-123, n=6, m=3, L=16) and ratios (≈1.35); see
bench_params / bench_ratio.

Each entry mirrors one row of Table III (name, dtype, relative size).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class WeightSetSpec:
    name: str
    dtype: str          # bf16 | fp16 | fp32
    n_elems: int
    bulk_scale: float = 0.015
    row_sigma: float = 0.6      # lognormal sigma of per-row scales
    outlier_frac: float = 2e-3  # Fig. 3 red-circle population
    outlier_gain: float = 64.0
    seed: int = 0


# the paper's Table III datasets (sizes scaled down ~2000x for CPU tests;
# ratios are size-independent per Table VI)
PAPER_MODELS = [
    WeightSetSpec("Falcon-7B", "bf16", 4 << 20, seed=1),
    WeightSetSpec("Qwen3-8B", "bf16", 4 << 20, seed=2),
    WeightSetSpec("deepseek-llm-7b-base", "bf16", 4 << 20, seed=3),
    WeightSetSpec("Qwen3-32B", "bf16", 8 << 20, seed=4),
    WeightSetSpec("Llama-3.1-8B-Instruct", "bf16", 4 << 20, seed=5),
    WeightSetSpec("CapybaraHermes-2.5-Mistral-7B", "fp16", 4 << 20, seed=6),
    WeightSetSpec("stable-video-diffusion-img2vid", "fp16", 2 << 20, seed=7,
                  row_sigma=1.0, outlier_frac=5e-3),
    WeightSetSpec("OLMo-1B-hf", "fp32", 2 << 20, seed=8),
    WeightSetSpec("bert-base-uncased", "fp32", 1 << 20, seed=9),
    WeightSetSpec("wav2vec2-large-xlsr-53-english", "fp32", 1 << 20, seed=10),
]


def generate(spec: WeightSetSpec) -> jax.Array:
    rng = np.random.default_rng(spec.seed)
    rows = max(1, spec.n_elems // 4096)
    scales = np.exp(rng.standard_normal(rows) * spec.row_sigma) \
        * spec.bulk_scale
    w = rng.standard_normal((rows, 4096)) * scales[:, None]
    w = w.reshape(-1)[: spec.n_elems]
    out_idx = rng.random(spec.n_elems) < spec.outlier_frac
    w[out_idx] *= spec.outlier_gain
    w32 = w.astype(np.float32)
    dt = {"bf16": jnp.bfloat16, "fp16": jnp.float16, "fp32": jnp.float32}
    x = jnp.asarray(w32).astype(dt[spec.dtype])
    return x


def by_name(name: str) -> WeightSetSpec:
    for s in PAPER_MODELS:
        if s.name == name:
            return s
    raise KeyError(name)
