"""Deterministic, shardable synthetic LM data pipeline.

Produces an infinite stream of (tokens, targets) batches from a seeded
Zipf-ish token source (more realistic loss curves than uniform).  Every
batch is a pure function of (seed, step, host_shard), so any host can
regenerate any slice — restart/elastic-friendly by construction.  A
background prefetch thread keeps one batch ahead of the training loop.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    shard_index: int = 0     # this host's shard
    shard_count: int = 1
    prefix_embed: int = 0    # modality stub width (VLM/audio)
    d_model: int = 0


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int,
                 a: float) -> np.ndarray:
    # bounded zipf via inverse-CDF on a truncated power law
    u = rng.random(shape)
    ranks = np.floor(np.exp(u * np.log(vocab))).astype(np.int64)  # log-uniform
    return np.clip(ranks - 1, 0, vocab - 1).astype(np.int32)


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Materialize this host's slice of batch ``step`` (pure function)."""
    assert cfg.global_batch % cfg.shard_count == 0
    local = cfg.global_batch // cfg.shard_count
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard_index]))
    tokens = _zipf_tokens(rng, (local, cfg.seq_len + 1), cfg.vocab_size,
                          cfg.zipf_a)
    out = {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
    if cfg.prefix_embed:
        out["prefix_embeds"] = rng.standard_normal(
            (local, cfg.prefix_embed, cfg.d_model)).astype(np.float32) * 0.02
    return out


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1


class Prefetcher:
    """One-batch-ahead background prefetch (host-side)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(cfg, start_step), daemon=True)
        self._thread.start()

    def _run(self, cfg, start_step):
        for batch in iterate(cfg, start_step):
            if self._stop.is_set():
                return
            self._q.put(batch)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
