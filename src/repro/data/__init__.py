"""Subpackage."""
