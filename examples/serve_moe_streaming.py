"""MoE serving with compressed expert streaming + an LRU decode cache.

Expert stacks never sit dense in memory: each expert is a per-expert
compressed wire record in an ``ExpertStore``, and a routing step
materializes only the experts it routed to, through a byte-budgeted LRU
of decoded experts (docs/MOE.md).  The budget is deliberately constrained
here so the cache both hits AND evicts — and the logits stay bit-identical
to dense serving at any budget, because ENEC is lossless and unrouted
slots are masked to exact zeros.

    PYTHONPATH=src python examples/serve_moe_streaming.py --tokens 8
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.experts import install_expert_store
from repro.runtime.streaming import assign_weight_modes, mode_mix


def _serve(model, tree, pb, max_len, n_tokens):
    logits, cache = model.prefill_fn(tree, pb, max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [np.asarray(logits)]
    gen = [tok]
    t0 = time.perf_counter()
    for _ in range(n_tokens - 1):
        dec, cache = model.decode_fn(tree, cache, tok)
        tok = jnp.argmax(dec, -1).astype(jnp.int32)
        outs.append(np.asarray(dec))
        gen.append(tok)
    jax.block_until_ready(tok)
    tpot = (time.perf_counter() - t0) / max(n_tokens - 1, 1)
    return outs, jnp.stack(gen, axis=1), tpot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--budget-frac", type=float, default=0.75,
                    help="expert-cache budget as a fraction of the fully-"
                         "resident expert bytes (0.75 sits between one "
                         "layer's working set and full residency, so the "
                         "LRU both hits and evicts)")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("phi3_5_moe_42b_a6_6b"),
                              scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pb = {"tokens": jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    max_len = args.prompt_len + args.tokens + 2

    # dense reference first: the streamed serve must reproduce these bits
    ref, ref_gen, _ = _serve(model, params, pb, max_len, args.tokens)

    tree, store = install_expert_store(params)
    store.budget_bytes = int(args.budget_frac * store.total_expert_bytes())
    tree = assign_weight_modes(tree, mode="stream", min_bytes=1024)
    print(f"[moe] {store.stats()['records']} expert records, "
          f"{store.total_expert_bytes() / 1e3:.0f} KB dense-equivalent, "
          f"budget {store.budget_bytes / 1e3:.0f} KB "
          f"({args.budget_frac:.0%}); mode_mix={mode_mix(tree)}")

    got, gen, tpot = _serve(model, tree, pb, max_len, args.tokens)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r).view(np.uint32),
                                      np.asarray(g).view(np.uint32))
    assert (np.asarray(gen) == np.asarray(ref_gen)).all()

    st = store.stats()
    hit_rate = st["hits"] / max(1, st["hits"] + st["misses"])
    print(f"[moe] experts: hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']} hit_rate={hit_rate:.2f} "
          f"fetches={st['fetches']} buckets={st['fetch_buckets']} "
          f"resident={st['resident_bytes'] / 1e3:.0f} KB")
    print(f"[moe] TPOT={tpot * 1e3:.1f} ms/token; miss-decode total "
          f"{st['decode_s'] * 1e3:.1f} ms")
    if st["evictions"] == 0 or st["hits"] == 0:
        raise SystemExit("budget did not constrain the cache "
                         f"(hits={st['hits']} evictions={st['evictions']})")
    print("[moe] generated token ids (first sequence):", gen[0].tolist())
    print("[moe] streamed-expert outputs verified bit-identical to dense")


if __name__ == "__main__":
    main()
