"""Quickstart: losslessly compress a model's weights with ENEC.

    PYTHONPATH=src python examples/quickstart.py

Compresses realistic BF16 weights, verifies bit-identical reconstruction,
prints the searched (b, n, m, L) parameters and the compression ratio —
the 60-second version of the paper's Tables II/IV.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import (compress_array, compress_tree, decompress_array,
                        search_for_array, tree_ratio, BF16)
from repro.core.wire import from_wire, to_wire
from repro.data.synthetic_weights import PAPER_MODELS, generate


def main():
    spec = next(s for s in PAPER_MODELS if s.name == "Qwen3-32B")
    print(f"== ENEC quickstart: {spec.name} ({spec.dtype}) ==")
    x = generate(spec)
    p = search_for_array(np.asarray(jax.device_get(x)), BF16)
    print(f"searched params   : (b, n, m, L) = {p.astuple()}  "
          f"(paper Table IV: (122, 6, 3, 16))")

    ct = compress_array(x, p)
    y = decompress_array(ct)
    bits_in = np.asarray(jax.device_get(x)).view(np.uint16)
    bits_out = np.asarray(jax.device_get(y)).view(np.uint16)
    assert (bits_in == bits_out).all()
    print(f"lossless          : True (bit-identical, {x.size:,} elements)")
    print(f"compression ratio : {ct.ratio():.3f}x  (paper Table II: 1.35)")

    blob = to_wire(ct)
    ct2 = from_wire(blob)
    assert (np.asarray(jax.device_get(decompress_array(ct2))).view(np.uint16)
            == bits_in).all()
    print(f"wire format       : {len(blob):,} bytes "
          f"(raw {x.size * 2:,}); round-trips exactly")

    tree = {"layer0": {"w": x[: 1 << 20].reshape(1024, 1024)},
            "scale": jax.numpy.ones((16,), jax.numpy.float32)}
    stats = tree_ratio(compress_tree(tree))
    print(f"pytree API        : {stats}")


if __name__ == "__main__":
    main()
