"""Quickstart: losslessly compress a model's weights with ENEC.

    PYTHONPATH=src python examples/quickstart.py

Uses the v1 ``Codec`` API (docs/API.md): construct a codec, compress
realistic BF16 weights, verify bit-identical reconstruction, inspect an
encode plan (bucket assignment + dispatch count), and print the searched
(b, n, m, L) parameters and the compression ratio — the 60-second version
of the paper's Tables II/IV.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.core import BF16, Codec, search_for_array, tree_ratio
from repro.core.wire import from_wire, to_wire
from repro.data.synthetic_weights import PAPER_MODELS, generate


def main():
    spec = next(s for s in PAPER_MODELS if s.name == "Qwen3-32B")
    print(f"== ENEC quickstart: {spec.name} ({spec.dtype}) ==")
    x = generate(spec)
    p = search_for_array(np.asarray(jax.device_get(x)), BF16)
    print(f"searched params   : (b, n, m, L) = {p.astuple()}  "
          f"(paper Table IV: (122, 6, 3, 16))")

    codec = Codec()   # instance-scoped caches/counters; no process globals
    ct = codec.compress_array(x, p)
    y = codec.decompress_array(ct)
    bits_in = np.asarray(jax.device_get(x)).view(np.uint16)
    bits_out = np.asarray(jax.device_get(y)).view(np.uint16)
    assert (bits_in == bits_out).all()
    print(f"lossless          : True (bit-identical, {x.size:,} elements)")
    print(f"compression ratio : {ct.ratio():.3f}x  (paper Table II: 1.35)")

    blob = to_wire(ct)
    ct2 = from_wire(blob, codec=codec)
    assert (np.asarray(jax.device_get(codec.decompress_array(ct2)))
            .view(np.uint16) == bits_in).all()
    print(f"wire format       : {len(blob):,} bytes "
          f"(raw {x.size * 2:,}); round-trips exactly")

    tree = {"layer0": {"w": x[: 1 << 20].reshape(1024, 1024)},
            "scale": jax.numpy.ones((16,), jax.numpy.float32)}
    # plan/execute split: the bucket assignment is inspectable data — one
    # jit dispatch per bucket, asserted before anything runs
    plan = codec.plan_encode(tree)
    print(f"encode plan       : {len(plan.buckets)} dispatch(es) for "
          f"{plan.n_inputs} leaves, ~{plan.predicted_wire_bytes:,} "
          f"predicted wire bytes")
    ctree = codec.execute(plan)
    assert codec.encode_cache_stats()["dispatches"] >= len(plan.buckets)
    stats = tree_ratio(ctree)
    print(f"pytree API        : {stats}")


if __name__ == "__main__":
    main()
