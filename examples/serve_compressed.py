"""Batched serving with ENEC weight streaming (the paper's §VI-C scenario).

Weights live ONLY in compressed form; each serve step decompresses
layer-by-layer inside the jitted program (XLA overlaps stream DMA + decode
of layer l+1 with layer l's compute).  Outputs are bit-identical to dense
serving — ENEC is lossless.

    PYTHONPATH=src python examples/serve_compressed.py --batch 4 --tokens 16
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import Codec, use_codec
from repro.models import build_model
from repro.runtime.streaming import (compress_params_for_streaming,
                                     stream_stats, streaming_encode_plan)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("qwen3_32b"),
                              n_layers=4, d_model=256, n_heads=8,
                              n_kv_heads=4, head_dim=32, d_ff=1024,
                              vocab_size=4096, scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # this server's explicit Codec instance (v1 API, docs/API.md): its
    # caches and counters are isolated from any other model in the process
    codec = Codec()
    plan = streaming_encode_plan(params, min_bytes=4096, shards=2,
                                 codec=codec)
    print(f"[serve] encode plan: {len(plan.buckets)} dispatch(es), "
          f"~{plan.predicted_wire_bytes / 1e6:.2f} MB predicted wire")
    # hand the inspected plan back — the policy executes it directly
    # instead of re-planning (stats + search + block staging) from scratch
    streamed = compress_params_for_streaming(params, min_bytes=4096,
                                             shards=2, codec=codec,
                                             plan=plan)
    print("[serve] stream stats:", stream_stats(streamed))

    rng = jax.random.key(1)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    max_len = args.prompt_len + args.tokens

    # StreamedWeight handles resolve inside the model — no hook to pass;
    # the jits trace under use_codec so decodes ride THIS codec's caches
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, max_len))
    decode = jax.jit(lambda p, c, t: model.decode_fn(p, c, t))

    with use_codec(codec):
        t0 = time.perf_counter()
        logits, cache = prefill(streamed, {"tokens": prompts})
        logits.block_until_ready()
        ttft = time.perf_counter() - t0
        # cross-check against dense weights: lossless -> bit-identical
        logits_dense, _ = jax.jit(
            lambda p, b: model.prefill_fn(p, b, max_len))(
            params, {"tokens": prompts})
        assert float(jnp.abs(logits_dense - logits).max()) == 0.0
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

        out_tokens = [tok]
        t0 = time.perf_counter()
        for _ in range(args.tokens - 1):
            logits, cache = decode(streamed, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        tpot = (time.perf_counter() - t0) / max(args.tokens - 1, 1)

    gen = jnp.stack(out_tokens, axis=1)
    print(f"[serve] batch={args.batch} TTFT={ttft*1e3:.1f} ms "
          f"TPOT={tpot*1e3:.1f} ms/token")
    print("[serve] generated token ids (first sequence):",
          gen[0].tolist())
    print("[serve] streamed outputs verified bit-identical to dense weights")


if __name__ == "__main__":
    main()
