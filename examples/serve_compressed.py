"""Batched serving with ENEC weight streaming (the paper's §VI-C scenario).

Weights live ONLY in compressed form; each serve step decompresses
layer-by-layer inside the jitted program (XLA overlaps stream DMA + decode
of layer l+1 with layer l's compute).  Outputs are bit-identical to dense
serving — ENEC is lossless.

    PYTHONPATH=src python examples/serve_compressed.py --batch 4 --tokens 16
"""
import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.runtime.streaming import (compress_params_for_streaming,
                                     stream_stats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("qwen3_32b"),
                              n_layers=4, d_model=256, n_heads=8,
                              n_kv_heads=4, head_dim=32, d_ff=1024,
                              vocab_size=4096, scan_layers=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    streamed = compress_params_for_streaming(params, min_bytes=4096,
                                             shards=2)
    print("[serve] stream stats:", stream_stats(streamed))

    rng = jax.random.key(1)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    max_len = args.prompt_len + args.tokens

    # StreamedWeight handles resolve inside the model — no hook to pass
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, max_len))
    decode = jax.jit(lambda p, c, t: model.decode_fn(p, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(streamed, {"tokens": prompts})
    logits.block_until_ready()
    ttft = time.perf_counter() - t0
    # cross-check against dense weights: ENEC is lossless -> bit-identical
    logits_dense, _ = jax.jit(lambda p, b: model.prefill_fn(p, b, max_len))(
        params, {"tokens": prompts})
    assert float(jnp.abs(logits_dense - logits).max()) == 0.0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = decode(streamed, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    tpot = (time.perf_counter() - t0) / max(args.tokens - 1, 1)

    gen = jnp.stack(out_tokens, axis=1)
    print(f"[serve] batch={args.batch} TTFT={ttft*1e3:.1f} ms "
          f"TPOT={tpot*1e3:.1f} ms/token")
    print("[serve] generated token ids (first sequence):",
          gen[0].tolist())
    print("[serve] streamed outputs verified bit-identical to dense weights")


if __name__ == "__main__":
    main()
