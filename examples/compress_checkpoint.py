"""Offline checkpoint (re)compression tool.

    PYTHONPATH=src python examples/compress_checkpoint.py

Builds a model state, saves it through the ENEC CheckpointManager, prints
per-tensor and aggregate compression accounting, restores, and verifies the
restore is bit-identical — the operational path a fleet uses to cut
checkpoint storage/network bytes by ~1.35x for free.
"""
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses
import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_smoke_config
from repro.data.synthetic_weights import PAPER_MODELS, generate
from repro.models import build_model
from repro.optim import adamw


def main():
    # realistic-statistics weights so ratios match the paper (random-init
    # smoke weights are narrower-spectrum)
    cfg = dataclasses.replace(get_smoke_config("llama3_2_1b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # swap one big leaf for trained-like statistics
    w = generate(dataclasses.replace(PAPER_MODELS[3], n_elems=1 << 21))
    state = {"params": params, "realistic_block": w.reshape(1024, 2048),
             "opt": adamw.init({"w": w[: 1 << 20]})}

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(Path(d), keep_last=2)
        mgr.save(1234, state, blocking=True)
        manifest = json.loads(
            (Path(d) / "step_000000001234" / "manifest.json").read_text())
        print(f"[ckpt] step {manifest['step']}: "
              f"{manifest['raw_bytes']:,} B -> "
              f"{manifest['compressed_bytes']:,} B "
              f"(ratio {manifest['ratio']:.3f}x, "
              f"{manifest['save_s']*1e3:.0f} ms)")
        biggest = sorted(manifest["leaves"], key=lambda e: -e["bytes"])[:5]
        for e in biggest:
            print(f"   {e['name']:<40s} {e['mode']:<6s} {e['bytes']:>10,} B"
                  + (f"  params={tuple(e['params'])}" if "params" in e
                     else ""))
        restored, _ = mgr.load(state)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(state)[0],
                jax.tree_util.tree_flatten_with_path(restored)[0]):
            np.testing.assert_array_equal(
                np.asarray(a).reshape(-1).view(np.uint8),
                np.asarray(b).reshape(-1).view(np.uint8), err_msg=str(pa))
        print("[ckpt] restore verified bit-identical")


if __name__ == "__main__":
    main()
